"""Docs lane: markdown link check + doctest over README/docs snippets.

Checks, for README.md and every docs/*.md file:

  1. every relative markdown link ``[text](target)`` resolves to a real
     file (anchors and external http(s)/mailto links are skipped);
  2. every ``>>>`` doctest snippet in the file runs and matches
     (``python -m doctest`` semantics via doctest.testfile);

and additionally runs the doctests embedded in the public-op docstrings
(``repro.kernels.ops`` — the ``help(flex_linear)`` examples).

  PYTHONPATH=src python scripts/check_docs.py
"""

from __future__ import annotations

import doctest
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

# [text](target) — excluding images' srcsets and in-code brackets is handled
# by only scanning outside fenced code blocks
LINK_RE = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")

DOCTEST_MODULES = ["repro.kernels.ops"]


def md_files() -> list[str]:
    files = [os.path.join(ROOT, "README.md")]
    docs = os.path.join(ROOT, "docs")
    if os.path.isdir(docs):
        files += sorted(
            os.path.join(docs, f) for f in os.listdir(docs) if f.endswith(".md")
        )
    return files


def check_links(path: str) -> list[str]:
    errors = []
    in_fence = False
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:  # pure in-page anchor
                    continue
                resolved = os.path.normpath(
                    os.path.join(os.path.dirname(path), rel)
                )
                if not os.path.exists(resolved):
                    errors.append(
                        f"{os.path.relpath(path, ROOT)}:{lineno}: "
                        f"broken link -> {target}"
                    )
    return errors


def run_doctests(path: str) -> list[str]:
    results = doctest.testfile(
        path, module_relative=False, verbose=False,
        optionflags=doctest.NORMALIZE_WHITESPACE,
    )
    if results.failed:
        return [f"{os.path.relpath(path, ROOT)}: {results.failed} doctest "
                f"failure(s) of {results.attempted}"]
    return []


def run_module_doctests(name: str) -> list[str]:
    import importlib

    mod = importlib.import_module(name)
    results = doctest.testmod(
        mod, verbose=False, optionflags=doctest.NORMALIZE_WHITESPACE
    )
    if results.failed:
        return [f"{name}: {results.failed} doctest failure(s) "
                f"of {results.attempted}"]
    return []


def main() -> int:
    errors: list[str] = []
    for path in md_files():
        errors += check_links(path)
        errors += run_doctests(path)
        print(f"checked {os.path.relpath(path, ROOT)}")
    for name in DOCTEST_MODULES:
        errors += run_module_doctests(name)
        print(f"doctested {name}")
    if errors:
        print("\n".join(["", "DOCS CHECK FAILED:"] + errors))
        return 1
    print("docs check OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
