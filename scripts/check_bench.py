"""Benchmark-record lane: validate every checked-in ``benchmarks/BENCH_*.json``
against its schema, hand-rolled (no jsonschema dependency).

Each benchmark driver owns a record shape; this script pins it so a schema
drift (a renamed key, a dropped section, a speedup that silently went below
1x) fails CI instead of rotting in the repo.  A ``BENCH_*.json`` file with
no registered schema is an error: new benchmarks register by adding one
``Bench`` row to the ``BENCHES`` table (schema + optional cross-field
checks) — nothing else to wire.

  PYTHONPATH=src python scripts/check_bench.py
"""

from __future__ import annotations

import glob
import json
import os
import sys
from typing import Callable, NamedTuple

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class Schema:
    """Tiny structural validator: dicts map key -> sub-schema, types check
    with isinstance, tuples mean any-of, callables are predicates."""

    def __init__(self, spec):
        self.spec = spec

    def errors(self, value, path="$"):
        return list(_check(self.spec, value, path))


def _check(spec, value, path):
    if isinstance(spec, dict):
        if not isinstance(value, dict):
            yield f"{path}: expected object, got {type(value).__name__}"
            return
        for key, sub in spec.items():
            if key not in value:
                yield f"{path}: missing key '{key}'"
            else:
                yield from _check(sub, value[key], f"{path}.{key}")
    elif isinstance(spec, tuple):
        for sub in spec:
            if not list(_check(sub, value, path)):
                return
        yield f"{path}: {value!r} matches none of {spec}"
    elif isinstance(spec, type):
        ok = isinstance(value, spec)
        if spec is float:  # ints are acceptable where floats are expected
            ok = isinstance(value, (int, float)) and not isinstance(value, bool)
        if spec is int:
            ok = isinstance(value, int) and not isinstance(value, bool)
        if not ok:
            yield f"{path}: expected {spec.__name__}, got {type(value).__name__}"
    elif spec is None:
        if value is not None:
            yield f"{path}: expected null"
    elif callable(spec):
        try:
            ok, why = spec(value)
        except Exception as e:  # a predicate crash is a schema failure
            ok, why = False, f"predicate raised {e!r}"
        if not ok:
            yield f"{path}: {why}"
    else:
        raise TypeError(f"bad schema node at {path}: {spec!r}")


def positive(v):
    return (isinstance(v, (int, float)) and not isinstance(v, bool) and v > 0,
            f"expected a positive number, got {v!r}")


def fraction(v):
    return (isinstance(v, (int, float)) and 0 <= v <= 1,
            f"expected a value in [0, 1], got {v!r}")


def nonempty_list(v):
    return (isinstance(v, list) and len(v) > 0, "expected a non-empty list")


_SUBPLAN = {"dataflow": str, "block": (list, None), "strip": int}

TRAIN_STEP_SCHEMA = Schema({
    "config": {"tokens": int, "d_model": int, "d_ff": int, "iters": int,
               "interpret": bool},
    "layers": nonempty_list,
    "walltime_s": {"pallas": positive, "pallas_streamed": positive,
                   "pallas_copy_bwd": positive, "xla": positive},
    "hbm_bytes_est": {"bwd_transpose_free": positive, "bwd_via_copy": positive,
                      "plan_strips": positive, "forced_streamed": positive},
    "quant": nonempty_list,
    "strip_showcase": nonempty_list,
    "mesh_composition": (list, None),
})


def extra_train_step_checks(rec) -> list[str]:
    """Per-layer quant columns: verdicts and gate errors must be coherent."""
    errors = []
    for row in rec["quant"]:
        name = row.get("name", "?")
        if row.get("qdtype") not in ("int8", "fp8", "bf16"):
            errors.append(f"quant[{name}]: verdict {row.get('qdtype')!r} is "
                          "not a tuned outcome")
        fb = row.get("fwd_hbm_bytes", {})
        if fb.get("quant", 0) >= fb.get("bf16", 0):
            errors.append(f"quant[{name}]: quantized fwd HBM bytes not below "
                          "bf16 — the 1-byte weight stream saved nothing")
        for qd, err in row.get("gate_errors", {}).items():
            if row.get("qdtype") == qd and err > row.get("budget", 0):
                errors.append(
                    f"quant[{name}]: verdict {qd} but its gate error {err} "
                    f"exceeds the budget {row.get('budget')}")
    return errors

_LANE = {"walltime_s": positive, "tokens": positive,
         "tokens_per_s": positive, "decode_steps": positive}

SERVE_SCHEMA = Schema({
    "config": {"profile": str, "requests": positive, "slots": positive,
               "block_size": positive, "prompt_len": list, "gen_len": list,
               "arrival_rate": float, "seed": int,
               "model": {"d_model": int, "d_ff": int, "num_layers": int,
                         "num_heads": int, "num_kv_heads": int,
                         "head_dim": int, "vocab_size": int}},
    "continuous": {**_LANE, "prefills": positive,
                   "slot_utilization": fraction,
                   "bucket_histogram": dict,
                   "latency_per_token_s": {"p50": positive, "p99": positive,
                                           "mean": positive}},
    "fixed_batch": {**_LANE, "row_steps": positive},
    "speedup_tokens_per_s": positive,
    "faulted": {"spec": str, "walltime_s": positive, "requests": positive,
                "completed": int, "completed_tokens": int,
                "emitted_tokens": int, "goodput_tokens_per_s": positive,
                "throughput_tokens_per_s": positive, "statuses": dict,
                "preemptions": int, "replays": int,
                "faults_injected": dict, "streams_match_clean": bool,
                "crashes": int},
})


def extra_serve_checks(rec) -> list[str]:
    """Cross-field relations the flat schema can't express."""
    errors = []
    cont, fixed = rec["continuous"], rec["fixed_batch"]
    if cont["tokens"] != fixed["tokens"]:
        errors.append(
            f"continuous decoded {cont['tokens']} tokens but fixed-batch "
            f"{fixed['tokens']} — not the same workload")
    if rec["speedup_tokens_per_s"] <= 1.0:
        errors.append(
            f"checked-in speedup is {rec['speedup_tokens_per_s']:.3f}x — "
            "continuous batching must beat the fixed-batch baseline")
    if fixed["row_steps"] < fixed["tokens"]:
        errors.append("fixed_batch.row_steps < useful tokens (impossible)")
    buckets = {int(k) for k in cont["bucket_histogram"]}
    if any(b > rec["config"]["slots"] for b in buckets):
        errors.append(
            f"bucket histogram {sorted(buckets)} exceeds slot capacity "
            f"{rec['config']['slots']}")
    ft = rec["faulted"]
    if ft["crashes"] != 0:
        errors.append(f"faulted.crashes is {ft['crashes']} — the scheduler "
                      "must degrade, never crash")
    if not ft["streams_match_clean"]:
        errors.append("faulted: a completed stream diverged from the clean "
                      "replay — preempt-and-replay determinism broken")
    # goodput <= clean, stated structurally (token counts / same-run rates)
    # rather than as cross-run wall-clock, which CPU timing noise can flip:
    # faults can only lose completed work, and replayed/truncated work is
    # never goodput.
    if ft["completed_tokens"] > cont["tokens"]:
        errors.append(
            f"faulted completed {ft['completed_tokens']} tokens but the "
            f"clean run only has {cont['tokens']} — injected faults cannot "
            "create completed work")
    if ft["goodput_tokens_per_s"] > ft["throughput_tokens_per_s"]:
        errors.append(
            "faulted goodput exceeds the same run's total throughput — "
            "replayed/failed work counted as goodput")
    if ft["completed_tokens"] > ft["emitted_tokens"]:
        errors.append(
            f"faulted: {ft['completed_tokens']} completed tokens exceed the "
            f"{ft['emitted_tokens']} emitted — accounting is wrong")
    if sum(ft["statuses"].values()) != ft["requests"]:
        errors.append(
            f"faulted.statuses {ft['statuses']} does not account for every "
            f"request ({ft['requests']})")
    if ft["completed"] < 1:
        errors.append("faulted: nothing completed — degradation is total")
    if ft["replays"] > ft["preemptions"]:
        errors.append(
            f"faulted: {ft['replays']} replays exceed {ft['preemptions']} "
            "preemptions (each replay must follow a preemption)")
    return errors


_ATTN_VARIANT = {"walltime_s": positive, "hbm_bytes": positive,
                 "vmem_bytes": positive}

ATTN_SCHEMA = Schema({
    "config": {"seq": int, "kv": int, "heads": int, "kv_heads": int,
               "head_dim": int, "group": int, "iters": int,
               "interpret": bool, "buckets": nonempty_list},
    "prefill": {"q": {**_ATTN_VARIANT, "block": nonempty_list},
                "kv": {**_ATTN_VARIANT, "block": nonempty_list}},
    "decode": dict,
    "planned": {"sweep": str, "block": nonempty_list, "source": str,
                "decode_kinds": dict},
})


def extra_attn_checks(rec) -> list[str]:
    """The analytical orderings the schedule family exists to exploit."""
    errors = []
    pf = rec["prefill"]
    if pf["q"]["block"] == pf["kv"]["block"]:
        if pf["kv"]["hbm_bytes"] >= pf["q"]["hbm_bytes"]:
            errors.append(
                "kv-stationary must move less HBM than q-stationary at the "
                "same blocks on a GQA prefill shape (K/V resident, Q streams)")
        if pf["kv"]["vmem_bytes"] <= pf["q"]["vmem_bytes"]:
            errors.append(
                "kv-stationary must hold more VMEM than q-stationary "
                "(whole-rows accumulator slab) — residency math drifted")
    for b, row in rec["decode"].items():
        for kind in ("paged", "gather"):
            if kind not in row:
                errors.append(f"decode[{b}]: missing kind '{kind}'")
                continue
            errors += [f"decode[{b}].{kind}: {m}"
                       for m in Schema(_ATTN_VARIANT).errors(row[kind])]
        if ("paged" in row and "gather" in row
                and row["paged"]["hbm_bytes"] >= row["gather"]["hbm_bytes"]):
            errors.append(
                f"decode[{b}]: the in-place paged kernel must read less HBM "
                "than the densifying gather (it skips the 3x cache copy)")
    if rec["planned"]["sweep"] not in ("q", "kv"):
        errors.append(f"planned.sweep {rec['planned']['sweep']!r} unknown")
    bad = {b: k for b, k in rec["planned"]["decode_kinds"].items()
           if k not in ("paged", "gather")}
    if bad:
        errors.append(f"planned.decode_kinds has unknown kinds: {bad}")
    if {int(b) for b in rec["decode"]} != set(rec["config"]["buckets"]):
        errors.append("decode buckets don't match config.buckets")
    return errors


_SSM_VARIANT = {"chunk": int, "walltime_s": positive, "hbm_bytes": positive,
                "vmem_bytes": positive}

SSM_SCHEMA = Schema({
    "config": {"batch": int, "seq": int, "heads": int, "key_dim": int,
               "val_dim": int, "post_update": bool, "iters": int,
               "interpret": bool, "buckets": nonempty_list},
    "prefill": dict,
    "decode": dict,
    "planned": {"sweep": str, "chunk": int, "source": str,
                "decode_kinds": dict},
})


def extra_ssm_checks(rec) -> list[str]:
    """The analytical orderings the scan schedule family exists to exploit."""
    errors = []
    for chunk, row in rec["prefill"].items():
        for sweep in ("state", "out"):
            if sweep not in row:
                errors.append(f"prefill[{chunk}]: missing sweep '{sweep}'")
                continue
            errors += [f"prefill[{chunk}].{sweep}: {m}"
                       for m in Schema(_SSM_VARIANT).errors(row[sweep])]
        if "state" in row and "out" in row:
            if row["state"]["hbm_bytes"] >= row["out"]["hbm_bytes"]:
                errors.append(
                    f"prefill[{chunk}]: state-stationary must move less HBM "
                    "than the out-streamed sweep at the same chunk (the "
                    "state never round-trips) — traffic math drifted")
            if row["state"]["vmem_bytes"] < row["out"]["vmem_bytes"]:
                errors.append(
                    f"prefill[{chunk}]: state-stationary must hold at least "
                    "as much VMEM (the whole state slab stays resident)")
    for b, row in rec["decode"].items():
        for kind in ("fused", "einsum"):
            if kind not in row:
                errors.append(f"decode[{b}]: missing kind '{kind}'")
                continue
            errors += [f"decode[{b}].{kind}: {m}"
                       for m in Schema({"walltime_s": positive,
                                        "hbm_bytes": positive,
                                        "vmem_bytes": positive,
                                        }).errors(row[kind])]
        if ("fused" in row and "einsum" in row
                and row["fused"]["hbm_bytes"] >= row["einsum"]["hbm_bytes"]):
            errors.append(
                f"decode[{b}]: the fused step kernel must read less HBM "
                "than the jnp recurrence (no k v^T intermediate round-trip)")
    if rec["planned"]["sweep"] not in ("state", "out"):
        errors.append(f"planned.sweep {rec['planned']['sweep']!r} unknown")
    if rec["planned"]["chunk"] <= 0:
        errors.append(f"planned.chunk {rec['planned']['chunk']} not positive")
    bad = {b: k for b, k in rec["planned"]["decode_kinds"].items()
           if k not in ("fused", "einsum")}
    if bad:
        errors.append(f"planned.decode_kinds has unknown kinds: {bad}")
    if {int(b) for b in rec["decode"]} != set(rec["config"]["buckets"]):
        errors.append("decode buckets don't match config.buckets")
    return errors


_QLANE = {"tokens": positive, "decode_hbm_bytes": positive}

QUANT_SCHEMA = Schema({
    "config": {"profile": str, "requests": positive, "slots": positive,
               "prompt_len": list, "gen_len": list, "arrival_rate": float,
               "seed": int,
               "model": {"d_model": int, "d_ff": int, "num_layers": int,
                         "vocab_size": int}},
    "walltime_s": positive,
    "tokens_per_s": positive,
    "bucket_histogram": dict,
    "quant": {"dtypes": nonempty_list, "budget": positive,
              "verdicts": dict, "max_qerror": positive},
    "lanes": {"bf16": _QLANE, "quant": _QLANE},
    "decode_hbm_ratio": positive,
})


def extra_quant_checks(rec) -> list[str]:
    """Cross-lane invariants: the quant lane must be the same workload as
    the bf16 lane and actually buy decode bandwidth, and the accuracy-gate
    metadata recorded with the plan must be coherent."""
    errors = []
    bf16, quant = rec["lanes"]["bf16"], rec["lanes"]["quant"]
    if bf16["tokens"] != quant["tokens"]:
        errors.append(
            f"lanes decoded different token counts (bf16 {bf16['tokens']} "
            f"vs quant {quant['tokens']}) — not the same workload")
    if quant["decode_hbm_bytes"] >= bf16["decode_hbm_bytes"]:
        errors.append(
            f"quant decode HBM {quant['decode_hbm_bytes']:,} B is not below "
            f"the bf16 lane's {bf16['decode_hbm_bytes']:,} B — quantization "
            "bought nothing")
    ratio = quant["decode_hbm_bytes"] / bf16["decode_hbm_bytes"]
    if abs(rec["decode_hbm_ratio"] - ratio) > 1e-9:
        errors.append(
            f"decode_hbm_ratio {rec['decode_hbm_ratio']} disagrees with the "
            f"lanes' quotient {ratio}")
    if rec["decode_hbm_ratio"] > 0.6:
        errors.append(
            f"decode_hbm_ratio {rec['decode_hbm_ratio']:.3f} above the 0.6 "
            "bar — a 1-byte weight stream should roughly halve decode GEMM "
            "traffic at the bench profile")
    q = rec["quant"]
    if q["max_qerror"] > q["budget"]:
        errors.append(
            f"max_qerror {q['max_qerror']} exceeds the recorded budget "
            f"{q['budget']} — a plan shipped past its own accuracy gate")
    bad = set(q["dtypes"]) - {"int8", "fp8"}
    if bad:
        errors.append(f"unknown quant dtypes {sorted(bad)}")
    bad = set(q["verdicts"]) - {"int8", "fp8", "bf16"}
    if bad:
        errors.append(f"unknown verdict dtypes {sorted(bad)}")
    if not any(k in q["verdicts"] for k in ("int8", "fp8")):
        errors.append(
            f"no quantized verdicts in {q['verdicts']} — every layer fell "
            "back to bf16 at the bench profile")
    buckets = {int(b) for b in rec["bucket_histogram"]}
    if any(b > rec["config"]["slots"] for b in buckets):
        errors.append(
            f"bucket histogram {sorted(buckets)} exceeds slot capacity "
            f"{rec['config']['slots']}")
    return errors


class Bench(NamedTuple):
    """One registered benchmark record: the filename it pins, its structural
    schema, and optional cross-field checks the flat schema can't express."""

    filename: str
    schema: Schema
    extra: Callable[[dict], list[str]] | None = None


BENCHES = (
    Bench("BENCH_train_step.json", TRAIN_STEP_SCHEMA, extra_train_step_checks),
    Bench("BENCH_serve.json", SERVE_SCHEMA, extra_serve_checks),
    Bench("BENCH_attn.json", ATTN_SCHEMA, extra_attn_checks),
    Bench("BENCH_ssm.json", SSM_SCHEMA, extra_ssm_checks),
    Bench("BENCH_quant.json", QUANT_SCHEMA, extra_quant_checks),
)

VALIDATORS = {b.filename: b for b in BENCHES}


def main() -> int:
    errors: list[str] = []
    paths = sorted(glob.glob(os.path.join(ROOT, "benchmarks", "BENCH_*.json")))
    if not paths:
        print("BENCH CHECK FAILED: no benchmarks/BENCH_*.json records found")
        return 1
    for path in paths:
        name = os.path.basename(path)
        bench = VALIDATORS.get(name)
        if bench is None:
            errors.append(f"{name}: no Bench row registered in check_bench.py")
            continue
        try:
            with open(path) as f:
                rec = json.load(f)
        except json.JSONDecodeError as e:
            errors.append(f"{name}: invalid JSON — {e}")
            continue
        errs = bench.schema.errors(rec)
        if not errs and bench.extra is not None:
            errs = [f"{name}: {msg}" for msg in bench.extra(rec)]
        else:
            errs = [f"{name}: {msg}" for msg in errs]
        errors += errs
        print(f"checked {name}" + (f" — {len(errs)} error(s)" if errs else ""))
    if errors:
        print("\n".join(["", "BENCH CHECK FAILED:"] + errors))
        return 1
    print(f"bench check OK ({len(paths)} record(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
