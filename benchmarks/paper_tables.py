"""Benchmarks reproducing each paper table/figure (Table I, Table II,
Fig. 1, Fig. 6, Fig. 7).  Each returns rows of (name, value-dict) and is
wrapped by benchmarks.run for CSV output."""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    ALL_DATAFLOWS,
    Dataflow,
    PAPER_TABLE1,
    PAPER_TABLE2,
    WORKLOADS,
    layer_cycle_table,
    overheads,
    simulate_network,
    synthesize,
)

# Critical-path delays (ns) used by the paper for Fig. 6 execution times.
TPU_DELAY_NS = 6.63
FLEX_DELAY_NS = 6.69


def table1_cycles(array: int = 32):
    """Table I: flex vs static cycles + speedups, S=32x32."""
    rows = []
    for name, layers in WORKLOADS.items():
        t0 = time.perf_counter()
        r = simulate_network(name, layers, array)
        us = (time.perf_counter() - t0) * 1e6
        row = {
            "us_per_call": us,
            "flex_cycles": r.flex_cycles,
            "paper_flex_cycles": PAPER_TABLE1[name]["flex"],
        }
        for df in ALL_DATAFLOWS:
            row[f"{df.name}_cycles"] = r.static_cycles(df)
            row[f"speedup_vs_{df.name}"] = round(r.speedup(df), 3)
            row[f"paper_speedup_vs_{df.name}"] = round(
                PAPER_TABLE1[name][df.name] / PAPER_TABLE1[name]["flex"], 3
            )
        rows.append((f"table1/{name}", row))
    return rows


def table2_area_power():
    """Table II: area/power/delay + overheads for S=8/16/32 (+128 extrap)."""
    rows = []
    for S in (8, 16, 32, 128):
        t0 = time.perf_counter()
        base, fx, o = synthesize(S), synthesize(S, flex=True), overheads(S)
        us = (time.perf_counter() - t0) * 1e6
        ref = PAPER_TABLE2.get(S)
        rows.append(
            (
                f"table2/S{S}",
                {
                    "us_per_call": us,
                    "tpu_area_mm2": round(base.area_mm2, 4),
                    "flex_area_mm2": round(fx.area_mm2, 4),
                    "area_overhead_pct": round(o.area_pct, 2),
                    "paper_area_overhead_pct": ref["overhead"]["area"] if ref else None,
                    "tpu_power_mw": round(base.power_mw, 3),
                    "flex_power_mw": round(fx.power_mw, 3),
                    "power_overhead_pct": round(o.power_pct, 2),
                    "paper_power_overhead_pct": ref["overhead"]["power"] if ref else None,
                    "delay_overhead_pct": round(o.delay_pct, 2),
                },
            )
        )
    return rows


def fig1_resnet_layers(array: int = 32):
    """Fig. 1: per-layer cycles for IS/OS/WS on ResNet-18 + the flex choice."""
    t0 = time.perf_counter()
    r = simulate_network("resnet18", WORKLOADS["resnet18"], array)
    us = (time.perf_counter() - t0) * 1e6
    tbl = layer_cycle_table(r)
    rows = []
    for i, l in enumerate(r.layers):
        rows.append(
            (
                f"fig1/{l.name}",
                {
                    "us_per_call": us / len(r.layers),
                    "IS": int(tbl[i, 0]),
                    "OS": int(tbl[i, 1]),
                    "WS": int(tbl[i, 2]),
                    "best": l.best[0].name,
                },
            )
        )
    return rows


def fig6_exec_time(array: int = 32):
    """Fig. 6: wall-clock execution time per model (cycles x critical path)."""
    rows = []
    for name, layers in WORKLOADS.items():
        if name == "vgg13":
            continue  # paper omits VGG from Fig. 6 for scale
        t0 = time.perf_counter()
        r = simulate_network(name, layers, array)
        us = (time.perf_counter() - t0) * 1e6
        row = {"us_per_call": us, "flex_ms": round(r.flex_cycles * FLEX_DELAY_NS * 1e-6, 3)}
        for df in ALL_DATAFLOWS:
            row[f"{df.name}_ms"] = round(r.static_cycles(df) * TPU_DELAY_NS * 1e-6, 3)
        row["best_static_ms"] = min(row[f"{df.name}_ms"] for df in ALL_DATAFLOWS)
        row["saved_ms_vs_worst"] = round(
            max(row[f"{df.name}_ms"] for df in ALL_DATAFLOWS) - row["flex_ms"], 3
        )
        rows.append((f"fig6/{name}", row))
    return rows


def fig7_scalability():
    """Fig. 7: average flex speedup vs static-OS at S=32/128/256."""
    rows = []
    for S in (32, 128, 256):
        t0 = time.perf_counter()
        sp = {df: [] for df in ALL_DATAFLOWS}
        for name, layers in WORKLOADS.items():
            r = simulate_network(name, layers, S)
            for df in ALL_DATAFLOWS:
                sp[df].append(r.speedup(df))
        us = (time.perf_counter() - t0) * 1e6
        paper_os = {32: 1.090, 128: 1.238, 256: 1.349}
        rows.append(
            (
                f"fig7/S{S}",
                {
                    "us_per_call": us,
                    "avg_speedup_vs_OS": round(float(np.mean(sp[Dataflow.OS])), 3),
                    "paper_avg_speedup_vs_OS": paper_os[S],
                    "avg_speedup_vs_IS": round(float(np.mean(sp[Dataflow.IS])), 3),
                    "avg_speedup_vs_WS": round(float(np.mean(sp[Dataflow.WS])), 3),
                },
            )
        )
    return rows
