"""Chunked-scan schedule-family benchmark: prefill sweeps + per-bucket decode.

Times every prefill schedule variant (state-stationary vs out-streamed at
each candidate chunk length) and both decode-scan kinds (the fused Pallas
step kernel vs the pure-jnp recurrence) per serving bucket, and reports
walltime next to the analytical cost model's HBM traffic and VMEM
residency for each — the numbers the CMU ranks scan schedules by.  The
bench shape is a long-sequence Mamba2-convention scan, the regime where
the state-stationary sweep's VMEM-resident state win shows up.

  PYTHONPATH=src python benchmarks/ssm_bench.py
  PYTHONPATH=src python benchmarks/ssm_bench.py --json benchmarks/BENCH_ssm.json
  PYTHONPATH=src python benchmarks/ssm_bench.py --dry-run   # CI smoke

``--dry-run`` is the CI lane's functional smoke: tiny shape, no timing
gates — it asserts the family's correctness invariants instead (both
sweeps bitwise-identical at every chunk, the fused decode step matching
the jnp recurrence, and the analytical ordering the schema check pins).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp


def bench_shape(dry: bool):
    from repro.core import ScanShape

    if dry:
        return ScanShape(batch=1, seq=64, heads=2, key_dim=8, val_dim=8,
                         post_update=True)
    return ScanShape(batch=1, seq=512, heads=4, key_dim=32, val_dim=32,
                     post_update=True)


def _time(run, iters: int, warmup: int = 1) -> float:
    for _ in range(warmup):
        run().block_until_ready()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        run().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def _scan_inputs(shape, seq):
    from repro.models.ssm import LOG_DECAY_MIN

    B, H = shape.batch, shape.heads
    kr, kk, kv_, kw = jax.random.split(jax.random.PRNGKey(0), 4)
    r = jax.random.normal(kr, (B, seq, H, shape.key_dim), jnp.float32)
    k = jax.random.normal(kk, (B, seq, H, shape.key_dim), jnp.float32)
    v = jax.random.normal(kv_, (B, seq, H, shape.val_dim), jnp.float32)
    lw = jnp.clip(-jax.nn.softplus(
        jax.random.normal(kw, (B, seq, H, shape.key_dim))),
        LOG_DECAY_MIN, -1e-6)
    return r, k, v, lw


def bench_prefill(shape, iters: int, interpret: bool) -> dict:
    """Both sweeps at every candidate chunk: same bits, different traffic —
    walltime + the cost model's HBM/VMEM per variant."""
    from repro.core import SCAN_CHUNK_CANDIDATES, scan_traffic_bytes
    from repro.kernels.flex_scan import SCAN_SWEEPS, flex_scan

    out = {}
    for chunk in SCAN_CHUNK_CANDIDATES:
        seq = -(-shape.seq // chunk) * chunk
        r, k, v, lw = _scan_inputs(shape, seq)
        row = {}
        bits = {}
        for sweep in SCAN_SWEEPS:
            run = lambda s=sweep: flex_scan(
                r, k, v, lw, None, chunk=chunk, sweep=s,
                post_update=shape.post_update, interpret=interpret)[0]
            cost = scan_traffic_bytes(shape, sweep, chunk,
                                      in_bytes=2, out_bytes=2)
            bits[sweep] = np.asarray(run()).tobytes()
            row[sweep] = {
                "chunk": chunk,
                "walltime_s": _time(run, iters),
                "hbm_bytes": cost.hbm_bytes,
                "vmem_bytes": cost.vmem_bytes,
            }
        assert bits["state"] == bits["out"], \
            "sweeps diverged bitwise — the schedule family is broken"
        out[str(chunk)] = row
    return out


def bench_decode(shape, buckets, iters: int, interpret: bool) -> dict:
    """Per-bucket decode step: the fused Pallas step kernel vs the jnp
    recurrence (same construction the CMU's timer uses)."""
    from repro.core import scan_decode_traffic_bytes
    from repro.kernels.flex_scan import flex_recurrent_step
    from repro.models.ssm import recurrent_step

    out = {}
    for b in buckets:
        bshape = type(shape)(batch=b, seq=1, heads=shape.heads,
                             key_dim=shape.key_dim, val_dim=shape.val_dim,
                             post_update=shape.post_update)
        r, k, v, lw = _scan_inputs(bshape, 1)
        r, k, v, lw = r[:, 0], k[:, 0], v[:, 0], lw[:, 0]
        S = jax.random.normal(
            jax.random.PRNGKey(b),
            (b, shape.heads, shape.key_dim, shape.val_dim), jnp.float32)
        args = (r, k, v, lw, S)
        fused = jax.jit(lambda *a: flex_recurrent_step(
            *a, post_update=shape.post_update, interpret=interpret)[0])
        einsum = jax.jit(lambda *a: recurrent_step(
            *a, post_update=shape.post_update)[0])
        np.testing.assert_allclose(np.asarray(fused(*args)),
                                   np.asarray(einsum(*args)),
                                   atol=2e-5, rtol=2e-5)
        row = {}
        for kind, run in (("fused", fused), ("einsum", einsum)):
            cost = scan_decode_traffic_bytes(shape, kind, b,
                                             in_bytes=2, out_bytes=2)
            row[kind] = {
                "walltime_s": _time(lambda r_=run: r_(*args), iters),
                "hbm_bytes": cost.hbm_bytes,
                "vmem_bytes": cost.vmem_bytes,
            }
        out[str(b)] = row
    return out


def planned_schedule(shape, buckets, iters: int, interpret: bool) -> dict:
    """What the CMU would actually pick for this shape (measured)."""
    from repro.core import cmu

    sp = cmu._tune_scan(
        shape, tuple(buckets), vmem_limit=cmu.VMEM_BUDGET_BYTES, top_k=3,
        measure=True, iters=iters, interpret=interpret)
    return {
        "sweep": sp.sweep,
        "chunk": sp.chunk,
        "source": sp.source,
        "decode_kinds": {str(b): sub.sweep for b, sub in
                         sorted(sp.decode.items())},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="", help="write the record here")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--dry-run", action="store_true",
                    help="CI smoke: tiny shape, correctness asserts only")
    args = ap.parse_args()

    from repro.core import DECODE_BUCKETS
    from repro.kernels.ops import default_interpret

    interpret = default_interpret()
    shape = bench_shape(args.dry_run)
    buckets = DECODE_BUCKETS if not args.dry_run else (8, 16)
    iters = 1 if args.dry_run else args.iters

    rec = {
        "config": {
            "batch": shape.batch, "seq": shape.seq, "heads": shape.heads,
            "key_dim": shape.key_dim, "val_dim": shape.val_dim,
            "post_update": shape.post_update, "iters": iters,
            "interpret": interpret, "buckets": list(buckets),
        },
        "prefill": bench_prefill(shape, iters, interpret),
        "decode": bench_decode(shape, buckets, iters, interpret),
        "planned": planned_schedule(shape, buckets, iters, interpret),
    }

    print(f"prefill T={shape.seq} H={shape.heads} "
          f"N={shape.key_dim} M={shape.val_dim}")
    for chunk, row in rec["prefill"].items():
        for sweep in ("state", "out"):
            r = row[sweep]
            print(f"  L={chunk:>2} {sweep:>5}-stationary: "
                  f"{r['walltime_s'] * 1e3:8.2f} ms   "
                  f"hbm {r['hbm_bytes'] / 1e6:8.2f} MB   "
                  f"vmem {r['vmem_bytes'] / 1024:6.1f} KiB")
    print("decode (per bucket):")
    for b, row in rec["decode"].items():
        line = f"  b={b:>3}:"
        for kind in ("fused", "einsum"):
            r = row[kind]
            line += (f"  {kind} {r['walltime_s'] * 1e3:7.2f} ms "
                     f"({r['hbm_bytes'] / 1e3:7.1f} KB hbm)")
        print(line)
    p = rec["planned"]
    print(f"planned: {p['sweep']}-stationary L={p['chunk']} "
          f"[{p['source']}], decode kinds {p['decode_kinds']}")

    if args.dry_run:
        # no timing gates on CI hardware — the correctness asserts above
        # (bitwise sweep agreement, fused-vs-einsum closeness) already ran
        print("dry-run OK")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
