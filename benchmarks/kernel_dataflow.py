"""Kernel-level flex benchmark: HBM-traffic model + interpret-mode timing.

The TPU-native analogue of Table I: for each LM architecture, total modelled
HBM bytes under each static dataflow vs. the CMU per-layer plan, plus
wall-clock interpret-mode timings of the three Pallas kernels at a
representative shape (CPU timings are NOT TPU performance — they validate
dispatch and give a relative sanity check only; the traffic model is the
perf claim)."""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import ALL_DATAFLOWS, GemmShape, static_vs_flex_traffic
from repro.kernels import flex_matmul
from repro.models.registry import ARCHS, get_config


def arch_gemms(arch: str, tokens: int = 8192) -> list[GemmShape]:
    """Per-layer GEMMs of one transformer block + embedding heads."""
    cfg = get_config(arch)
    D, M = cfg.d_model, tokens
    gs = [
        GemmShape(M, D, cfg.q_dim, name="wq"),
        GemmShape(M, D, cfg.kv_dim, name="wk"),
        GemmShape(M, D, cfg.kv_dim, name="wv"),
        GemmShape(M, cfg.q_dim, D, name="wo"),
    ]
    if cfg.family == "moe":
        e_ff = cfg.expert_d_ff or cfg.d_ff
        cap = tokens * cfg.top_k // cfg.num_experts
        gs += [
            GemmShape(M, D, cfg.num_experts, name="router"),
            GemmShape(max(cap, 1), D, e_ff, name="we1"),
            GemmShape(max(cap, 1), e_ff, D, name="we2"),
        ]
    else:
        gs += [
            GemmShape(M, D, cfg.d_ff, name="w1"),
            GemmShape(M, cfg.d_ff, D, name="w2"),
        ]
    gs.append(GemmShape(M, D, cfg.padded_vocab, name="lm_head"))
    return gs


def traffic_table(tokens: int = 8192):
    rows = []
    for arch in ARCHS:
        t0 = time.perf_counter()
        tot = static_vs_flex_traffic(arch_gemms(arch, tokens))
        us = (time.perf_counter() - t0) * 1e6
        best_static = min(tot[d.name] for d in ALL_DATAFLOWS)
        rows.append(
            (
                f"kernel_traffic/{arch}",
                {
                    "us_per_call": us,
                    **{f"{d.name}_GB": round(tot[d.name] / 1e9, 3) for d in ALL_DATAFLOWS},
                    "FLEX_GB": round(tot["FLEX"] / 1e9, 3),
                    "flex_vs_best_static": round(best_static / tot["FLEX"], 4),
                    "flex_vs_worst_static": round(
                        max(tot[d.name] for d in ALL_DATAFLOWS) / tot["FLEX"], 4
                    ),
                },
            )
        )
    return rows


def kernel_timing(M=512, K=512, N=512, block=(128, 128, 128), iters=3):
    """interpret=True wall time per dataflow (dispatch validation only)."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    rows = []
    for df in ALL_DATAFLOWS:
        out = flex_matmul(a, b, dataflow=df, block=block, interpret=True)
        out.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            flex_matmul(a, b, dataflow=df, block=block, interpret=True).block_until_ready()
        us = (time.perf_counter() - t0) / iters * 1e6
        rows.append(
            (
                f"kernel_interp/{df.name}",
                {"us_per_call": round(us, 1), "M": M, "K": K, "N": N,
                 "max_abs_err": float(jnp.abs(out - a @ b).max())},
            )
        )
    return rows
