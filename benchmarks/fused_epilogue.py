"""Fused vs unfused epilogue: HBM-traffic model + interpret-mode walltime.

The fused path writes the finished ``act(x @ w + b) + res`` block once from
VMEM; the unfused path re-streams the matmul output through HBM for every
epilogue op (read + write per op).  The traffic model quantifies the saving
the fusion buys per layer shape; the walltime columns are CPU interpret-mode
sanity checks of dispatch, not TPU performance.

  PYTHONPATH=src python benchmarks/fused_epilogue.py [--tokens 512]
"""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import GemmShape, autotune_plan
from repro.kernels import flex_linear, linear_ref


def epilogue_hbm_bytes(g: GemmShape, out_bytes: int = 4) -> tuple[int, int]:
    """(unfused, fused) extra HBM bytes for bias + activation + residual.

    Unfused: each epilogue op re-reads and re-writes the (M, N) output
    (bias-add, activation, residual-add -> 3 read+write round trips, plus one
    read of the residual operand).  Fused: only the residual operand read —
    the output block never leaves VMEM between matmul and final write.
    """
    out = g.M * g.N * out_bytes
    unfused = 3 * 2 * out + out  # 3 rmw round trips + residual read
    fused = out  # residual operand read only
    return unfused, fused


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=512)
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()

    shapes = [
        GemmShape(args.tokens, 512, 1024, name="mlp.w1"),
        GemmShape(args.tokens, 1024, 512, name="mlp.w2"),
        GemmShape(args.tokens, 512, 512, name="attn.wo"),
    ]
    plan = autotune_plan(shapes, top_k=2, iters=1)
    rng = np.random.default_rng(0)

    print(f"{'layer':10} {'df':3} {'block':>15} {'epi bytes -fuse':>16} "
          f"{'+fuse':>10} {'saving':>7} {'t_fused':>9} {'t_unfused':>10}")
    for lp in plan.layers:
        g = lp.gemm
        x = jnp.asarray(rng.normal(size=(g.M, g.K)) * 0.1, jnp.float32)
        w = jnp.asarray(rng.normal(size=(g.K, g.N)) * 0.1, jnp.float32)
        b = jnp.asarray(rng.normal(size=(g.N,)) * 0.1, jnp.float32)
        r = jnp.asarray(rng.normal(size=(g.M, g.N)) * 0.1, jnp.float32)

        def fused():
            return flex_linear(x, w, b, activation="gelu", residual=r,
                               dataflow=lp.dataflow, block=lp.block,
                               strip=lp.strip, interpret=True)

        def unfused():
            return linear_ref(x, w, b, activation="gelu", residual=r)

        np.testing.assert_allclose(np.asarray(fused()), np.asarray(unfused()),
                                   atol=1e-5, rtol=1e-5)
        tf = min(_timeit(fused) for _ in range(args.iters))
        tu = min(_timeit(unfused) for _ in range(args.iters))
        ub, fb = epilogue_hbm_bytes(g)
        print(f"{g.name:10} {lp.dataflow.name:3} {str(lp.block):>15} "
              f"{ub:>16,} {fb:>10,} {1 - fb / ub:>6.0%} {tf:>8.3f}s {tu:>9.3f}s")


def _timeit(fn) -> float:
    t0 = time.perf_counter()
    fn().block_until_ready()
    return time.perf_counter() - t0


if __name__ == "__main__":
    main()
