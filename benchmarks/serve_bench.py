"""Serving benchmark: continuous batching vs the fixed-batch baseline.

Replays a synthetic Poisson trace (mixed prompt/generation lengths) through
the ``launch.scheduler`` continuous-batching runtime and through the legacy
fixed-batch loop, and reports tokens/s, p50/p99 per-token latency, slot
utilization, and the decode bucket histogram.  Both paths get one untimed
warm-up replay first so compile time never pollutes the comparison.

The default ``--profile bench`` model (d=512, 4 layers) is deliberately
compute-bound: that is the regime continuous batching targets.  At toy
``--profile smoke`` scale a decode step costs microseconds and Python
dispatch dominates, which rewards the fixed batch's fewer-but-fatter steps
— a scheduling artifact, not a serving result.

  PYTHONPATH=src python benchmarks/serve_bench.py
  PYTHONPATH=src python benchmarks/serve_bench.py --json benchmarks/BENCH_serve.json
  PYTHONPATH=src python benchmarks/serve_bench.py --dry-run   # CI smoke

``--dry-run`` is the CI serving lane's functional smoke: tiny workload,
no timing gates — it asserts the scheduler invariants (every admitted
request finishes with exactly ``max_new`` tokens, the block allocator is
fully restored, streams are bitwise identical to per-request sequential
decode) and that decode steps actually dispatch through the tuned
batch-bucket CMU sub-plans (a recorder on ``LayerPlan.decode_plan``).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax


def build_model(profile: str):
    """The benchmark model.  ``bench`` scales the smoke config up to a
    compute-bound size; ``smoke`` is the tiny CI config."""
    from repro.models import Model, get_config

    cfg = get_config("qwen3_4b", smoke=True)
    if profile == "bench":
        cfg = cfg.replace(d_model=512, d_ff=2048, num_heads=8,
                          num_kv_heads=4, head_dim=64, num_layers=4)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def latency_percentiles(events: list[tuple[int, int, float]]) -> dict:
    """Per-token latency percentiles from the scheduler's sync-event stream.

    Events are ``(decode steps so far, tokens so far, perf_counter)`` at
    every admission/eviction sync.  For consecutive events with a token
    delta, the segment walltime is attributed evenly across its tokens —
    the finest-grained latency the no-per-step-sync discipline can observe
    without reintroducing the per-step host sync it exists to avoid.
    """
    per_token: list[float] = []
    for (s0, k0, t0), (s1, k1, t1) in zip(events, events[1:]):
        dk = k1 - k0
        if dk > 0:
            per_token.extend([(t1 - t0) / dk] * dk)
    if not per_token:
        return {"p50": 0.0, "p99": 0.0, "mean": 0.0}
    arr = np.asarray(per_token)
    return {"p50": float(np.percentile(arr, 50)),
            "p99": float(np.percentile(arr, 99)),
            "mean": float(arr.mean())}


def run_continuous(model, params, trace, args):
    from repro.launch.scheduler import ServeScheduler

    def once():
        sched = ServeScheduler(
            model, params, capacity=args.slots, block_size=args.block_size,
            max_total_len=args.max_prompt + args.max_gen)
        t0 = time.perf_counter()
        results, stats = sched.run(trace)
        return results, stats, time.perf_counter() - t0, sched

    once()  # warm-up: compile every (prompt-bucket, batch-bucket) signature
    results, stats, wall, sched = once()
    check_invariants(trace, results, stats, sched)
    return results, {
        "walltime_s": wall,
        "tokens": stats.tokens,
        "tokens_per_s": stats.tokens / max(wall, 1e-9),
        "decode_steps": stats.steps,
        "prefills": stats.prefills,
        "slot_utilization": stats.slot_utilization,
        "bucket_histogram": {str(k): v for k, v in stats.bucket_histogram().items()},
        "latency_per_token_s": latency_percentiles(stats.events),
    }


def run_faulted(model, params, trace, args, clean_results):
    """Goodput under injected faults: the same trace replayed through a
    seeded ``FaultPlan``.  Goodput counts only *completed* tokens; the
    run must not crash, every request must end terminal, every completed
    stream must stay bitwise identical to the clean replay, and the KV
    allocator must be fully restored."""
    from repro.launch.scheduler import ServeScheduler
    from repro.runtime.fault_injection import FaultPlan

    faults = FaultPlan.from_spec(args.faults, seed=args.seed)

    def once():
        faults.reset()
        sched = ServeScheduler(
            model, params, capacity=args.slots, block_size=args.block_size,
            max_total_len=args.max_prompt + args.max_gen,
            deadline=args.deadline or None, faults=faults)
        t0 = time.perf_counter()
        results, stats = sched.run(trace)
        return results, stats, time.perf_counter() - t0, sched

    once()  # warm-up (poison signature adds one jit variant)
    results, stats, wall, sched = once()

    assert set(results) == {r.rid for r in trace}, "a request vanished"
    alloc = sched.kv.allocator
    assert alloc.live_blocks == 0, f"{alloc.live_blocks} KV blocks leaked"
    statuses: dict[str, int] = {}
    completed_tokens = 0
    match = True
    for r in trace:
        out = results[r.rid]
        statuses[out.status.value] = statuses.get(out.status.value, 0) + 1
        if out.status.completed:
            completed_tokens += len(out.tokens)
            match &= bool(np.array_equal(out.tokens,
                                         clean_results[r.rid].tokens))
    assert match, "a completed stream diverged from the clean replay"
    return {
        "spec": args.faults,
        "walltime_s": wall,
        "requests": len(trace),
        "completed": sum(1 for r in results.values() if r.status.completed),
        "completed_tokens": completed_tokens,
        "emitted_tokens": stats.tokens,  # includes replayed + truncated work
        "goodput_tokens_per_s": completed_tokens / max(wall, 1e-9),
        "throughput_tokens_per_s": stats.tokens / max(wall, 1e-9),
        "statuses": statuses,
        "preemptions": stats.preemptions,
        "replays": stats.replays,
        "faults_injected": stats.faults_injected,
        "streams_match_clean": match,
        "crashes": 0,  # reaching this line is the proof
    }


def run_fixed(model, params, trace):
    from repro.launch.scheduler import run_fixed_batch

    run_fixed_batch(model, params, trace)  # warm-up
    results, st = run_fixed_batch(model, params, trace)
    return results, {
        "walltime_s": st["walltime_s"],
        "tokens": st["useful_tokens"],
        "tokens_per_s": st["useful_tokens"] / max(st["walltime_s"], 1e-9),
        "decode_steps": st["decode_steps"],
        "row_steps": st["row_steps"],
    }


def check_invariants(trace, results, stats, sched) -> None:
    """The scheduler contract, asserted on every benchmark replay."""
    assert set(results) == {r.rid for r in trace}, "not every request finished"
    for r in trace:
        out = results[r.rid]
        assert out.tokens is not None and len(out.tokens) == r.max_new, \
            f"req{r.rid}: {0 if out.tokens is None else len(out.tokens)} " \
            f"tokens, wanted {r.max_new}"
        assert out.admitted_step <= out.finished_step
    assert stats.prefills == len(trace)
    alloc = sched.kv.allocator
    assert alloc.live_blocks == 0, f"{alloc.live_blocks} KV blocks leaked"
    assert alloc.free_blocks == sched.kv.num_blocks - 1  # all but scratch
    assert set(stats.bucket_histogram()) <= set(sched.buckets)


def decode_gemm_hbm_bytes(plan, histogram: dict[int, int]) -> int:
    """Analytic decode-GEMM HBM traffic of one serving lane: for every
    (bucket, steps) pair in the scheduler's bucket histogram, the dtype-aware
    roofline traffic of each layer's decode sub-plan at its planned
    (dataflow, block, strip) geometry — weight at 1 byte plus the f32
    per-channel scale when the verdict quantized, bf16 operands otherwise.
    This is the decode-bandwidth economics ``--quant`` exists to buy."""
    from repro.core import GemmShape, hbm_traffic_bytes

    total = 0
    for bucket, steps in histogram.items():
        for lp in plan.layers:
            gp = lp.decode[bucket]
            g = GemmShape(M=bucket, K=lp.gemm.K, N=lp.gemm.N,
                          name=f"{lp.name}@b{bucket}")
            bm, bk, bn = gp.block
            kw = (dict(a_bytes=2, b_bytes=1, scale_bytes=4)
                  if gp.qdtype in ("int8", "fp8")
                  else dict(a_bytes=2, b_bytes=2))
            cost = hbm_traffic_bytes(g, gp.dataflow, bm, bk, bn,
                                     strip=gp.strip, **kw)
            total += steps * cost.hbm_bytes
    return total


def quant_bench(args) -> None:
    """The ``--quant`` lane: one scheduler replay for tokens/walltime, then
    the decode-GEMM bandwidth economics of the accuracy-gated quant plan vs
    the bf16 plan over the replay's actual bucket histogram — written as
    ``BENCH_quant.json`` with the gate metadata the CI checker pins."""
    from repro.core import (
        QUANT_ERROR_BUDGET,
        autotune_plan,
        model_epilogues,
        model_gemms,
    )
    from repro.launch.scheduler import poisson_trace, serve_buckets

    dtypes = tuple(q for q in args.quant.split(",") if q)
    cfg, model, params = build_model(args.profile)
    trace = poisson_trace(
        args.requests, vocab=cfg.vocab_size, max_prompt=args.max_prompt,
        max_gen=args.max_gen, rate=args.rate, seed=args.seed,
        min_prompt=args.min_prompt, min_gen=args.min_gen)
    _, cont = run_continuous(model, params, trace, args)
    histogram = {int(b): n for b, n in cont["bucket_histogram"].items()}

    buckets = serve_buckets(args.slots)
    gemms = model_gemms(cfg, args.requests * args.max_prompt)
    sigs = model_epilogues(cfg)
    bf16_plan = autotune_plan(gemms, measure=False, decode_buckets=buckets,
                              epilogue=sigs)
    quant_plan = autotune_plan(gemms, measure=False, decode_buckets=buckets,
                               epilogue=sigs, quant=dtypes)
    assert quant_plan.has_quant(buckets)

    verdicts: dict[str, int] = {}
    qerrs = []
    for lp in quant_plan.layers:
        for gp in (lp, *lp.decode.values()):
            verdicts[gp.qdtype] = verdicts.get(gp.qdtype, 0) + 1
            if gp.qerror is not None:
                qerrs.append(gp.qerror)
    b_bf16 = decode_gemm_hbm_bytes(bf16_plan, histogram)
    b_quant = decode_gemm_hbm_bytes(quant_plan, histogram)
    ratio = b_quant / max(b_bf16, 1)
    print(f"quant decode GEMM HBM: {b_quant:,} B vs bf16 {b_bf16:,} B "
          f"over buckets {histogram} = {ratio:.2f}x")
    print(f"verdicts {verdicts}, max gate error "
          f"{max(qerrs) if qerrs else 0.0:.4f} "
          f"(budget {QUANT_ERROR_BUDGET})")

    if args.json:
        record = {
            "config": {
                "profile": args.profile,
                "requests": args.requests,
                "slots": args.slots,
                "prompt_len": [args.min_prompt, args.max_prompt],
                "gen_len": [args.min_gen, args.max_gen],
                "arrival_rate": args.rate,
                "seed": args.seed,
                "model": {"d_model": cfg.d_model, "d_ff": cfg.d_ff,
                          "num_layers": cfg.num_layers,
                          "vocab_size": cfg.vocab_size},
            },
            "walltime_s": cont["walltime_s"],
            "tokens_per_s": cont["tokens_per_s"],
            "bucket_histogram": cont["bucket_histogram"],
            "quant": {
                "dtypes": list(dtypes),
                "budget": QUANT_ERROR_BUDGET,
                "verdicts": verdicts,
                "max_qerror": max(qerrs) if qerrs else 0.0,
            },
            "lanes": {
                "bf16": {"tokens": cont["tokens"],
                         "decode_hbm_bytes": b_bf16},
                "quant": {"tokens": cont["tokens"],
                          "decode_hbm_bytes": b_quant},
            },
            "decode_hbm_ratio": ratio,
        }
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"wrote {args.json}")


def dry_run(args) -> None:
    """CI smoke: invariants + bucket-plan dispatch, zero timing gates."""
    from repro.core import (
        activate_plan,
        autotune_plan,
        model_epilogues,
        model_gemms,
    )
    from repro.core.cmu import LayerPlan
    from repro.launch.scheduler import ServeScheduler, poisson_trace, serve_buckets
    from repro.launch.serve import sequential_reference
    from repro.models import Model, get_config

    cfg = get_config("qwen3_4b", smoke=True).replace(use_pallas=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    slots = 8
    buckets = serve_buckets(slots)
    plan = autotune_plan(model_gemms(cfg, tokens=64), measure=False,
                         decode_buckets=buckets,
                         epilogue=model_epilogues(cfg))
    assert plan.has_decode(buckets)
    activate_plan(plan)

    trace = poisson_trace(8, vocab=cfg.vocab_size, max_prompt=12, max_gen=6,
                          rate=0.5, seed=args.seed)
    sched = ServeScheduler(model, params, capacity=slots,
                           block_size=args.block_size, max_total_len=12 + 6)

    # record every decode-bucket plan lookup the pallas dispatch makes while
    # the run traces its jit signatures
    lookups: list[tuple[str, int]] = []
    orig = LayerPlan.decode_plan

    def recording(self, m):
        sub = orig(self, m)
        if sub is not None:
            lookups.append((self.name, m))
        return sub

    LayerPlan.decode_plan = recording
    try:
        results, stats = sched.run(trace)
    finally:
        LayerPlan.decode_plan = orig

    check_invariants(trace, results, stats, sched)
    hit = sorted({m for _, m in lookups})
    assert lookups, "decode steps never consulted the bucket sub-plans"
    assert set(hit) <= set(buckets), (hit, buckets)
    print(f"bucket-plan dispatch: {len(lookups)} lookups across layers, "
          f"batch buckets hit {hit} (tuned {list(buckets)})")

    ref = sequential_reference(model, params, trace,
                               sched.max_blocks * sched.block_size)
    for r in trace:
        assert np.array_equal(results[r.rid].tokens, ref[r.rid]), \
            f"req{r.rid} diverges from sequential decode"
    print(f"invariants OK: {len(trace)} requests finished, allocator "
          f"restored, streams identical to per-request sequential decode")

    # the fault-degradation contract on the same trace: injected alloc
    # failures + preemptions — no crash, every request terminal, every
    # completed stream still bitwise equal to the sequential reference
    from repro.runtime.fault_injection import FaultPlan

    faults = FaultPlan(seed=args.seed, alloc_fail=0.3, preempt=0.05)
    fsched = ServeScheduler(model, params, capacity=slots,
                            block_size=args.block_size, max_total_len=12 + 6,
                            faults=faults)
    fresults, fstats = fsched.run(trace)
    assert set(fresults) == {r.rid for r in trace}, "a request vanished"
    assert fsched.kv.allocator.live_blocks == 0, "KV blocks leaked"
    assert faults.total_injected >= 1, "the fault plan never fired"
    completed = 0
    for r in trace:
        out = fresults[r.rid]
        if out.status.completed:
            completed += 1
            assert np.array_equal(out.tokens, ref[r.rid]), \
                f"req{r.rid} diverges from sequential decode under faults"
    assert completed >= 1
    print(f"fault degradation OK: {completed}/{len(trace)} completed under "
          f"{faults.describe()} (injected {fstats.faults_injected}, "
          f"preemptions {fstats.preemptions}), completed streams bitwise "
          f"identical, allocator restored")

    # the quant planning contract on the same GEMMs: the accuracy-gated
    # quant axis annotates every forward row and decode bucket, and the
    # analytic decode traffic of a quantized verdict is strictly below the
    # bf16 plan's at the same bucket
    qplan = autotune_plan(model_gemms(cfg, tokens=64), measure=False,
                          decode_buckets=buckets,
                          epilogue=model_epilogues(cfg),
                          quant=("int8", "fp8"))
    assert qplan.has_quant(buckets), "quant tuning left a verdict missing"
    quantized = sum(gp.qdtype in ("int8", "fp8")
                    for lp in qplan.layers
                    for gp in lp.decode.values())
    assert quantized >= 1, "no decode sub-plan quantized at smoke scale"
    b0 = decode_gemm_hbm_bytes(plan, {b: 1 for b in buckets})
    b1 = decode_gemm_hbm_bytes(qplan, {b: 1 for b in buckets})
    assert b1 < b0, (b1, b0)
    print(f"quant plan OK: {quantized} quantized decode verdicts, analytic "
          f"decode HBM {b1}/{b0} = {b1 / b0:.2f}x bf16")
    print("dry-run OK")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", choices=("bench", "smoke"), default="bench")
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--min-prompt", type=int, default=8)
    ap.add_argument("--max-prompt", type=int, default=16)
    ap.add_argument("--min-gen", type=int, default=4)
    ap.add_argument("--max-gen", type=int, default=64)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="Poisson arrivals per decode step")
    ap.add_argument("--slots", type=int, default=16)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the full benchmark record as JSON")
    ap.add_argument("--faults", default="",
                    help="also measure goodput under this injected fault "
                         "spec (runtime/fault_injection.py), e.g. "
                         "'alloc=0.05,nan=0.005,preempt=0.02,latency=0.02'")
    ap.add_argument("--deadline", type=int, default=0,
                    help="queue-wait TTL in decode steps for the faulted "
                         "replay (0 = none)")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny workload, invariants + bucket-plan dispatch "
                         "+ fault-degradation contract asserted, no timing "
                         "(CI smoke)")
    ap.add_argument("--quant", nargs="?", const="int8,fp8", default="",
                    help="measure the quant serving lane instead: one "
                         "scheduler replay plus the analytic decode-GEMM "
                         "HBM economics of the accuracy-gated quant plan "
                         "vs bf16 (bare flag = 'int8,fp8')")
    args = ap.parse_args()

    if args.dry_run:
        dry_run(args)
        return
    if args.quant:
        quant_bench(args)
        return

    from repro.launch.scheduler import poisson_trace, serve_buckets

    cfg, model, params = build_model(args.profile)
    trace = poisson_trace(
        args.requests, vocab=cfg.vocab_size, max_prompt=args.max_prompt,
        max_gen=args.max_gen, rate=args.rate, seed=args.seed,
        min_prompt=args.min_prompt, min_gen=args.min_gen)
    total = sum(r.max_new for r in trace)
    gens = sorted(r.max_new for r in trace)
    print(f"trace: {args.requests} requests, {total} tokens, gen lengths "
          f"{gens[0]}..{gens[-1]} (median {gens[len(gens) // 2]}), "
          f"arrival rate {args.rate}/step")

    clean_results, cont = run_continuous(model, params, trace, args)
    lat = cont["latency_per_token_s"]
    print(f"continuous: {cont['tokens']} tok in {cont['walltime_s']*1e3:.0f} ms "
          f"= {cont['tokens_per_s']:,.0f} tok/s | {cont['decode_steps']} steps, "
          f"util {cont['slot_utilization']:.2f}, "
          f"buckets {cont['bucket_histogram']}")
    print(f"  per-token latency p50 {lat['p50']*1e3:.2f} ms, "
          f"p99 {lat['p99']*1e3:.2f} ms")

    _, fixed = run_fixed(model, params, trace)
    print(f"fixed batch: {fixed['tokens']} tok in {fixed['walltime_s']*1e3:.0f} ms "
          f"= {fixed['tokens_per_s']:,.0f} tok/s | {fixed['row_steps']} "
          f"row-steps for {fixed['tokens']} useful")

    speedup = cont["tokens_per_s"] / max(fixed["tokens_per_s"], 1e-9)
    print(f"continuous / fixed tokens/s: {speedup:.2f}x")

    faulted = None
    if args.faults:
        faulted = run_faulted(model, params, trace, args, clean_results)
        print(f"faulted ({faulted['spec']}): "
              f"{faulted['completed']}/{faulted['requests']} completed, "
              f"goodput {faulted['goodput_tokens_per_s']:,.0f} tok/s "
              f"({faulted['goodput_tokens_per_s']/max(cont['tokens_per_s'], 1e-9):.2f}x clean) | "
              f"statuses {faulted['statuses']}, "
              f"injected {faulted['faults_injected']}")

    if args.json:
        record = {
            "config": {
                "profile": args.profile,
                "requests": args.requests,
                "slots": args.slots,
                "block_size": args.block_size,
                "prompt_len": [args.min_prompt, args.max_prompt],
                "gen_len": [args.min_gen, args.max_gen],
                "arrival_rate": args.rate,
                "seed": args.seed,
                "model": {"d_model": cfg.d_model, "d_ff": cfg.d_ff,
                          "num_layers": cfg.num_layers,
                          "num_heads": cfg.num_heads,
                          "num_kv_heads": cfg.num_kv_heads,
                          "head_dim": cfg.head_dim,
                          "vocab_size": cfg.vocab_size},
            },
            "continuous": cont,
            "fixed_batch": fixed,
            "speedup_tokens_per_s": speedup,
        }
        if faulted is not None:
            record["faulted"] = faulted
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
