import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis per (arch x shape) on the single-pod production mesh.

Three terms per cell (DESIGN.md §6):
  compute_s    = HLO_FLOPs_per_chip / 197e12
  memory_s     = HLO_bytes_per_chip / 819e9
  collective_s = collective_bytes_per_chip / 50e9

XLA's cost analysis counts while-loop bodies ONCE, so scanned layer stacks
would be undercounted ~L-fold.  This harness therefore lowers UNROLLED
reduced-depth probes (1 and 2 layer-groups, full shapes, attention chunk
scans unrolled) and extrapolates linearly in depth — exact because every
group is structurally identical.  Interior SSM chunk scans stay rolled and
are corrected analytically (`ssm_chunk_correction`).  MODEL_FLOPS uses the
spec convention 6·N_active·tokens (train) / 2·N_active·tokens (inference).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--cells a,b] [--out DIR]
"""

import argparse
import json
import time
from typing import Any

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256  # single-pod 16x16


def probe_plan(cfg) -> list[tuple[dict, float]]:
    """[(override, weight)] s.t. total_cost = sum(weight_i * C(override_i)).

    For a stack of G identical groups: C(G) = C1 + (G-1)*(C2-C1)
                                            = (2-G)*C1 + (G-1)*C2.
    """
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        pat = len(cfg.window_pattern)
        G = cfg.num_layers // pat
        return [({"num_layers": pat}, 2.0 - G), ({"num_layers": 2 * pat}, G - 1.0)]
    if fam == "ssm":
        G = cfg.num_layers
        return [({"num_layers": 1}, 2.0 - G), ({"num_layers": 2}, G - 1.0)]
    if fam == "hybrid":
        k = cfg.attn_every
        G = cfg.num_layers / k
        return [({"num_layers": k}, 2.0 - G), ({"num_layers": 2 * k}, G - 1.0)]
    if fam == "encdec":
        E, D = cfg.num_enc_layers, cfg.num_layers
        base = {"num_enc_layers": 1, "num_layers": 1}
        return [
            (dict(base), 1.0 - (E - 1.0) - (D - 1.0)),
            ({"num_enc_layers": 2, "num_layers": 1}, E - 1.0),
            ({"num_enc_layers": 1, "num_layers": 2}, D - 1.0),
        ]
    raise ValueError(fam)


def ssm_chunk_correction(cfg, cell, num_layers: int) -> float:
    """FLOPs of the rolled interior chunk-scan bodies beyond the one counted.

    Per chunk body (chunked_diag_linear_attn): scores 2BHL²N, intra-out
    2BHL²M, state-read 2BHLNM, state-update 2BHLNM  (L = LA_CHUNK = 16).
    """
    from repro.models.ssm import LA_CHUNK

    if cell.step == "decode":
        return 0.0
    B, T, L = cell.global_batch, cell.seq_len, LA_CHUNK
    chunks = T // L
    if cfg.family == "ssm":
        H, N = cfg.rwkv_heads, cfg.rwkv_head_size
        M = N
        per_chunk = 2 * B * H * (L * L * N + L * L * M + 2 * L * N * M)
        return num_layers * (chunks - 1) * per_chunk
    if cfg.family == "hybrid":
        H, N, M = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
        per_chunk = 2 * B * H * (L * L * N + L * L * M + 2 * L * N * M)
        return num_layers * (chunks - 1) * per_chunk
    return 0.0


def model_flops(cfg, cell) -> float:
    total, active = cfg.param_count()
    if cell.step == "train":
        return 6.0 * active * cell.global_batch * cell.seq_len
    if cell.step == "prefill":
        return 2.0 * active * cell.global_batch * cell.seq_len
    return 2.0 * active * cell.global_batch  # decode: per generated token


def probe_cell(arch: str, shape: str, rules=None, microbatches=1) -> dict[str, Any]:
    """Extrapolated per-chip HLO flops / bytes / collective bytes for a cell."""
    from repro.launch.dryrun import lower_cell
    from repro.launch.specs import model_for_cell

    model, cell = model_for_cell(arch, shape)
    cfg = model.cfg
    tot = {"hlo_flops": 0.0, "hlo_bytes": 0.0, "coll_bytes": 0.0, "coll": {}}
    for overrides, w in probe_plan(cfg):
        ov = dict(overrides, attn_unroll=True)
        rec = lower_cell(
            arch, shape, overrides=ov, unroll=True, rules=rules,
            microbatches=microbatches,
        )
        nl = ov.get("num_layers", cfg.num_layers)
        corr = ssm_chunk_correction(cfg, cell, nl) / CHIPS
        if cell.step == "train":
            corr *= 3  # fwd + bwd
        tot["hlo_flops"] += w * (rec["hlo_flops"] + corr)
        tot["hlo_bytes"] += w * rec["hlo_bytes"]
        cb = sum(v["bytes"] for v in rec["collectives"].values())
        tot["coll_bytes"] += w * cb
        for k, v in rec["collectives"].items():
            tot["coll"][k] = tot["coll"].get(k, 0.0) + w * v["bytes"]
    return tot


def roofline_terms(tot: dict[str, Any]) -> dict[str, float]:
    return {
        "compute_s": tot["hlo_flops"] / PEAK_FLOPS,
        "memory_s": tot["hlo_bytes"] / HBM_BW,
        "collective_s": tot["coll_bytes"] / ICI_BW,
    }


def analyse_cell(arch: str, shape: str, rules=None, microbatches=1) -> dict[str, Any]:
    from repro.launch.specs import model_for_cell

    model, cell = model_for_cell(arch, shape)
    t0 = time.time()
    tot = probe_cell(arch, shape, rules=rules, microbatches=microbatches)
    terms = roofline_terms(tot)
    dom = max(terms, key=terms.get)
    mf = model_flops(cell=cell, cfg=model.cfg)
    hlo_total = tot["hlo_flops"] * CHIPS
    rec = {
        "arch": arch, "shape": shape, "step": cell.step,
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dom,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": round(mf / hlo_total, 4) if hlo_total else None,
        "roofline_fraction": round(
            max(terms["compute_s"], 1e-12) / max(sum(terms.values()), 1e-12), 4
        ),
        "coll_breakdown_GB": {k: round(v / 1e9, 3) for k, v in tot["coll"].items() if v},
        "probe_s": round(time.time() - t0, 1),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default="")
    ap.add_argument("--out", default="results/roofline")
    args = ap.parse_args()
    from repro.configs import all_cells

    cells = (
        [tuple(c.split(":")) for c in args.cells.split(",")]
        if args.cells
        else all_cells()
    )
    os.makedirs(args.out, exist_ok=True)
    for arch, shape in cells:
        path = os.path.join(args.out, f"{arch}__{shape}.json")
        if os.path.exists(path):
            print(f"CACHED {arch} x {shape}")
            continue
        try:
            rec = analyse_cell(arch, shape)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(
                f"OK {arch:16s} {shape:12s} comp {rec['compute_s']:.4f}s "
                f"mem {rec['memory_s']:.4f}s coll {rec['collective_s']:.4f}s "
                f"dom={rec['dominant'][:-2]:10s} useful={rec['useful_ratio']}"
            )
        except Exception as e:  # noqa: BLE001
            print(f"FAIL {arch} x {shape}: {str(e)[:200]}")


if __name__ == "__main__":
    main()
