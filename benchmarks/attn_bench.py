"""Attention schedule-family benchmark: prefill sweeps + per-bucket decode.

Times every prefill schedule variant (q-stationary vs kv-stationary at a
fixed block geometry) and both decode-attention kinds (in-place Pallas
paged kernel vs the pure-jnp gather baseline) per serving bucket, and
reports walltime next to the analytical cost model's HBM traffic and VMEM
residency for each — the numbers the CMU ranks schedules by.  The bench
shape is long-context GQA prefill (group 2), the regime where the
kv-stationary sweep's K/V-resident HBM win shows up.

  PYTHONPATH=src python benchmarks/attn_bench.py
  PYTHONPATH=src python benchmarks/attn_bench.py --json benchmarks/BENCH_attn.json
  PYTHONPATH=src python benchmarks/attn_bench.py --dry-run   # CI smoke

``--dry-run`` is the CI lane's functional smoke: tiny shape, no timing
gates — it asserts the family's correctness invariants instead (both
sweep orders bitwise-identical, the paged decode kernel matching its
gather oracle, and the analytical ordering the schema check pins).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp


def bench_shape(dry: bool):
    from repro.core import AttnShape

    if dry:
        return AttnShape(seq=64, kv=64, heads=4, kv_heads=2, head_dim=16)
    return AttnShape(seq=512, kv=512, heads=4, kv_heads=2, head_dim=32)


def _time(run, iters: int, warmup: int = 1) -> float:
    for _ in range(warmup):
        run().block_until_ready()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        run().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_prefill(shape, iters: int, interpret: bool) -> dict:
    """Both sweep orders at the same (bq, bk): same bits, different
    traffic — walltime + the cost model's HBM/VMEM per variant."""
    from repro.core import attn_traffic_bytes
    from repro.kernels.flash_attention import mha_flash

    bq = bk = min(128, max(-(-shape.rows // 8) * 8, 8))
    kq, kk, kv_ = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (1, shape.seq, shape.heads, shape.head_dim),
                          jnp.float32)
    k = jax.random.normal(kk, (1, shape.kv, shape.kv_heads, shape.head_dim),
                          jnp.float32)
    v = jax.random.normal(kv_, (1, shape.kv, shape.kv_heads, shape.head_dim),
                          jnp.float32)
    out = {}
    bits = {}
    for sweep in ("q", "kv"):
        run = lambda s=sweep: mha_flash(q, k, v, causal=True, block_q=bq,
                                        block_k=bk, sweep=s,
                                        interpret=interpret)
        cost = attn_traffic_bytes(shape, sweep, bq, bk,
                                  in_bytes=2, out_bytes=2)
        bits[sweep] = np.asarray(run()).tobytes()
        out[sweep] = {
            "block": [bq, bk],
            "walltime_s": _time(run, iters),
            "hbm_bytes": cost.hbm_bytes,
            "vmem_bytes": cost.vmem_bytes,
        }
    assert bits["q"] == bits["kv"], \
        "sweep orders diverged bitwise — the schedule family is broken"
    return out


def bench_decode(shape, buckets, iters: int, interpret: bool) -> dict:
    """Per-bucket decode step: the Pallas paged kernel vs the jnp gather,
    over a proxy paged cache (same construction the CMU's timer uses)."""
    from repro.core import attn_decode_traffic_bytes
    from repro.kernels.flash_attention import (
        paged_attention,
        paged_attention_reference,
    )

    bs = 16
    cache_len = max(min(shape.kv, 64), bs)
    nb = -(-cache_len // bs)
    out = {}
    for b in buckets:
        kq, kp = jax.random.split(jax.random.PRNGKey(b))
        q = jax.random.normal(kq, (b, shape.heads, shape.head_dim),
                              jnp.float32)
        pools = jax.random.normal(
            kp, (2, b * nb + 1, bs, shape.kv_heads, shape.head_dim),
            jnp.float32)
        table = 1 + jnp.arange(b * nb, dtype=jnp.int32).reshape(b, nb)
        positions = jnp.full((b,), cache_len - 1, jnp.int32)
        args = (q, pools[0], pools[1], table, positions)
        paged = jax.jit(lambda a, k_, v_, t, p: paged_attention(
            a, k_, v_, t, p, interpret=interpret))
        gather = jax.jit(paged_attention_reference)
        np.testing.assert_allclose(np.asarray(paged(*args)),
                                   np.asarray(gather(*args)),
                                   atol=2e-5, rtol=2e-5)
        row = {}
        for kind, run in (("paged", paged), ("gather", gather)):
            cost = attn_decode_traffic_bytes(shape, kind, b, block_size=bs,
                                             in_bytes=2, out_bytes=2)
            row[kind] = {
                "walltime_s": _time(lambda r=run: r(*args), iters),
                "hbm_bytes": cost.hbm_bytes,
                "vmem_bytes": cost.vmem_bytes,
            }
        out[str(b)] = row
    return out


def planned_schedule(shape, buckets, iters: int, interpret: bool) -> dict:
    """What the CMU would actually pick for this shape (measured)."""
    from repro.core import cmu

    ap = cmu._tune_attention(
        shape, tuple(buckets), vmem_limit=cmu.VMEM_BUDGET_BYTES, top_k=3,
        measure=True, iters=iters, interpret=interpret)
    return {
        "sweep": ap.sweep,
        "block": list(ap.block),
        "source": ap.source,
        "decode_kinds": {str(b): sub.sweep for b, sub in
                         sorted(ap.decode.items())},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="", help="write the record here")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--dry-run", action="store_true",
                    help="CI smoke: tiny shape, correctness asserts only")
    args = ap.parse_args()

    from repro.core import DECODE_BUCKETS
    from repro.kernels.ops import default_interpret

    interpret = default_interpret()
    shape = bench_shape(args.dry_run)
    buckets = DECODE_BUCKETS if not args.dry_run else (8, 16)
    iters = 1 if args.dry_run else args.iters

    rec = {
        "config": {
            "seq": shape.seq, "kv": shape.kv, "heads": shape.heads,
            "kv_heads": shape.kv_heads, "head_dim": shape.head_dim,
            "group": shape.group, "iters": iters, "interpret": interpret,
            "buckets": list(buckets),
        },
        "prefill": bench_prefill(shape, iters, interpret),
        "decode": bench_decode(shape, buckets, iters, interpret),
        "planned": planned_schedule(shape, buckets, iters, interpret),
    }

    pf = rec["prefill"]
    print(f"prefill {shape.seq}x{shape.kv} g={shape.group} "
          f"(bq,bk)={tuple(pf['q']['block'])}")
    for sweep in ("q", "kv"):
        r = pf[sweep]
        print(f"  {sweep:>2}-stationary: {r['walltime_s'] * 1e3:8.2f} ms   "
              f"hbm {r['hbm_bytes'] / 1e6:8.2f} MB   "
              f"vmem {r['vmem_bytes'] / 1024:6.1f} KiB")
    print("decode (per bucket):")
    for b, row in rec["decode"].items():
        line = f"  b={b:>3}:"
        for kind in ("paged", "gather"):
            r = row[kind]
            line += (f"  {kind} {r['walltime_s'] * 1e3:7.2f} ms "
                     f"({r['hbm_bytes'] / 1e3:7.1f} KB hbm)")
        print(line)
    p = rec["planned"]
    print(f"planned: {p['sweep']}-stationary {tuple(p['block'])} "
          f"[{p['source']}], decode kinds {p['decode_kinds']}")

    if args.dry_run:
        # no timing gates on CI hardware — the correctness asserts above
        # (bitwise sweep agreement, paged-vs-gather closeness) already ran
        print("dry-run OK")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=1, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
