"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the headline value
each table argues from), then a JSON dump with all columns to
results/bench/.  Heavy 512-device artefacts (dry-run, roofline) run via
their own modules; this driver summarises their cached results when present.

Run: PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import glob
import json
import os


def _emit(rows, derived_key):
    for name, row in rows:
        print(f"{name},{row['us_per_call']:.1f},{row.get(derived_key)}")
    return rows


def main() -> None:
    from benchmarks import kernel_dataflow, paper_tables

    all_rows: list = []
    print("name,us_per_call,derived")

    all_rows += _emit(paper_tables.table1_cycles(), "speedup_vs_OS")
    all_rows += _emit(paper_tables.table2_area_power(), "area_overhead_pct")
    all_rows += _emit(paper_tables.fig1_resnet_layers(), "best")
    all_rows += _emit(paper_tables.fig6_exec_time(), "flex_ms")
    all_rows += _emit(paper_tables.fig7_scalability(), "avg_speedup_vs_OS")
    all_rows += _emit(kernel_dataflow.traffic_table(), "flex_vs_worst_static")
    all_rows += _emit(kernel_dataflow.kernel_timing(), "max_abs_err")

    # summarise cached 512-device artefacts if present
    for pattern, tag, keys in [
        ("results/dryrun/*.json", "dryrun",
         ("compile_s", "mem_temp_size_in_bytes", "hlo_flops")),
        ("results/roofline/*.json", "roofline",
         ("compute_s", "memory_s", "collective_s", "dominant", "useful_ratio")),
    ]:
        for path in sorted(glob.glob(pattern)):
            with open(path) as f:
                rec = json.load(f)
            name = os.path.basename(path)[:-5]
            row = {"us_per_call": 0.0, **{k: rec.get(k) for k in keys}}
            derived = rec.get("dominant", rec.get("compile_s"))
            print(f"{tag}/{name},0.0,{derived}")
            all_rows.append((f"{tag}/{name}", row))

    os.makedirs("results/bench", exist_ok=True)
    with open("results/bench/all.json", "w") as f:
        json.dump([{"name": n, **r} for n, r in all_rows], f, indent=1)
    print(f"\n{len(all_rows)} benchmark rows -> results/bench/all.json")


if __name__ == "__main__":
    main()
