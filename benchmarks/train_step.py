"""Fwd+bwd microbenchmark: one training step of an MLP block through the
flex kernels' custom VJP vs the XLA reference path.

Per layer the CMU train plan programs THREE decisions — forward,
dX = dY @ W^T, dW = X^T @ dY, each a (dataflow, block, operand-layout)
triple — and this benchmark reports all of them next to the measured step
walltimes.  The backward GEMMs run **transpose-free** by default (the
kernels stream W and X as stored through transposed index maps); the
``copy-bwd`` column forces the pre-v3 behaviour (materialise ``w.T`` /
``x.T`` in HBM before each backward kernel) so the trajectory of the
transpose-free win stays visible.  On CPU the kernels run in Pallas
interpret mode, so walltimes are dispatch sanity checks, not TPU
performance; the HBM-bytes column is the analytical estimate the CMU ranks
with.  ``--json`` writes the full record (see BENCH_train_step.json for the
checked-in baseline).

  PYTHONPATH=src python benchmarks/train_step.py [--tokens 256] [--iters 3]
  PYTHONPATH=src python benchmarks/train_step.py --json out.json
  PYTHONPATH=src python benchmarks/train_step.py --dry-run   # CI smoke
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import NO_TRANS, GemmShape, autotune_plan, bwd_gemms, hbm_traffic_bytes
from repro.kernels import DEFAULT_BLOCK, flex_linear, linear_ref


def _bwd_spec(sub, force_copy: bool = False):
    if sub is None:
        return None
    trans = NO_TRANS if force_copy else sub.trans
    return (sub.dataflow, sub.block, trans)


def build_losses(plan, interpret: bool, force_copy_bwd: bool = False):
    """(pallas_loss, ref_loss) over a gated-MLP block: w1 -> gelu -> w2 (+res).

    The pallas loss dispatches every GEMM — forward and, via the custom VJP,
    backward — per the train plan's sub-plans.  ``force_copy_bwd`` overrides
    every backward sub-plan's operand layout to (False, False), i.e. the
    copy-based fallback that materialises the transposed operand in HBM.
    """
    by_name = {lp.name: lp for lp in plan.layers}

    def pallas_loss(params, x):
        h = x
        for name in ("mlp.w1", "mlp.w2"):
            lp = by_name[name]
            w, b = params[name]
            res = x if name == "mlp.w2" else None
            act = "gelu" if name == "mlp.w1" else None
            h = flex_linear(
                h, w, b, activation=act, residual=res,
                dataflow=lp.dataflow, block=lp.block, interpret=interpret,
                bwd_dx=_bwd_spec(lp.bwd_dx, force_copy_bwd),
                bwd_dw=_bwd_spec(lp.bwd_dw, force_copy_bwd),
            )
        return (h * h).mean()

    def ref_loss(params, x):
        h = x
        for name in ("mlp.w1", "mlp.w2"):
            w, b = params[name]
            res = x if name == "mlp.w2" else None
            act = "gelu" if name == "mlp.w1" else None
            h = linear_ref(h, w, b, activation=act, residual=res)
        return (h * h).mean()

    return pallas_loss, ref_loss


def bwd_hbm_bytes(plan) -> dict[str, int]:
    """Analytical HBM bytes of the plan's backward GEMMs, transpose-free vs
    via-copy.  The kernel traffic is identical (same (dataflow, block)
    schedule reads the same blocks, just through swapped index maps); the
    copy path additionally round-trips the transposed operand through HBM —
    one f32 read + one write of W per dX and of X per dW.
    """
    kernel = copy_extra = 0
    for lp in plan.layers:
        g_dx, g_dw = bwd_gemms(lp.gemm)
        # the operand the copy path materialises: W (the B operand, K*N) for
        # dX, X (the A operand, M*K) for dW
        for g, sub, copied in ((g_dx, lp.bwd_dx, g_dx.K * g_dx.N),
                               (g_dw, lp.bwd_dw, g_dw.M * g_dw.K)):
            assert sub is not None, "bwd_hbm_bytes needs a train=True plan"
            blk = sub.block or DEFAULT_BLOCK
            kernel += hbm_traffic_bytes(g, sub.dataflow, *blk,
                                        in_bytes=4).hbm_bytes
            copy_extra += 2 * copied * 4  # f32 read + write of the copy
    return {"bwd_transpose_free": kernel, "bwd_via_copy": kernel + copy_extra}


def _timeit(fn, *args) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return time.perf_counter() - t0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--d-ff", type=int, default=512)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the full benchmark record as JSON")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny shapes, 1 iter, grad-correctness assert (CI smoke)")
    args = ap.parse_args()
    if args.dry_run:
        args.tokens, args.d_model, args.d_ff, args.iters = 64, 64, 128, 1

    T, D, F = args.tokens, args.d_model, args.d_ff
    gemms = [GemmShape(T, D, F, name="mlp.w1"), GemmShape(T, F, D, name="mlp.w2")]
    plan = autotune_plan(gemms, top_k=2, iters=1, train=True)

    print(f"{'layer':8} {'gemm (M,K,N)':>18} {'fwd':>4} {'dX':>8} {'dW':>8}")
    for lp in plan.layers:
        g = lp.gemm
        dx_tag = lp.bwd_dx.dataflow.name + ("" if lp.bwd_dx.trans == (False, False) else "/T")
        dw_tag = lp.bwd_dw.dataflow.name + ("" if lp.bwd_dw.trans == (False, False) else "/T")
        print(f"{lp.name:8} {f'({g.M},{g.K},{g.N})':>18} "
              f"{lp.dataflow.name:>4} {dx_tag:>8} {dw_tag:>8}")

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(T, D)) * 0.1, jnp.float32)
    params = {
        "mlp.w1": (jnp.asarray(rng.normal(size=(D, F)) * 0.05, jnp.float32),
                   jnp.zeros((F,), jnp.float32)),
        "mlp.w2": (jnp.asarray(rng.normal(size=(F, D)) * 0.05, jnp.float32),
                   jnp.zeros((D,), jnp.float32)),
    }

    pallas_loss, ref_loss = build_losses(plan, interpret=True)
    copy_loss, _ = build_losses(plan, interpret=True, force_copy_bwd=True)
    pallas_step = jax.jit(jax.value_and_grad(pallas_loss))
    copy_step = jax.jit(jax.value_and_grad(copy_loss))
    ref_step = jax.jit(jax.value_and_grad(ref_loss))

    (lp_, gp), (lr, gr) = pallas_step(params, x), ref_step(params, x)
    (lc, gc) = copy_step(params, x)
    np.testing.assert_allclose(float(lp_), float(lr), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(lc), float(lr), atol=1e-5, rtol=1e-5)
    for k in params:
        np.testing.assert_allclose(np.asarray(gp[k][0]), np.asarray(gr[k][0]),
                                   atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(gc[k][0]), np.asarray(gr[k][0]),
                                   atol=2e-4, rtol=2e-4)
    print("fwd+bwd gradients match the XLA reference (transpose-free and copy bwd)")

    tp = min(_timeit(pallas_step, params, x) for _ in range(args.iters))
    tc = min(_timeit(copy_step, params, x) for _ in range(args.iters))
    tr = min(_timeit(ref_step, params, x) for _ in range(args.iters))
    hbm = bwd_hbm_bytes(plan)
    print(f"step walltime: pallas {tp*1e3:8.2f} ms ({T/tp:10,.0f} tok/s)   "
          f"copy-bwd {tc*1e3:8.2f} ms   xla {tr*1e3:8.2f} ms ({T/tr:10,.0f} tok/s)")
    print(f"bwd HBM bytes (analytical): transpose-free {hbm['bwd_transpose_free']:,} "
          f"vs via-copy {hbm['bwd_via_copy']:,} "
          f"({hbm['bwd_via_copy'] / hbm['bwd_transpose_free']:.2f}x)")

    if args.json:
        record = {
            "config": {"tokens": T, "d_model": D, "d_ff": F,
                       "iters": args.iters, "interpret": True},
            "layers": [
                {
                    "name": lp.name,
                    "gemm": [lp.gemm.M, lp.gemm.K, lp.gemm.N],
                    "fwd": {"dataflow": lp.dataflow.name,
                            "block": list(lp.block) if lp.block else None},
                    "dx": lp.bwd_dx.to_row(),
                    "dw": lp.bwd_dw.to_row(),
                }
                for lp in plan.layers
            ],
            "walltime_s": {"pallas": tp, "pallas_copy_bwd": tc, "xla": tr},
            "hbm_bytes_est": hbm,
        }
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"wrote {args.json}")
    if args.dry_run:
        print("dry-run OK")


if __name__ == "__main__":
    main()
