"""Fwd+bwd microbenchmark: one training step of an MLP block through the
flex kernels' custom VJP vs the XLA reference path.

Per layer the CMU train plan programs THREE decisions — forward,
dX = dY @ W^T, dW = X^T @ dY, each a (dataflow, block, operand-layout,
strip) quadruple — and this benchmark reports all of them next to the
measured step walltimes.  Two ablation columns track the schedule-space
history:

* ``copy-bwd`` forces the pre-v3 backward behaviour (materialise ``w.T`` /
  ``x.T`` in HBM before each backward kernel);
* ``streamed`` forces every decision's strip to 1, i.e. the pre-v4 WS/IS
  schedules whose partial sums round-trip through HBM.

A quant-columns section reports, per layer, the accuracy-gate calibration
errors for int8/fp8, the CMU's analytic verdict, and the fwd HBM bytes a
quantized weight stream would move vs bf16 (the dispatched train plan stays
full precision — quantized training fwd would shift the grad-check
tolerances).

On CPU the kernels run in Pallas interpret mode, so walltimes are dispatch
sanity checks, not TPU performance; the HBM-bytes columns are the
analytical estimates the CMU ranks with, and ``--verify-traffic`` asserts
they agree with a walk over the exact kernel grids/index maps
(``kernels.flex_matmul.schedule_cost_bytes``) — the CI perf smoke.
``--json`` writes the full record (see BENCH_train_step.json for the
checked-in baseline).

  PYTHONPATH=src python benchmarks/train_step.py [--tokens 256] [--iters 3]
  PYTHONPATH=src python benchmarks/train_step.py --json out.json
  PYTHONPATH=src python benchmarks/train_step.py --dry-run   # CI smoke
  PYTHONPATH=src python benchmarks/train_step.py --verify-traffic
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.kernels  # noqa: F401  — materialises the kernel submodules
from repro.core import (
    NO_TRANS,
    ALL_DATAFLOWS,
    Dataflow,
    GemmShape,
    MeshSpec,
    autotune_plan,
    bwd_gemms,
    hbm_traffic_bytes,
    mesh_local_gemm,
    strip_blocks,
    strip_candidates,
)
from repro.kernels import DEFAULT_BLOCK, flex_linear, linear_ref

fk = sys.modules["repro.kernels.flex_matmul"]


def _bwd_spec(sub, force_copy: bool = False, force_streamed: bool = False):
    if sub is None:
        return None
    trans = NO_TRANS if force_copy else sub.trans
    strip = 1 if force_streamed else sub.strip
    return (sub.dataflow, sub.block, trans, strip)


def build_losses(plan, interpret: bool, force_copy_bwd: bool = False,
                 force_streamed: bool = False):
    """(pallas_loss, ref_loss) over a gated-MLP block: w1 -> gelu -> w2 (+res).

    The pallas loss dispatches every GEMM — forward and, via the custom VJP,
    backward — per the train plan's sub-plans.  ``force_copy_bwd`` overrides
    every backward sub-plan's operand layout to (False, False), i.e. the
    copy-based fallback that materialises the transposed operand in HBM.
    ``force_streamed`` overrides every strip to 1 — the pre-v4 schedules
    whose WS/IS partial sums stream through HBM.
    """
    by_name = {lp.name: lp for lp in plan.layers}

    def pallas_loss(params, x):
        h = x
        for name in ("mlp.w1", "mlp.w2"):
            lp = by_name[name]
            w, b = params[name]
            res = x if name == "mlp.w2" else None
            act = "gelu" if name == "mlp.w1" else None
            h = flex_linear(
                h, w, b, activation=act, residual=res,
                dataflow=lp.dataflow, block=lp.block, interpret=interpret,
                strip=1 if force_streamed else lp.strip,
                bwd_dx=_bwd_spec(lp.bwd_dx, force_copy_bwd, force_streamed),
                bwd_dw=_bwd_spec(lp.bwd_dw, force_copy_bwd, force_streamed),
            )
        return (h * h).mean()

    def ref_loss(params, x):
        h = x
        for name in ("mlp.w1", "mlp.w2"):
            w, b = params[name]
            res = x if name == "mlp.w2" else None
            act = "gelu" if name == "mlp.w1" else None
            h = linear_ref(h, w, b, activation=act, residual=res)
        return (h * h).mean()

    return pallas_loss, ref_loss


def bwd_hbm_bytes(plan) -> dict[str, int]:
    """Analytical HBM bytes of the plan's backward GEMMs, transpose-free vs
    via-copy.  The kernel traffic is identical (same (dataflow, block)
    schedule reads the same blocks, just through swapped index maps); the
    copy path additionally round-trips the transposed operand through HBM —
    one f32 read + one write of W per dX and of X per dW.
    """
    kernel = copy_extra = 0
    for lp in plan.layers:
        g_dx, g_dw = bwd_gemms(lp.gemm)
        # the operand the copy path materialises: W (the B operand, K*N) for
        # dX, X (the A operand, M*K) for dW
        for g, sub, copied in ((g_dx, lp.bwd_dx, g_dx.K * g_dx.N),
                               (g_dw, lp.bwd_dw, g_dw.M * g_dw.K)):
            assert sub is not None, "bwd_hbm_bytes needs a train=True plan"
            blk = sub.block or DEFAULT_BLOCK
            kernel += hbm_traffic_bytes(g, sub.dataflow, *blk, in_bytes=4,
                                        strip=sub.strip).hbm_bytes
            copy_extra += 2 * copied * 4  # f32 read + write of the copy
    return {"bwd_transpose_free": kernel, "bwd_via_copy": kernel + copy_extra}


def strip_hbm_bytes(plan) -> dict[str, int]:
    """Total analytical HBM bytes of every GEMM the plan dispatches (fwd +
    dX + dW), under the plan's strips vs forced strip=1 streaming — the
    partial-sum round-trips the two-level schedules eliminate."""

    def total(forced_streamed: bool) -> int:
        bytes_ = 0
        for lp in plan.layers:
            g_dx, g_dw = bwd_gemms(lp.gemm)
            for g, df, blk, strip in (
                (lp.gemm, lp.dataflow, lp.block, lp.strip),
                (g_dx, lp.bwd_dx.dataflow, lp.bwd_dx.block, lp.bwd_dx.strip),
                (g_dw, lp.bwd_dw.dataflow, lp.bwd_dw.block, lp.bwd_dw.strip),
            ):
                blk = blk or DEFAULT_BLOCK
                bytes_ += hbm_traffic_bytes(
                    g, df, *blk, in_bytes=4,
                    strip=1 if forced_streamed else strip,
                ).hbm_bytes
        return bytes_

    return {"plan_strips": total(False), "forced_streamed": total(True)}


# Training-scale GEMMs where K spans many blocks and no single-block bk
# fits the VMEM budget, so every streamed WS/IS schedule pays (2Kb-1)
# output round-trips.  The dW GEMMs are the canonical case — their
# contraction axis is the token count.
STRIP_SHOWCASE = [
    GemmShape(65_536, 2048, 8192, name="mlp.w1@64k-tokens"),
    GemmShape(2048, 65_536, 8192, name="mlp.w1.dw"),
    GemmShape(8192, 65_536, 2048, name="mlp.w2.dw"),
]


def strip_showcase(shapes: list[GemmShape] = STRIP_SHOWCASE) -> list[dict]:
    """Analytical streamed-vs-strip comparison on strip-feasible shapes.

    Three schedules per GEMM: the best overall (dataflow, block, strip),
    the best *streamed* WS/IS schedule — the pre-v4 kernels, paying
    (2Kb-1) partial-sum round-trips — and the best OS schedule.  The point
    of the strip redesign is visible in the columns: streamed WS/IS lose
    to OS by the partial-sum term alone (an artifact of the grid order),
    while the strip schedules eliminate exactly that term and close the
    gap to the a+b+c traffic floor.  Analytically strips and OS then tie
    (a strip spends its VMEM on depth where OS spends it on block area —
    the same trade), so which stationarity actually runs falls to the
    *measured* pass, the paper's per-layer argument, instead of being
    decided by a schedule artifact.
    """
    from repro.core import VMEM_BUDGET_BYTES
    from repro.core.cmu import _ranked_candidates

    rows = []
    for g in shapes:
        # no quant axis here: candidates are 5-tuples with qdtype = None
        ranked = _ranked_candidates(g, VMEM_BUDGET_BYTES)

        def entry(pred):
            t, df, blk, strip, _qd = next(r for r in ranked if pred(*r))
            cost = hbm_traffic_bytes(g, df, *blk, in_bytes=2, strip=strip)
            kb = -(-g.K // blk[1])
            partials = ((2 * kb - 2) * g.M * g.N * 4
                        if df is not Dataflow.OS and strip == 1 and kb > 1
                        else 0)
            return {"dataflow": df.name, "block": list(blk), "strip": strip,
                    "hbm_bytes": cost.hbm_bytes,
                    "partial_rw_bytes": partials}

        rows.append({
            "gemm": [g.M, g.K, g.N], "name": g.name,
            "best": entry(lambda t, df, blk, s, qd: True),
            "best_streamed_wsis": entry(
                lambda t, df, blk, s, qd: s == 1 and df is not Dataflow.OS),
            "best_os": entry(lambda t, df, blk, s, qd: df is Dataflow.OS),
        })
    return rows


def quant_rows(gemms: list[GemmShape],
               dtypes: tuple[str, ...] = ("int8", "fp8")) -> list[dict]:
    """Quant columns: per layer, the accuracy-gate calibration error of each
    candidate dtype, the CMU's analytic verdict (qdtype — "bf16" means gated
    out or a traffic loss), and fwd HBM bytes at the chosen geometry with
    bf16 operands vs the quantized weight (1 B/element + the f32 per-channel
    scale streamed alongside)."""
    from repro.core import autotune_plan
    from repro.core.cmu import QUANT_ERROR_BUDGET, measure_quant_error

    plan = autotune_plan(gemms, measure=False, quant=dtypes)
    rows = []
    for lp in plan.layers:
        blk = lp.block or DEFAULT_BLOCK
        base = hbm_traffic_bytes(lp.gemm, lp.dataflow, *blk, in_bytes=2,
                                 strip=lp.strip).hbm_bytes
        quant = hbm_traffic_bytes(lp.gemm, lp.dataflow, *blk, strip=lp.strip,
                                  a_bytes=2, b_bytes=1, scale_bytes=4).hbm_bytes
        rows.append({
            "name": lp.name,
            "gemm": [lp.gemm.M, lp.gemm.K, lp.gemm.N],
            "qdtype": lp.qdtype, "qerror": lp.qerror,
            "gate_errors": {qd: measure_quant_error(lp.gemm, qd)
                            for qd in dtypes},
            "budget": QUANT_ERROR_BUDGET,
            "fwd_hbm_bytes": {"bf16": base, "quant": quant},
        })
    return rows


def mesh_rows(plan) -> list[dict]:
    """Mesh-composition columns: per layer, the mesh-level dataflow the plan
    programs, the ICI bytes/chip its collectives put on the wire (mesh cost
    model), and the per-chip HBM bytes of the *local shard* GEMMs under the
    tuned local geometry (fwd + dX + dW; an OS ring runs ``tp`` local
    launches per GEMM, so its per-chip traffic is the per-step cost x tp)."""
    rows = []
    for lp in plan.layers:
        mp = lp.mesh
        if mp is None:
            rows.append({"name": lp.name, "mesh": None})
            continue
        steps = mp.tp if mp.dataflow is Dataflow.OS else 1
        lshape = mesh_local_gemm(lp.gemm, mp.dataflow, mp.tp, mp.dp)
        hbm = 0
        subs = [(lshape, mp.local)]
        if mp.local_dx is not None and mp.local_dw is not None:
            g_dx, g_dw = bwd_gemms(lshape)
            subs += [(g_dx, mp.local_dx), (g_dw, mp.local_dw)]
        for g, sub in subs:
            blk = sub.block or DEFAULT_BLOCK
            hbm += steps * hbm_traffic_bytes(
                g, sub.dataflow, *blk, in_bytes=4, strip=sub.strip
            ).hbm_bytes
        rows.append({
            "name": lp.name,
            "mesh": {
                "dataflow": mp.dataflow.name,
                "tp": mp.tp, "dp": mp.dp,
                "ici_comm_bytes": mp.comm_bytes,
                "local": {"dataflow": mp.local.dataflow.name,
                          "block": list(mp.local.block or DEFAULT_BLOCK),
                          "strip": mp.local.strip,
                          "gemm": [lshape.M, lshape.K, lshape.N]},
                "hbm_bytes_per_chip": hbm,
            },
        })
    return rows


def verify_traffic(shapes: list[GemmShape]) -> int:
    """Assert the strip-aware analytical model agrees with a walk over the
    exact grids/index maps the kernels emit (Pallas revisiting semantics):
    byte-for-byte when every dim spans >= 2 blocks, an upper bound on
    degenerate axes.  Returns the number of (dataflow, block, strip)
    schedules checked.  This is the CI perf-smoke guard that the CMU ranks
    schedules by what the kernels actually do.
    """
    checked = 0
    for g in shapes:
        for df in ALL_DATAFLOWS:
            for blk in [(64, 64, 64), (128, 64, 128)]:
                bm, bk, bn = blk
                # the kernels run on the padded geometry (ops pads to block
                # multiples), so the model is compared on the padded shape —
                # that is the traffic the schedule actually moves
                padded = GemmShape(-(-g.M // bm) * bm, -(-g.K // bk) * bk,
                                   -(-g.N // bn) * bn)
                strips = [1] if df is Dataflow.OS else strip_candidates(
                    strip_blocks(padded, df, bm, bn))
                exact = all(d >= 2 * b for d, b in
                            zip((padded.M, padded.K, padded.N), blk))
                for strip in strips:
                    # (4, 4): both operands f32.  (4, 1): the quantized
                    # schedule — a 1-byte weight streamed against f32
                    # activations; the f32 per-channel scale rides the
                    # epilogue stream and is outside both models by the
                    # same contract as bias/residual (scale_bytes=0 here).
                    for ab, bb in ((4, 4), (4, 1)):
                        walk = fk.schedule_cost_bytes(df, g.M, g.K, g.N, blk,
                                                      strip=strip, in_bytes=4,
                                                      out_bytes=4, a_bytes=ab,
                                                      b_bytes=bb)
                        model = hbm_traffic_bytes(padded, df, bm, bk, bn,
                                                  in_bytes=4, strip=strip,
                                                  a_bytes=ab,
                                                  b_bytes=bb).hbm_bytes
                        if exact:
                            assert walk == model, (
                                g, df, blk, strip, ab, bb, walk, model)
                        else:
                            assert walk <= model, (
                                g, df, blk, strip, ab, bb, walk, model)
                        checked += 1
    return checked


def _timeit(fn, *args) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return time.perf_counter() - t0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--d-ff", type=int, default=512)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the full benchmark record as JSON")
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny shapes, 1 iter, grad-correctness assert (CI smoke)")
    ap.add_argument("--verify-traffic", action="store_true",
                    help="assert the analytical strip model matches the "
                         "kernel schedule walk, then exit (CI perf smoke)")
    ap.add_argument("--mesh", default="",
                    help="'DxM' data x model grid (e.g. 1x8): add mesh-"
                         "composition columns — per-layer mesh dataflow, "
                         "ICI comm bytes/chip from the mesh cost model, and "
                         "per-chip HBM bytes of the local shard GEMMs")
    args = ap.parse_args()
    if args.dry_run:
        args.tokens, args.d_model, args.d_ff, args.iters = 64, 64, 128, 1

    T, D, F = args.tokens, args.d_model, args.d_ff
    gemms = [GemmShape(T, D, F, name="mlp.w1"), GemmShape(T, F, D, name="mlp.w2")]

    if args.verify_traffic:
        shapes = gemms + [g for gm in gemms for g in bwd_gemms(gm)]
        n = verify_traffic(shapes)
        print(f"traffic model OK: analytical bytes match the kernel schedule "
              f"walk on {n} (dataflow, block, strip) schedules")
        return

    mesh_spec = None
    if args.mesh:
        d, m = (int(v) for v in args.mesh.lower().split("x"))
        mesh_spec = MeshSpec(axes=(("data", d), ("model", m)),
                             dp_axes=("data",))
    plan = autotune_plan(gemms, top_k=2, iters=1, train=True, mesh=mesh_spec)

    print(f"{'layer':8} {'gemm (M,K,N)':>18} {'fwd':>7} {'dX':>9} {'dW':>9}")
    for lp in plan.layers:
        g = lp.gemm

        def tag(df, trans, strip):
            t = df.name + ("" if trans == (False, False) else "/T")
            return t + (f"/s{strip}" if strip > 1 else "")

        print(f"{lp.name:8} {f'({g.M},{g.K},{g.N})':>18} "
              f"{tag(lp.dataflow, NO_TRANS, lp.strip):>7} "
              f"{tag(lp.bwd_dx.dataflow, lp.bwd_dx.trans, lp.bwd_dx.strip):>9} "
              f"{tag(lp.bwd_dw.dataflow, lp.bwd_dw.trans, lp.bwd_dw.strip):>9}")

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(T, D)) * 0.1, jnp.float32)
    params = {
        "mlp.w1": (jnp.asarray(rng.normal(size=(D, F)) * 0.05, jnp.float32),
                   jnp.zeros((F,), jnp.float32)),
        "mlp.w2": (jnp.asarray(rng.normal(size=(F, D)) * 0.05, jnp.float32),
                   jnp.zeros((D,), jnp.float32)),
    }

    pallas_loss, ref_loss = build_losses(plan, interpret=True)
    copy_loss, _ = build_losses(plan, interpret=True, force_copy_bwd=True)
    stream_loss, _ = build_losses(plan, interpret=True, force_streamed=True)
    pallas_step = jax.jit(jax.value_and_grad(pallas_loss))
    copy_step = jax.jit(jax.value_and_grad(copy_loss))
    stream_step = jax.jit(jax.value_and_grad(stream_loss))
    ref_step = jax.jit(jax.value_and_grad(ref_loss))

    (lp_, gp), (lr, gr) = pallas_step(params, x), ref_step(params, x)
    (lc, gc) = copy_step(params, x)
    (ls, gs) = stream_step(params, x)
    np.testing.assert_allclose(float(lp_), float(lr), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(lc), float(lr), atol=1e-5, rtol=1e-5)
    # strip schedules change residency, never math: bit-identical to streamed
    np.testing.assert_array_equal(np.asarray(lp_), np.asarray(ls))
    for k in params:
        np.testing.assert_allclose(np.asarray(gp[k][0]), np.asarray(gr[k][0]),
                                   atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(gc[k][0]), np.asarray(gr[k][0]),
                                   atol=2e-4, rtol=2e-4)
        np.testing.assert_array_equal(np.asarray(gp[k][0]), np.asarray(gs[k][0]))
    print("fwd+bwd gradients match the XLA reference (transpose-free and "
          "copy bwd); strip schedules bit-identical to streamed")
    if args.dry_run:
        n = verify_traffic(gemms + [g for gm in gemms for g in bwd_gemms(gm)])
        print(f"traffic model OK ({n} schedules)")

    tp = min(_timeit(pallas_step, params, x) for _ in range(args.iters))
    tc = min(_timeit(copy_step, params, x) for _ in range(args.iters))
    ts = min(_timeit(stream_step, params, x) for _ in range(args.iters))
    tr = min(_timeit(ref_step, params, x) for _ in range(args.iters))
    hbm = bwd_hbm_bytes(plan)
    strips = strip_hbm_bytes(plan)
    print(f"step walltime: pallas {tp*1e3:8.2f} ms ({T/tp:10,.0f} tok/s)   "
          f"streamed {ts*1e3:8.2f} ms   copy-bwd {tc*1e3:8.2f} ms   "
          f"xla {tr*1e3:8.2f} ms ({T/tr:10,.0f} tok/s)")
    print(f"bwd HBM bytes (analytical): transpose-free {hbm['bwd_transpose_free']:,} "
          f"vs via-copy {hbm['bwd_via_copy']:,} "
          f"({hbm['bwd_via_copy'] / hbm['bwd_transpose_free']:.2f}x)")
    print(f"plan HBM bytes (analytical, fwd+dX+dW): strips {strips['plan_strips']:,} "
          f"vs streamed {strips['forced_streamed']:,} "
          f"({strips['forced_streamed'] / strips['plan_strips']:.2f}x)")

    qrows = quant_rows(gemms)
    print("quant columns (accuracy gate + analytical fwd HBM bytes):")
    for row in qrows:
        errs = " ".join(f"{qd}={e:.4f}" for qd, e in row["gate_errors"].items())
        fb = row["fwd_hbm_bytes"]
        print(f"  {row['name']:8} verdict {row['qdtype']:>5} "
              f"(gate {errs}, budget {row['budget']}) "
              f"fwd HBM bf16 {fb['bf16']:>12,} B -> quant {fb['quant']:>12,} B "
              f"({fb['quant'] / fb['bf16']:.2f}x)")

    showcase = strip_showcase()
    print("strip showcase (training-scale shapes, analytical HBM bytes):")
    for row in showcase:
        b = row["best"]
        s = row["best_streamed_wsis"]
        o = row["best_os"]
        print(f"  {row['name']:18} {str(tuple(row['gemm'])):>21} "
              f"best {b['dataflow']}/s{b['strip']} {b['hbm_bytes']:>14,} B | "
              f"streamed {s['dataflow']} {s['hbm_bytes']:>14,} B "
              f"({s['hbm_bytes'] / b['hbm_bytes']:.2f}x, partial rw "
              f"{s['partial_rw_bytes']:,} B) | "
              f"OS {o['hbm_bytes']:>14,} B")

    mrows = None
    if mesh_spec is not None:
        mrows = mesh_rows(plan)
        print(f"mesh composition ({args.mesh} grid, tp={mesh_spec.tp}):")
        for row in mrows:
            mp = row["mesh"]
            if mp is None:
                print(f"  {row['name']:8} (does not divide the mesh — "
                      "single-device fallback)")
                continue
            loc = mp["local"]
            print(f"  {row['name']:8} mesh-{mp['dataflow']:2} local "
                  f"{loc['dataflow']}/{tuple(loc['gemm'])} "
                  f"ICI {mp['ici_comm_bytes']:>12,} B/chip  "
                  f"HBM {mp['hbm_bytes_per_chip']:>12,} B/chip")

    if args.json:
        record = {
            "config": {"tokens": T, "d_model": D, "d_ff": F,
                       "iters": args.iters, "interpret": True,
                       "mesh": args.mesh or None},
            "layers": [
                {
                    "name": lp.name,
                    "gemm": [lp.gemm.M, lp.gemm.K, lp.gemm.N],
                    "fwd": {"dataflow": lp.dataflow.name,
                            "block": list(lp.block) if lp.block else None,
                            "strip": lp.strip},
                    "dx": lp.bwd_dx.to_row(),
                    "dw": lp.bwd_dw.to_row(),
                }
                for lp in plan.layers
            ],
            "walltime_s": {"pallas": tp, "pallas_streamed": ts,
                           "pallas_copy_bwd": tc, "xla": tr},
            "hbm_bytes_est": {**hbm, **strips},
            "quant": qrows,
            "strip_showcase": showcase,
            "mesh_composition": mrows,
        }
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"wrote {args.json}")
    if args.dry_run:
        print("dry-run OK")


if __name__ == "__main__":
    main()
