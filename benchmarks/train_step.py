"""Fwd+bwd microbenchmark: one training step of an MLP block through the
flex kernels' custom VJP vs the XLA reference path.

Per layer the CMU train plan programs THREE (dataflow, block) decisions —
forward, dX = dY @ W^T, dW = X^T @ dY — and this benchmark reports all of
them next to the measured step walltimes.  On CPU the kernels run in Pallas
interpret mode, so the walltime columns are dispatch sanity checks, not TPU
performance; the dataflow columns are the paper's point (the backward GEMMs
transpose the forward aspect ratio and land on different stationarity).

  PYTHONPATH=src python benchmarks/train_step.py [--tokens 256] [--iters 3]
  PYTHONPATH=src python benchmarks/train_step.py --dry-run   # CI smoke
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import GemmShape, autotune_plan
from repro.kernels import flex_linear, linear_ref


def _bwd_spec(sub):
    return None if sub is None else (sub.dataflow, sub.block)


def build_losses(plan, interpret: bool):
    """(pallas_loss, ref_loss) over a gated-MLP block: w1 -> gelu -> w2 (+res).

    The pallas loss dispatches every GEMM — forward and, via the custom VJP,
    backward — per the train plan's sub-plans.
    """
    by_name = {lp.name: lp for lp in plan.layers}

    def pallas_loss(params, x):
        h = x
        for name in ("mlp.w1", "mlp.w2"):
            lp = by_name[name]
            w, b = params[name]
            res = x if name == "mlp.w2" else None
            act = "gelu" if name == "mlp.w1" else None
            h = flex_linear(
                h, w, b, activation=act, residual=res,
                dataflow=lp.dataflow, block=lp.block, interpret=interpret,
                bwd_dx=_bwd_spec(lp.bwd_dx), bwd_dw=_bwd_spec(lp.bwd_dw),
            )
        return (h * h).mean()

    def ref_loss(params, x):
        h = x
        for name in ("mlp.w1", "mlp.w2"):
            w, b = params[name]
            res = x if name == "mlp.w2" else None
            act = "gelu" if name == "mlp.w1" else None
            h = linear_ref(h, w, b, activation=act, residual=res)
        return (h * h).mean()

    return pallas_loss, ref_loss


def _timeit(fn, *args) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return time.perf_counter() - t0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--d-ff", type=int, default=512)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny shapes, 1 iter, grad-correctness assert (CI smoke)")
    args = ap.parse_args()
    if args.dry_run:
        args.tokens, args.d_model, args.d_ff, args.iters = 64, 64, 128, 1

    T, D, F = args.tokens, args.d_model, args.d_ff
    gemms = [GemmShape(T, D, F, name="mlp.w1"), GemmShape(T, F, D, name="mlp.w2")]
    plan = autotune_plan(gemms, top_k=2, iters=1, train=True)

    print(f"{'layer':8} {'gemm (M,K,N)':>18} {'fwd':>4} {'dX':>4} {'dW':>4}")
    for lp in plan.layers:
        g = lp.gemm
        print(f"{lp.name:8} {f'({g.M},{g.K},{g.N})':>18} "
              f"{lp.dataflow.name:>4} {lp.bwd_dx.dataflow.name:>4} "
              f"{lp.bwd_dw.dataflow.name:>4}")

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(T, D)) * 0.1, jnp.float32)
    params = {
        "mlp.w1": (jnp.asarray(rng.normal(size=(D, F)) * 0.05, jnp.float32),
                   jnp.zeros((F,), jnp.float32)),
        "mlp.w2": (jnp.asarray(rng.normal(size=(F, D)) * 0.05, jnp.float32),
                   jnp.zeros((D,), jnp.float32)),
    }

    pallas_loss, ref_loss = build_losses(plan, interpret=True)
    pallas_step = jax.jit(jax.value_and_grad(pallas_loss))
    ref_step = jax.jit(jax.value_and_grad(ref_loss))

    (lp_, gp), (lr, gr) = pallas_step(params, x), ref_step(params, x)
    np.testing.assert_allclose(float(lp_), float(lr), atol=1e-5, rtol=1e-5)
    for k in params:
        np.testing.assert_allclose(np.asarray(gp[k][0]), np.asarray(gr[k][0]),
                                   atol=2e-4, rtol=2e-4)
    print("fwd+bwd gradients match the XLA reference")

    tp = min(_timeit(pallas_step, params, x) for _ in range(args.iters))
    tr = min(_timeit(ref_step, params, x) for _ in range(args.iters))
    print(f"step walltime: pallas {tp*1e3:8.2f} ms ({T/tp:10,.0f} tok/s)   "
          f"xla {tr*1e3:8.2f} ms ({T/tr:10,.0f} tok/s)")
    if args.dry_run:
        print("dry-run OK")


if __name__ == "__main__":
    main()
