"""Serve a model with batched requests: prefill + greedy/temperature decode.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch gemma3_12b]
"""
import argparse
import subprocess
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gemma3_12b")
ap.add_argument("--requests", default="8")
ap.add_argument("--gen", default="16")
args = ap.parse_args()

cmd = [sys.executable, "-m", "repro.launch.serve", "--arch", args.arch, "--smoke",
       "--requests", args.requests, "--gen", args.gen]
print("+", " ".join(cmd))
raise SystemExit(subprocess.call(cmd))
