"""Quickstart: the Flex-TPU reproduction in one minute.

Simulates ResNet-18 on a 32x32 systolic array under all three static
dataflows and the Flex (per-layer CMU) schedule, prints Table-I-style
numbers, then runs the three Pallas dataflow kernels on CPU (interpret).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import ALL_DATAFLOWS, WORKLOADS, overheads, simulate_network
from repro.kernels import flex_matmul, matmul_ref

# 1. the paper's experiment: per-layer dataflow choice beats any static one
r = simulate_network("resnet18", WORKLOADS["resnet18"], 32)
print("ResNet-18 @ 32x32 systolic array")
for df in ALL_DATAFLOWS:
    print(f"  static {df.name}: {r.static_cycles(df):>9,} cycles "
          f"(flex speedup {r.speedup(df):.3f}x)")
print(f"  FLEX       : {r.flex_cycles:>9,} cycles")
print(f"  per-layer schedule: {[d.name for d in r.flex_schedule]}")

# 2. the hardware cost of flexibility (Table II)
o = overheads(32)
print(f"\nFlex-TPU overhead @32x32: area +{o.area_pct:.1f}%  "
      f"power +{o.power_pct:.1f}%  critical path +{o.delay_pct:.2f}%")

# 3. the same idea on a real TPU: three Pallas kernels, one MAC, three
#    block schedules (validated in interpret mode on CPU)
a = jnp.asarray(np.random.default_rng(0).normal(size=(256, 256)), jnp.float32)
b = jnp.asarray(np.random.default_rng(1).normal(size=(256, 256)), jnp.float32)
ref = matmul_ref(a, b)
for df in ALL_DATAFLOWS:
    out = flex_matmul(a, b, dataflow=df, block=(128, 128, 128), interpret=True)
    print(f"pallas {df.name}: max|err| = {float(jnp.abs(out-ref).max()):.2e}")
