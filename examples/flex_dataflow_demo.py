"""The CMU end-to-end: per-layer dataflow planning for a real LM architecture.

Shows (a) the offline plan for qwen3-4b's GEMMs at train vs decode token
counts, (b) the HBM traffic saved vs any static dataflow, and (c) the
mesh-level stationarity choice (DESIGN.md §2.2).

Run:  PYTHONPATH=src python examples/flex_dataflow_demo.py
"""
from benchmarks.kernel_dataflow import arch_gemms
from repro.core import ALL_DATAFLOWS, plan_kernels_tuned, plan_mesh, static_vs_flex_traffic

for tokens, tag in [(1_048_576, "train_4k (1M tokens)"), (128, "decode (128 tokens)")]:
    gemms = arch_gemms("qwen3_4b", tokens)
    rows = plan_kernels_tuned(gemms)
    print(f"\n=== qwen3_4b, {tag} ===")
    print(f"{'layer':10s} {'M':>9s} {'K':>6s} {'N':>7s}  dataflow  block")
    for g, df, blk, t in rows:
        print(f"{g.name:10s} {g.M:>9d} {g.K:>6d} {g.N:>7d}  {df.name:8s} {blk}")
    tot = static_vs_flex_traffic(gemms)
    best = min(tot[d.name] for d in ALL_DATAFLOWS)
    print(f"HBM traffic: flex {tot['FLEX']/1e9:.2f} GB vs best-static {best/1e9:.2f} GB "
          f"vs worst-static {max(tot[d.name] for d in ALL_DATAFLOWS)/1e9:.2f} GB")
    mesh_plan = plan_mesh(gemms, tp=16)
    print(f"mesh-level stationarity (16-way): { {k: v.name for k, v in list(mesh_plan.items())[:4]} }")
