"""Train a language model end-to-end with the full framework stack
(data pipeline -> model -> AdamW -> checkpointing -> fault-tolerant loop).

Default is a CPU-sized run; `--preset 100m` trains a ~100M-param qwen3-style
model for a few hundred steps (sized for a TPU host; takes hours on 1 CPU).

Run:  PYTHONPATH=src python examples/train_lm.py [--preset 100m] [--steps N]
"""
import argparse
import subprocess
import sys

PRESETS = {
    "tiny": ["--arch", "qwen3_4b", "--smoke", "--steps", "60",
             "--global-batch", "8", "--seq", "64", "--lr", "1e-3"],
    "20m": ["--arch", "qwen3_4b", "--smoke", "--d-model", "256", "--layers", "4",
            "--steps", "200", "--global-batch", "8", "--seq", "128", "--lr", "6e-4"],
    "100m": ["--arch", "qwen3_4b", "--smoke", "--d-model", "640", "--layers", "10",
             "--steps", "300", "--global-batch", "16", "--seq", "256", "--lr", "4e-4"],
}

ap = argparse.ArgumentParser()
ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
ap.add_argument("--steps", default=None)
ap.add_argument("--fail-at", default=None, help="inject a node failure at step N")
args = ap.parse_args()

cmd = [sys.executable, "-m", "repro.launch.train"] + PRESETS[args.preset]
if args.steps:
    cmd += ["--steps", args.steps]
if args.fail_at:
    cmd += ["--fail-at", args.fail_at]
print("+", " ".join(cmd))
raise SystemExit(subprocess.call(cmd))
