"""Chunked linear-attention (Mamba2 / RWKV-6) vs exact sequential recurrence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.models import ssm as S

RNG = np.random.default_rng(7)

def _seq_ref(r, k, v, lw, post, u=None):
    B, T, H, N = r.shape
    M = v.shape[-1]
    St = jnp.zeros((B, H, N, M))
    outs = []
    for t in range(T):
        o, St = S.recurrent_step(
            r[:, t], k[:, t], v[:, t], lw[:, t], St, diag_scale=u, post_update=post
        )
        outs.append(o)
    return jnp.stack(outs, 1), St

def _inputs(B, T, H, N, M, seed=0):
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.normal(size=(B, T, H, N)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, N)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, M)), jnp.float32)
    lw = jnp.clip(
        jnp.asarray(-np.abs(rng.normal(size=(B, T, H, N))), jnp.float32),
        S.LOG_DECAY_MIN, -1e-6,
    )
    return r, k, v, lw

@pytest.mark.parametrize("post", [True, False])
@pytest.mark.parametrize("T", [16, 32, 48])
def test_chunked_equals_recurrent(post, T):
    B, H, N, M = 2, 3, 8, 16
    r, k, v, lw = _inputs(B, T, H, N, M)
    u = jnp.asarray(RNG.normal(size=(H, N)), jnp.float32) if not post else None
    o_c, S_c = S.chunked_diag_linear_attn(r, k, v, lw, u, post_update=post)
    o_r, S_r = _seq_ref(r, k, v, lw, post, u)
    np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_r), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(S_c), np.asarray(S_r), atol=2e-4, rtol=2e-4)

@given(seed=st.integers(0, 10_000), post=st.booleans())
@settings(max_examples=20, deadline=None)
def test_chunked_equals_recurrent_property(seed, post):
    B, T, H, N, M = 1, 32, 2, 4, 8
    r, k, v, lw = _inputs(B, T, H, N, M, seed)
    o_c, S_c = S.chunked_diag_linear_attn(r, k, v, lw, None, post_update=post)
    o_r, S_r = _seq_ref(r, k, v, lw, post, None)
    np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_r), atol=3e-4, rtol=3e-4)

def test_state_carried_across_calls():
    """Splitting a sequence across two chunked calls == one call (streaming)."""
    B, T, H, N, M = 1, 64, 2, 4, 8
    r, k, v, lw = _inputs(B, T, H, N, M, 3)
    o_full, S_full = S.chunked_diag_linear_attn(r, k, v, lw, post_update=True)
    h = T // 2
    o1, S1 = S.chunked_diag_linear_attn(
        r[:, :h], k[:, :h], v[:, :h], lw[:, :h], post_update=True
    )
    o2, S2 = S.chunked_diag_linear_attn(
        r[:, h:], k[:, h:], v[:, h:], lw[:, h:], state0=S1, post_update=True
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([o1, o2], 1)), np.asarray(o_full), atol=2e-4, rtol=2e-4
    )
    np.testing.assert_allclose(np.asarray(S2), np.asarray(S_full), atol=2e-4, rtol=2e-4)

def test_numerical_safety_extreme_decay():
    """All exponents stay bounded at the decay floor — no inf/nan."""
    B, T, H, N, M = 1, 64, 1, 4, 4
    r, k, v, _ = _inputs(B, T, H, N, M, 5)
    lw = jnp.full((B, T, H, N), S.LOG_DECAY_MIN)
    o, St = S.chunked_diag_linear_attn(r, k, v, lw, post_update=True)
    assert bool(jnp.all(jnp.isfinite(o))) and bool(jnp.all(jnp.isfinite(St)))

def test_causal_conv_state_streaming():
    from repro.models.ssm import _causal_conv1d

    B, T, C, Kw = 2, 10, 6, 4
    x = jnp.asarray(RNG.normal(size=(B, T, C)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(Kw, C)), jnp.float32)
    b = jnp.zeros((C,))
    y_full, st_full = _causal_conv1d(x, w, b)
    # stream one token at a time
    st = jnp.zeros((B, Kw - 1, C))
    ys = []
    for t in range(T):
        y, st = _causal_conv1d(x[:, t : t + 1], w, b, state=st)
        ys.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, 1)), np.asarray(y_full), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_full), atol=1e-6)

def test_mamba2_block_shapes_and_decode():
    from repro.models.config import ModelConfig
    from repro.models.ssm import init_mamba2, init_mamba_state, mamba2

    cfg = ModelConfig(d_model=32, ssm_state=8, ssm_head_dim=8, num_heads=2, num_kv_heads=2)
    p = init_mamba2(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(RNG.normal(size=(2, 24, 32)), jnp.float32)
    y, _ = mamba2(cfg, p, x)
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))
    st = init_mamba_state(cfg, 2)
    y1, st = mamba2(cfg, p, x[:, :1], state=st)
    assert y1.shape == (2, 1, 32)


# ---------------------------------------------------------------------------
# prefill -> decode handoff: the state return_state captures is the state
# a decode stream actually needs (the contract the deleted duplicate-compute
# paths used to re-derive by running every layer twice)
# ---------------------------------------------------------------------------


@given(
    T=st.sampled_from([7, 16, 19, 32, 45]),  # ragged + aligned pad paths
    post=st.booleans(),
    dtype_name=st.sampled_from(["float32", "bfloat16"]),
)
@settings(max_examples=10, deadline=None)
def test_prefill_state_feeds_decode_exactly(T, post, dtype_name):
    """Chunked-prefill final state handed to ``recurrent_step`` continues
    the sequence identically to a full sequential decode — both
    conventions, ragged T (exercising the zero-pad path), both dtypes."""
    dtype = jnp.dtype(dtype_name)
    B, H, N, M = 1, 2, 4, 8
    extra = 4
    r, k, v, lw = _inputs(B, T + extra, H, N, M, seed=T)
    r, k, v = (a.astype(dtype).astype(jnp.float32) for a in (r, k, v))
    u = jnp.asarray(RNG.normal(size=(H, N)), jnp.float32) if not post else None
    # chunked prefill over the ragged prefix (pads internally to LA_CHUNK)
    pad = (-T) % S.LA_CHUNK
    rp, kp, vp, lwp = (S._pad_chunks(a[:, :T], pad) for a in (r, k, v, lw))
    _, St = S.chunked_diag_linear_attn(rp, kp, vp, lwp, u, post_update=post)
    # ... then decode the suffix from that state
    outs = []
    for t in range(T, T + extra):
        o, St = S.recurrent_step(r[:, t], k[:, t], v[:, t], lw[:, t], St,
                                 diag_scale=u, post_update=post)
        outs.append(o)
    # oracle: sequential decode of the whole sequence
    o_ref, S_ref = _seq_ref(r, k, v, lw, post, u)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1)), np.asarray(o_ref[:, T:]),
        atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(St), np.asarray(S_ref),
                               atol=3e-4, rtol=3e-4)


@given(T=st.sampled_from([5, 12, 16, 23]), post=st.booleans())
@settings(max_examples=8, deadline=None)
def test_pad_invariance_of_chunked_scan(T, post):
    """Output and final state are bitwise invariant to ``T % LA_CHUNK``:
    padding with zero rows (r = k = v = 0, log_w = 0) is an exact no-op.
    This is the property that made the historical ``where(lw == 0, -1e-6)``
    guard dead — and what lets the planner choose arbitrary chunks."""
    B, H, N, M = 1, 2, 4, 8
    r, k, v, lw = _inputs(B, T, H, N, M, seed=T * 7)
    pad = (-T) % S.LA_CHUNK
    a = [S._pad_chunks(x, pad) for x in (r, k, v, lw)]
    b = [S._pad_chunks(x, pad + 2 * S.LA_CHUNK) for x in (r, k, v, lw)]
    o_a, S_a = S.chunked_diag_linear_attn(*a, post_update=post)
    o_b, S_b = S.chunked_diag_linear_attn(*b, post_update=post)
    assert np.asarray(o_a[:, :T]).tobytes() == np.asarray(o_b[:, :T]).tobytes()
    assert np.asarray(S_a).tobytes() == np.asarray(S_b).tobytes(), \
        "final state depends on the pad amount"


def test_mamba2_return_state_matches_streaming_decode():
    """The state ``return_state=True`` captures during a chunked prefill is
    the state a token-by-token decode of the same prefix arrives at — the
    contract the deleted ``_mamba_final_state`` re-computed every layer to
    satisfy."""
    from repro.models.config import ModelConfig
    from repro.models.ssm import init_mamba2, init_mamba_state, mamba2

    cfg = ModelConfig(d_model=32, ssm_state=8, ssm_head_dim=8, num_heads=2,
                      num_kv_heads=2)
    p = init_mamba2(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(RNG.normal(size=(2, 24, 32)), jnp.float32)
    _, st_prefill = mamba2(cfg, p, x, return_state=True)
    st = init_mamba_state(cfg, 2)
    for t in range(x.shape[1]):
        _, st = mamba2(cfg, p, x[:, t : t + 1], state=st)
    np.testing.assert_allclose(np.asarray(st_prefill["ssm"]),
                               np.asarray(st["ssm"]), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st_prefill["conv"]),
                               np.asarray(st["conv"]), atol=1e-5, rtol=1e-5)


def _count_scan_cumsums(jaxpr):
    """Multi-dim cumsum ops anywhere in the jaxpr — the chunked scan's
    signature op (the 1-D bookkeeping cumsum in hybrid prefill is excluded
    by the ndim bar)."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "cumsum" and eqn.invars[0].aval.ndim >= 2:
            n += 1
        for val in eqn.params.values():
            vals = val if isinstance(val, (list, tuple)) else [val]
            for sub in vals:
                if isinstance(sub, jax.core.ClosedJaxpr):
                    n += _count_scan_cumsums(sub.jaxpr)
                elif isinstance(sub, jax.core.Jaxpr):
                    n += _count_scan_cumsums(sub)
    return n


@pytest.mark.parametrize("arch", ["zamba2_7b", "rwkv6_7b"])
def test_prefill_runs_one_chunked_scan_per_layer(arch):
    """Op-count regression for the prefill double-compute bug: prefill must
    trace exactly as many chunked scans as the forward pass (one per mixer
    body).  The old ``_mamba_final_state`` / inlined-rwkv paths re-ran
    every mixer a second time just to recover its final state."""
    from repro.models import get_config
    from repro.models.transformer import Model

    cfg = get_config(arch, smoke=True)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                      cfg.vocab_size)}
    n_fwd = _count_scan_cumsums(
        jax.make_jaxpr(lambda p, bb: m.forward(p, bb))(params, b).jaxpr)
    n_pre = _count_scan_cumsums(
        jax.make_jaxpr(lambda p, bb: m.prefill(p, bb, cache_len=16))(
            params, b).jaxpr)
    assert n_fwd >= 1  # detector sanity: the scan is visible
    assert n_pre == n_fwd, \
        f"prefill traces {n_pre} chunked scans but forward traces {n_fwd}"


@pytest.mark.parametrize("arch", ["zamba2_7b", "rwkv6_7b"])
def test_prefill_logits_bitwise_match_forward(arch):
    """Prefill runs the exact block-forward op sequence (plus state
    capture), so its last-position logits equal the forward pass *bitwise*
    — the pin that keeps the prefill paths from drifting back into
    hand-inlined near-copies."""
    from repro.models import get_config
    from repro.models.transformer import Model

    cfg = get_config(arch, smoke=True)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                                      cfg.vocab_size)}
    full, _ = m.forward(params, b)
    _, last = m.prefill(params, b, cache_len=16)
    assert np.asarray(last).tobytes() == np.asarray(full[:, -1]).tobytes()


def test_rwkv_groupnorm_eps_derivation():
    """The group-norm eps derives from the head size (upstream RWKV's
    ``1e-5 * head_size_divisor**2``): 64e-5 at the stock 64, and it scales
    linearly — no more magic constant hardcoded at two call sites."""
    from repro.models.config import ModelConfig

    assert S.rwkv_groupnorm_eps(
        ModelConfig(d_model=64, rwkv_head_size=64)) == pytest.approx(64e-5)
    assert S.rwkv_groupnorm_eps(
        ModelConfig(d_model=64, rwkv_head_size=16)) == pytest.approx(16e-5)
