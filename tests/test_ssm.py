"""Chunked linear-attention (Mamba2 / RWKV-6) vs exact sequential recurrence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.models import ssm as S

RNG = np.random.default_rng(7)

def _seq_ref(r, k, v, lw, post, u=None):
    B, T, H, N = r.shape
    M = v.shape[-1]
    St = jnp.zeros((B, H, N, M))
    outs = []
    for t in range(T):
        o, St = S.recurrent_step(
            r[:, t], k[:, t], v[:, t], lw[:, t], St, diag_scale=u, post_update=post
        )
        outs.append(o)
    return jnp.stack(outs, 1), St

def _inputs(B, T, H, N, M, seed=0):
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.normal(size=(B, T, H, N)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, N)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, M)), jnp.float32)
    lw = jnp.clip(
        jnp.asarray(-np.abs(rng.normal(size=(B, T, H, N))), jnp.float32),
        S.LOG_DECAY_MIN, -1e-6,
    )
    return r, k, v, lw

@pytest.mark.parametrize("post", [True, False])
@pytest.mark.parametrize("T", [16, 32, 48])
def test_chunked_equals_recurrent(post, T):
    B, H, N, M = 2, 3, 8, 16
    r, k, v, lw = _inputs(B, T, H, N, M)
    u = jnp.asarray(RNG.normal(size=(H, N)), jnp.float32) if not post else None
    o_c, S_c = S.chunked_diag_linear_attn(r, k, v, lw, u, post_update=post)
    o_r, S_r = _seq_ref(r, k, v, lw, post, u)
    np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_r), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(S_c), np.asarray(S_r), atol=2e-4, rtol=2e-4)

@given(seed=st.integers(0, 10_000), post=st.booleans())
@settings(max_examples=20, deadline=None)
def test_chunked_equals_recurrent_property(seed, post):
    B, T, H, N, M = 1, 32, 2, 4, 8
    r, k, v, lw = _inputs(B, T, H, N, M, seed)
    o_c, S_c = S.chunked_diag_linear_attn(r, k, v, lw, None, post_update=post)
    o_r, S_r = _seq_ref(r, k, v, lw, post, None)
    np.testing.assert_allclose(np.asarray(o_c), np.asarray(o_r), atol=3e-4, rtol=3e-4)

def test_state_carried_across_calls():
    """Splitting a sequence across two chunked calls == one call (streaming)."""
    B, T, H, N, M = 1, 64, 2, 4, 8
    r, k, v, lw = _inputs(B, T, H, N, M, 3)
    o_full, S_full = S.chunked_diag_linear_attn(r, k, v, lw, post_update=True)
    h = T // 2
    o1, S1 = S.chunked_diag_linear_attn(
        r[:, :h], k[:, :h], v[:, :h], lw[:, :h], post_update=True
    )
    o2, S2 = S.chunked_diag_linear_attn(
        r[:, h:], k[:, h:], v[:, h:], lw[:, h:], state0=S1, post_update=True
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([o1, o2], 1)), np.asarray(o_full), atol=2e-4, rtol=2e-4
    )
    np.testing.assert_allclose(np.asarray(S2), np.asarray(S_full), atol=2e-4, rtol=2e-4)

def test_numerical_safety_extreme_decay():
    """All exponents stay bounded at the decay floor — no inf/nan."""
    B, T, H, N, M = 1, 64, 1, 4, 4
    r, k, v, _ = _inputs(B, T, H, N, M, 5)
    lw = jnp.full((B, T, H, N), S.LOG_DECAY_MIN)
    o, St = S.chunked_diag_linear_attn(r, k, v, lw, post_update=True)
    assert bool(jnp.all(jnp.isfinite(o))) and bool(jnp.all(jnp.isfinite(St)))

def test_causal_conv_state_streaming():
    from repro.models.ssm import _causal_conv1d

    B, T, C, Kw = 2, 10, 6, 4
    x = jnp.asarray(RNG.normal(size=(B, T, C)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(Kw, C)), jnp.float32)
    b = jnp.zeros((C,))
    y_full, st_full = _causal_conv1d(x, w, b)
    # stream one token at a time
    st = jnp.zeros((B, Kw - 1, C))
    ys = []
    for t in range(T):
        y, st = _causal_conv1d(x[:, t : t + 1], w, b, state=st)
        ys.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, 1)), np.asarray(y_full), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_full), atol=1e-6)

def test_mamba2_block_shapes_and_decode():
    from repro.models.config import ModelConfig
    from repro.models.ssm import init_mamba2, init_mamba_state, mamba2

    cfg = ModelConfig(d_model=32, ssm_state=8, ssm_head_dim=8, num_heads=2, num_kv_heads=2)
    p = init_mamba2(cfg, jax.random.PRNGKey(0))
    x = jnp.asarray(RNG.normal(size=(2, 24, 32)), jnp.float32)
    y, _ = mamba2(cfg, p, x)
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))
    st = init_mamba_state(cfg, 2)
    y1, st = mamba2(cfg, p, x[:, :1], state=st)
    assert y1.shape == (2, 1, 32)
