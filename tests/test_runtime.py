"""Fault tolerance, stragglers, gradient compression, elastic restore."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (
    RunnerConfig,
    ShardAssignment,
    SimulatedNodeFailure,
    StragglerConfig,
    StragglerTracker,
    TrainRunner,
    compression_ratio,
    dequantize_int8,
    quantize_int8,
)
from repro.runtime.compression import compress_residual


def _toy_runner(d, failure_hook=None, max_steps=20, ckpt_every=5):
    """state = (x, step_counter); step adds the (deterministic) step index."""

    def init():
        return {"x": jnp.zeros((4,)), "seen": jnp.zeros((), jnp.int32)}

    def step(state, i):
        return (
            {"x": state["x"] + i, "seen": state["seen"] + 1},
            {"loss": float(i)},
        )

    return TrainRunner(
        step, init,
        RunnerConfig(ckpt_dir=d, ckpt_every=ckpt_every, max_steps=max_steps),
        failure_hook=failure_hook,
    )


def test_runner_completes_without_failure():
    with tempfile.TemporaryDirectory() as d:
        state, step = _toy_runner(d).run()
        assert step == 20
        assert float(state["x"][0]) == sum(range(20))


def test_runner_recovers_identically_after_failure():
    """A crash at step 13 must produce bit-identical final state (replay from
    the step-10 checkpoint, deterministic data)."""
    with tempfile.TemporaryDirectory() as d1:
        ref, _ = _toy_runner(d1).run()
    fired = []

    def bomb(step):
        if step == 13 and not fired:
            fired.append(1)
            raise SimulatedNodeFailure("chip 42 went away")

    with tempfile.TemporaryDirectory() as d2:
        r = _toy_runner(d2, failure_hook=bomb)
        state, step = r.run()
        assert r.restarts == 1 and step == 20
        np.testing.assert_array_equal(np.asarray(state["x"]), np.asarray(ref["x"]))


def test_runner_restart_budget():
    def always(step):
        raise SimulatedNodeFailure("flaky host")

    with tempfile.TemporaryDirectory() as d:
        r = _toy_runner(d, failure_hook=always)
        r.cfg.max_restarts = 3
        with pytest.raises(RuntimeError, match="restart budget"):
            r.run()


def test_runner_resumes_from_latest_checkpoint_only():
    fired = []

    def bomb(step):
        if step == 17 and not fired:
            fired.append(1)
            raise SimulatedNodeFailure("preempted")

    with tempfile.TemporaryDirectory() as d:
        r = _toy_runner(d, failure_hook=bomb)
        state, _ = r.run()
        # steps 15..16 replayed exactly once in final state
        assert float(state["x"][0]) == sum(range(20))


def test_runner_recoverable_exception_types():
    """The restart loop recovers only from the types named in
    ``cfg.recoverable`` — a production config widens it past the injected
    test failure; a programming error still propagates."""

    class DeviceLost(RuntimeError):
        pass

    fired = []

    def bomb(step):
        if step == 7 and not fired:
            fired.append(1)
            raise DeviceLost("XLA device disappeared")

    with tempfile.TemporaryDirectory() as d:
        r = _toy_runner(d, failure_hook=bomb)
        r.cfg.recoverable = (SimulatedNodeFailure, DeviceLost)
        state, step = r.run()
        assert r.restarts == 1 and step == 20
        assert float(state["x"][0]) == sum(range(20))

    fired.clear()
    with tempfile.TemporaryDirectory() as d:
        r = _toy_runner(d, failure_hook=bomb)  # default: only the injected type
        with pytest.raises(DeviceLost):
            r.run()


def test_runner_metrics_log_has_no_duplicate_steps():
    """A crash between checkpoints replays committed steps; the metrics log
    must read as one consistent history — each step exactly once."""
    fired = []

    def bomb(step):
        if step == 13 and not fired:
            fired.append(1)
            raise SimulatedNodeFailure("preempted")

    with tempfile.TemporaryDirectory() as d:
        r = _toy_runner(d, failure_hook=bomb)
        r.run()
        steps = [m["step"] for m in r.metrics_log]
        assert steps == list(range(1, 21)), "replayed steps appear once"


# ---- stragglers ------------------------------------------------------------


def test_straggler_detection_and_reassignment():
    t = StragglerTracker(8, StragglerConfig(threshold=1.5, patience=3))
    flagged = []
    for _ in range(5):
        times = np.ones(8)
        times[2] = 4.0  # persistent straggler
        flagged = t.observe(times)
    assert flagged == [2]
    sa = ShardAssignment(16, 8)
    before = dict(sa.assignment)
    after = sa.reassign(flagged)
    assert all(h != 2 for h in after.values())
    assert any(before[s] == 2 for s in before)


def test_straggler_transient_spike_not_flagged():
    t = StragglerTracker(4, StragglerConfig(patience=4))
    t.observe(np.array([1.0, 1, 1, 5.0]))
    flagged = []
    for _ in range(3):
        flagged = t.observe(np.ones(4))
    assert flagged == []  # EWMA decays before patience runs out
    assert t.p99_step_time() > 1.0


def test_straggler_zero_step_time_host_is_tracked():
    """A host reporting a 0.0 step time is a legitimate observation, not an
    'unseeded' sentinel: subsequent observations must blend into its EWMA
    instead of re-seeding it forever."""
    t = StragglerTracker(4, StragglerConfig(ewma=0.5))
    t.observe(np.array([0.0, 1.0, 1.0, 1.0]))  # host 0: instant heartbeat
    assert t.ewma_times[0] == 0.0
    t.observe(np.array([10.0, 1.0, 1.0, 1.0]))
    # 0.5 * 10 + 0.5 * 0 — a re-seed would have produced 10.0
    assert t.ewma_times[0] == pytest.approx(5.0)
    # and the slow host is eventually flagged like any other
    t2 = StragglerTracker(4, StragglerConfig(patience=2, ewma=0.5))
    t2.observe(np.zeros(4))
    flagged = []
    for _ in range(4):
        flagged = t2.observe(np.array([4.0, 1.0, 1.0, 1.0]))
    assert flagged == [0]


# ---- gradient compression ---------------------------------------------------


def test_int8_compression_roundtrip_bound():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(3, 1000)), jnp.float32)
    q, s, meta = quantize_int8(g)
    rec = dequantize_int8(q, s, meta)
    assert float(jnp.abs(rec - g).max()) <= float(s.max()) * 0.51
    assert compression_ratio(g) > 3.0


def test_error_feedback_telescopes():
    """With error feedback, the *cumulative* transmitted signal tracks the
    cumulative gradient (residual stays bounded)."""
    rng = np.random.default_rng(1)
    res = None
    total_g = np.zeros(512, np.float32)
    total_tx = np.zeros(512, np.float32)
    for _ in range(50):
        g = jnp.asarray(rng.normal(size=512), jnp.float32) * 0.01
        q, s, meta, res = compress_residual(g, res)
        total_g += np.asarray(g)
        total_tx += np.asarray(dequantize_int8(q, s, meta))
    # residual = total_g - total_tx exactly (telescoping)
    np.testing.assert_allclose(total_g - total_tx, np.asarray(res), atol=1e-5)
    assert np.abs(np.asarray(res)).max() < 0.01  # bounded by one quant step


def test_compressed_psum_single_device():
    """Semantics on an axis of size 1 (multi-device exercised in
    test_distributed.py subprocesses)."""

    mesh = jax.make_mesh((1,), ("x",))
    g = jnp.asarray(np.random.default_rng(2).normal(size=(64,)), jnp.float32)

    from repro.launch.mesh import shard_map
    from repro.runtime import compressed_psum

    def f(g):
        out, res = compressed_psum(g, "x")
        return out, res

    out, res = jax.jit(
        shard_map(f, mesh=mesh, in_specs=(jax.sharding.PartitionSpec("x"),),
                  out_specs=(jax.sharding.PartitionSpec("x"),) * 2)
    )(g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=0.02)
