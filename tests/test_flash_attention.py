"""Pallas flash-attention kernel vs the jnp oracle (interpret=True)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.kernels import attention_ref, mha_flash

RNG = np.random.default_rng(3)

def _qkv(B, S, H, Hkv, hd, dtype=jnp.float32, skv=None):
    skv = skv or S
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, skv, Hkv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, skv, Hkv, hd)), dtype)
    return q, k, v

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(2, 256, 4, 2, 64), (1, 128, 8, 8, 128), (2, 384, 6, 1, 128)])
def test_flash_matches_oracle(shape, causal):
    B, S, H, Hkv, hd = shape
    q, k, v = _qkv(B, S, H, Hkv, hd)
    out = mha_flash(q, k, v, causal=causal, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)

def test_flash_bf16():
    q, k, v = _qkv(2, 256, 4, 2, 64, jnp.bfloat16)
    out = mha_flash(q, k, v, interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=0.05, rtol=0.05
    )

def test_flash_cross_attention_longer_kv():
    q, k, v = _qkv(1, 128, 4, 4, 64, skv=384)
    out = mha_flash(q, k, v, causal=False, interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)

@given(
    bq=st.sampled_from([64, 128]),
    bk=st.sampled_from([64, 128]),
    causal=st.booleans(),
)
@settings(max_examples=8, deadline=None)
def test_flash_block_shape_invariance(bq, bk, causal):
    """The OS dataflow guarantee: block shape changes traffic, never results."""
    q, k, v = _qkv(1, 256, 2, 2, 64)
    out = mha_flash(q, k, v, causal=causal, interpret=True, block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)

def test_flash_agrees_with_model_attention_core():
    """Kernel == the framework's jnp online-softmax path."""
    from repro.models.config import ModelConfig
    from repro.models.layers import _attention_core

    cfg = ModelConfig(d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
                      attn_chunk=64, dtype="float32")
    q, k, v = _qkv(2, 256, 4, 2, 32)
    ker = mha_flash(q, k, v, causal=True, interpret=True, block_q=64, block_k=64)
    core = _attention_core(cfg, q, k, v, q_offset=0, causal=True, window=0,
                           prefix_len=0, scale=1.0 / np.sqrt(32))
    np.testing.assert_allclose(np.asarray(ker), np.asarray(core), atol=2e-5, rtol=2e-5)
