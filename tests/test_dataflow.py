"""Unit + property tests for the core dataflow cost models."""

from _propcheck import given, settings, st

from repro.core import (
    ALL_DATAFLOWS,
    Dataflow,
    GemmShape,
    arithmetic_intensity,
    best_dataflow,
    best_kernel_dataflow,
    best_mesh_dataflow,
    hbm_traffic_bytes,
    mesh_gemm_cost,
    mxu_utilization,
    simulate_exact_os,
    systolic_cycles,
)

dims = st.integers(min_value=1, max_value=2048)
arr = st.sampled_from([8, 16, 32, 64, 128])

@given(M=dims, K=dims, N=dims, S=arr)
@settings(max_examples=200, deadline=None)
def test_cycles_positive_and_monotone_in_work(M, K, N, S):
    g = GemmShape(M, K, N)
    for df in ALL_DATAFLOWS:
        c = systolic_cycles(g, df, S, S)
        assert c > 0
        g2 = GemmShape(M * 2, K, N)
        assert systolic_cycles(g2, df, S, S) >= c

@given(M=dims, K=dims, N=dims, S=arr)
@settings(max_examples=200, deadline=None)
def test_best_dataflow_is_argmin(M, K, N, S):
    g = GemmShape(M, K, N)
    df, c = best_dataflow(g, S, S)
    assert c == min(systolic_cycles(g, d, S, S) for d in ALL_DATAFLOWS)

@given(M=st.integers(1, 96), K=st.integers(1, 96), N=st.integers(1, 96),
       r=st.sampled_from([4, 8, 16]), c=st.sampled_from([4, 8, 16]))
@settings(max_examples=100, deadline=None)
def test_exact_os_simulation_bounds_closed_form(M, K, N, r, c):
    """The closed form assumes full folds; the event-exact sim with edge tiles
    is never slower than it (equal when tiles divide evenly)."""
    g = GemmShape(M, K, N)
    closed = systolic_cycles(g, Dataflow.OS, r, c)
    exact = simulate_exact_os(M, K, N, r, c)
    assert exact <= closed
    if M % r == 0 and N % c == 0:
        assert exact == closed

def test_dataflow_asymptotics():
    """WS wins for tall GEMMs (M huge), IS for wide-K, OS for K-dominant."""
    S = 32
    tall = GemmShape(M=100_000, K=64, N=64)
    assert best_dataflow(tall, S, S)[0] is Dataflow.WS
    deep = GemmShape(M=32, K=100_000, N=32)
    # K-huge: OS streams K with one fold; IS folds over K
    assert best_dataflow(deep, S, S)[0] is Dataflow.OS

@given(M=dims, K=dims, N=dims)
@settings(max_examples=100, deadline=None)
def test_hbm_traffic_lower_bound(M, K, N):
    """No dataflow moves fewer bytes than (read each input once + write out)."""
    g = GemmShape(M, K, N)
    floor = (M * K + K * N) * 2 + M * N * 4
    for df in ALL_DATAFLOWS:
        cost = hbm_traffic_bytes(g, df, 512, 512, 512)
        assert cost.hbm_bytes >= floor * 0.999

@given(M=dims, K=dims, N=dims)
@settings(max_examples=100, deadline=None)
def test_single_block_gemm_all_dataflows_tie(M, K, N):
    """If the whole GEMM fits in one block, stationarity is irrelevant."""
    g = GemmShape(M, K, N)
    b = 2048
    costs = {df: hbm_traffic_bytes(g, df, b, b, b).hbm_bytes for df in ALL_DATAFLOWS}
    assert len(set(costs.values())) == 1

def test_kernel_dataflow_shape_dependence():
    """The CMU picks different dataflows for different layer shapes —
    the paper's core premise, at the kernel level.  All three appear:
    IS for a small-activation huge-vocab head, WS for a tall token stream
    through a one-block weight, OS for square compute-bound GEMMs."""
    bm = bk = bn = 256
    picks = {
        Dataflow.IS: GemmShape(64, 256, 152_064),   # decode vocab projection
        Dataflow.WS: GemmShape(1_000_000, 256, 256),  # tall training GEMM
        Dataflow.OS: GemmShape(4096, 4096, 4096),     # square, K-deep
    }
    for want, g in picks.items():
        got, _ = best_kernel_dataflow(g, bm, bk, bn)
        assert got is want, (g, got, want)

def test_tuned_cmu_matches_paper_narrative():
    """Block-shape-co-tuned CMU: train GEMMs pin weights (WS), decode GEMMs
    pin inputs (IS) — the paper's per-layer heterogeneity at the VMEM level."""
    from repro.core import tune_kernel_dataflow

    df_train, blk_t, _ = tune_kernel_dataflow(GemmShape(1_048_576, 2560, 9728))
    df_dec, blk_d, _ = tune_kernel_dataflow(GemmShape(128, 2560, 9728))
    assert df_train is Dataflow.WS and blk_t[1] >= 2560  # bk >= K: no partials
    assert df_dec is Dataflow.IS and blk_d[1] >= 2560

def test_tuned_cmu_never_worse_than_fixed_block():
    from repro.core import hbm_traffic_bytes, tune_kernel_dataflow

    for g in [GemmShape(4096, 4096, 4096), GemmShape(128, 2560, 152064),
              GemmShape(1_048_576, 2560, 9728)]:
        _, _, cost = tune_kernel_dataflow(g)
        fixed = min(
            hbm_traffic_bytes(g, df, 512, 512, 512).time_s() for df in ALL_DATAFLOWS
        )
        assert cost.time_s() <= fixed + 1e-12

def test_mesh_dataflow_train_vs_decode():
    """Mesh-level CMU: training (tokens >> weights) prefers weight-gathering
    (IS); decode (tiny activations) prefers weight-stationary TP (WS)."""
    tp = 16
    train = GemmShape(M=1_048_576, K=4096, N=14336)
    decode = GemmShape(M=128, K=4096, N=14336)
    assert best_mesh_dataflow(train, tp)[0] is Dataflow.IS
    assert best_mesh_dataflow(decode, tp)[0] is Dataflow.WS

@given(M=dims, K=dims, N=dims)
@settings(max_examples=50, deadline=None)
def test_mesh_costs_positive(M, K, N):
    g = GemmShape(M, K, N)
    for df in ALL_DATAFLOWS:
        c = mesh_gemm_cost(g, df, 16)
        assert c.comm_bytes >= 0 and c.flops_per_chip >= 0
        assert g.flops > 0
        assert c.time_s(overlap=1.0) <= c.time_s(overlap=0.0) + 1e-12

def test_utilization_and_intensity():
    g = GemmShape(4096, 4096, 4096)
    assert 0.99 <= mxu_utilization(g) <= 1.0
    g2 = GemmShape(100, 100, 100)
    assert mxu_utilization(g2) < 0.5
    assert arithmetic_intensity(g) > arithmetic_intensity(GemmShape(64, 64, 64))
