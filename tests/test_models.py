"""Per-architecture smoke tests + cross-path consistency (forward vs decode)."""

import jax
import jax.numpy as jnp
import pytest

from repro.models import ARCHS, Model, build_model, get_config

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=24, train=True, seed=1):
    k = jax.random.PRNGKey(seed)
    b = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size)}
    if train:
        b["labels"] = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    if cfg.family == "encdec":
        b["audio_embeds"] = jax.random.normal(k, (B, cfg.enc_seq_len, cfg.d_model))
    if cfg.family == "vlm":
        b["vision_embeds"] = jax.random.normal(
            k, (B, cfg.vision_tokens, cfg.vision_embed_dim or cfg.d_model)
        )
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nans(arch):
    m = build_model(arch, smoke=True)
    params = m.init(KEY)
    b = _batch(m.cfg)
    logits, aux = m.forward(params, b)
    assert logits.shape == (2, b["tokens"].shape[1], m.cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    from repro.launch.steps import init_train_state, make_train_step

    m = build_model(arch, smoke=True)
    params, opt = init_train_state(m, KEY)
    step = jax.jit(make_train_step(m))
    b = _batch(m.cfg)
    p2, o2, metrics = step(params, opt, b)
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.abs(x).sum()),
        jax.tree.map(lambda a, b_: (a.astype(jnp.float32) - b_.astype(jnp.float32)), params, p2),
        0.0,
    )
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_consistency_with_forward(arch):
    """Prefill+decode of token t must match the parallel forward at t."""
    cfg = get_config(arch, smoke=True).replace(dtype="float32")
    m = Model(cfg)
    params = m.init(KEY)
    B, S = 2, 24
    b = _batch(cfg, B, S, train=False)
    full, _ = m.forward(params, b)
    pre = dict(b)
    pre["tokens"] = b["tokens"][:, : S - 1]
    cache, last = m.prefill(params, pre, cache_len=48)
    dec, cache2 = m.decode_step(params, cache, b["tokens"][:, S - 1])
    denom = float(jnp.abs(full[:, -1]).max()) + 1e-9
    rel = float(jnp.abs(dec - full[:, -1]).max()) / denom
    assert rel < 2e-2, rel
    # prefill's last logits == forward at S-2
    rel2 = float(jnp.abs(last - full[:, -2]).max()) / denom
    assert rel2 < 2e-2, rel2
    expect_pos = S + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    assert int(cache2["pos"]) == expect_pos


@pytest.mark.parametrize("arch", ARCHS)
def test_multi_step_greedy_decode_finite(arch):
    m = build_model(arch, smoke=True)
    params = m.init(KEY)
    b = _batch(m.cfg, train=False)
    cache, last = m.prefill(params, b, cache_len=48)
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    dec = jax.jit(m.decode_step)
    for _ in range(4):
        logits, cache = dec(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert bool(jnp.all(jnp.isfinite(logits)))


def test_tiny_model_overfits():
    """A 2-layer model must overfit one repeated batch (loss drops a lot)."""
    from repro.launch.steps import init_train_state, make_train_step

    cfg = get_config("qwen3_4b", smoke=True)
    m = Model(cfg)
    params, opt = init_train_state(m, KEY)
    step = jax.jit(make_train_step(m, peak_lr=3e-3, warmup=5, total_steps=80))
    b = _batch(cfg, B=4, S=16, seed=3)
    first = last = None
    for i in range(60):
        params, opt, metrics = step(params, opt, b)
        if i == 0:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first * 0.55, (first, last)


def test_microbatched_step_matches_plain():
    """Gradient accumulation (4 microbatches) == single-batch step."""
    from repro.launch.steps import init_train_state, make_train_step

    cfg = get_config("minicpm_2b", smoke=True)
    m = Model(cfg)
    params, opt = init_train_state(m, KEY)
    b = _batch(cfg, B=8, S=16, seed=5)
    p1, _, m1 = jax.jit(make_train_step(m))(params, opt, b)
    p2, _, m2 = jax.jit(make_train_step(m, microbatches=4))(params, opt, b)
    d = jax.tree.reduce(
        max,
        jax.tree.map(lambda a, c: float(jnp.abs(a.astype(jnp.float32) - c.astype(jnp.float32)).max()), p1, p2),
        0.0,
    )
    assert d < 5e-4, d


def test_window_pattern_masks_differ():
    """gemma3 smoke: windowed layer attends less than a global layer."""
    cfg = get_config("gemma3_12b", smoke=True).replace(dtype="float32")
    m = Model(cfg)
    params = m.init(KEY)
    b = _batch(cfg, B=1, S=20, train=False)
    logits, _ = m.forward(params, b)
    # flip a token far outside every window; only global layers can see it
    b2 = dict(b)
    b2["tokens"] = b["tokens"].at[0, 0].set((b["tokens"][0, 0] + 1) % cfg.vocab_size)
    logits2, _ = m.forward(params, b2)
    assert float(jnp.abs(logits - logits2)[0, -1].max()) > 0  # info still flows


def test_param_count_sane():
    full = get_config("qwen3_4b")
    total, active = full.param_count()
    assert 3.0e9 < total < 6.0e9, total  # "4b"
    moe = get_config("qwen3_moe_235b")
    t2, a2 = moe.param_count()
    assert 1.8e11 < t2 < 3.2e11, t2    # "235b"
    assert 1.2e10 < a2 < 4.0e10, a2    # "a22b"
    arctic = get_config("arctic_480b")
    t3, _ = arctic.param_count()
    assert 3.8e11 < t3 < 5.8e11, t3    # "480b"
