"""Two-level stationarity: VMEM-resident accumulator strips.

Four acceptance bars:

* **Bit-identity property sweep** — for every dataflow x (trans_a, trans_b)
  x epilogue combination x ragged shape, the strip schedules must be
  bit-identical to ``strip=1`` streaming (same f32 MACs in the same k
  order; only residency differs).
* **Budget property** — every candidate ``_ranked_candidates`` emits fits
  ``VMEM_BUDGET_BYTES`` *including* the f32 accumulator-strip scratch, the
  strip tiles its axis exactly, and OS only ever carries strip=1.
* **Traffic model honesty** — ``hbm_traffic_bytes(strip=...)`` equals the
  byte count of a walk over the exact grid + index maps the kernel builders
  emit (``schedule_cost_bytes``), and strips eliminate the WS/IS
  partial-sum round-trips.
* **Schema v4** — v1/v2/v3 caches load-and-migrate with strip=1 (today's
  streamed behaviour, unchanged dispatch) and a migrated plan drives a
  correct end-to-end gradient.
"""

import json
import os
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

import repro.kernels  # noqa: F401  — materialises the kernel submodules
from repro.core import (
    ALL_DATAFLOWS,
    TRANS_DX,
    TRANS_DW,
    VMEM_BUDGET_BYTES,
    Dataflow,
    GemmShape,
    autotune_plan,
    hbm_traffic_bytes,
    kernel_block_candidates,
    load_plan,
    strip_blocks,
    strip_candidates,
)
from repro.core.cmu import _ranked_candidates
from repro.kernels import flex_linear, flex_matmul, linear_ref

fk = sys.modules["repro.kernels.flex_matmul"]

RNG = np.random.default_rng(11)


def _rand(shape, dtype=jnp.float32, scale=0.2):
    return jnp.asarray(RNG.normal(size=shape) * scale, np.float32).astype(dtype)


def _physical(arr, trans: bool):
    return jnp.asarray(np.asarray(arr).T.copy()) if trans else arr


# ---------------------------------------------------------------------------
# bit-identity property sweep: strip vs streamed
# ---------------------------------------------------------------------------


@settings(max_examples=24, deadline=None)
@given(
    st.sampled_from([Dataflow.WS, Dataflow.IS]),
    st.booleans(),
    st.booleans(),
    st.integers(min_value=1, max_value=200),
    st.integers(min_value=1, max_value=200),
    st.integers(min_value=1, max_value=200),
    st.sampled_from([2, 3, 4, 8]),
)
def test_strip_matmul_bit_identical_to_streamed(df, ta, tb, M, K, N, strip):
    """Ragged shapes x trans layouts: ops pads and clamps the strip to the
    padded geometry; whatever depth actually runs must reproduce the
    streamed result bit-for-bit."""
    A, B = _rand((M, K)), _rand((K, N))
    a, b = _physical(A, ta), _physical(B, tb)
    kw = dict(dataflow=df, block=(64, 64, 64), interpret=True,
              trans_a=ta, trans_b=tb)
    streamed = flex_matmul(a, b, strip=1, **kw)
    stripped = flex_matmul(a, b, strip=strip, **kw)
    np.testing.assert_array_equal(np.asarray(streamed), np.asarray(stripped))


@settings(max_examples=12, deadline=None)
@given(
    st.sampled_from([Dataflow.WS, Dataflow.IS]),
    st.sampled_from([None, "relu", "gelu", "silu"]),
    st.booleans(),
    st.booleans(),
    st.integers(min_value=1, max_value=160),
    st.integers(min_value=1, max_value=160),
    st.sampled_from([2, 4]),
)
def test_strip_linear_bit_identical_to_streamed(df, act, bias, res, M, N, strip):
    """The fused epilogue off the strip flush (bias/activation/residual/cast)
    must match the streamed flush bit-for-bit — including the residual,
    which the strip kernel fuses in-kernel while the streamed path adds it
    outside in the same f32 op order."""
    K = 96
    x, w = _rand((M, K)), _rand((K, N))
    b = _rand((N,)) if bias else None
    r = _rand((M, N)) if res else None
    kw = dict(activation=act, residual=r, dataflow=df, block=(64, 64, 64),
              interpret=True, out_dtype=jnp.bfloat16)
    streamed = flex_linear(x, w, b, strip=1, **kw)
    stripped = flex_linear(x, w, b, strip=strip, **kw)
    np.testing.assert_array_equal(np.asarray(streamed), np.asarray(stripped))


@pytest.mark.parametrize("df", [Dataflow.WS, Dataflow.IS])
def test_strip_grad_bit_identical_to_streamed(df, strip=4):
    """save_preact + both backward GEMMs under strip schedules: gradients
    equal the streamed gradients bitwise and the XLA reference to tolerance."""
    x, w, b = _rand((128, 192)), _rand((192, 128)), _rand((128,))

    def loss(x, w, strip_fwd, st_dx, st_dw):
        # identical (dataflow, block, trans) for both runs — only the strip
        # depth differs, so any bit difference is the strip schedule's fault
        return flex_linear(x, w, b, activation="gelu", dataflow=df,
                           block=(64, 64, 64), interpret=True,
                           bwd_dx=(df, (64, 64, 64), TRANS_DX, st_dx),
                           bwd_dw=(df, (64, 64, 64), TRANS_DW, st_dw),
                           strip=strip_fwd).sum()

    g_stream = jax.grad(lambda x, w: loss(x, w, 1, 1, 1), (0, 1))(x, w)
    g_strip = jax.grad(
        lambda x, w: loss(x, w, strip, strip, strip), (0, 1)
    )(x, w)
    for gs, gt in zip(g_stream, g_strip):
        np.testing.assert_array_equal(np.asarray(gs), np.asarray(gt))
    g_ref = jax.grad(
        lambda x, w: linear_ref(x, w, b, activation="gelu").sum(), (0, 1)
    )(x, w)
    for gs, gr in zip(g_strip, g_ref):
        np.testing.assert_allclose(np.asarray(gs), np.asarray(gr),
                                   atol=2e-4, rtol=2e-4)


def test_os_rejects_strips_and_matmul_threads_them():
    a, b = _rand((128, 64)), _rand((64, 128))
    with pytest.raises(ValueError, match="OS runs strip=1"):
        fk.matmul(a, b, Dataflow.OS, block=(64, 64, 64), interpret=True,
                  strip=2)
    # the jitted wrapper normalises OS to strip=1 instead of erroring
    out = flex_matmul(a, b, Dataflow.OS, block=(64, 64, 64), interpret=True,
                      strip=4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b), atol=1e-5)


def test_strip_must_tile_axis_at_kernel_level():
    a, b = _rand((192, 64)), _rand((64, 64))  # 3 M-blocks of 64
    with pytest.raises(ValueError, match="must tile"):
        fk.matmul_ws(a, b, block=(64, 64, 64), interpret=True, strip=2)
    # the traffic walker rejects the same schedule instead of silently
    # walking a truncated grid
    with pytest.raises(ValueError, match="does not tile"):
        fk.schedule_cost_bytes(Dataflow.WS, 192, 64, 64, (64, 64, 64),
                               strip=2)
    # ops clamps 2 -> 1 for the same geometry (largest divisor of 3 <= 2)
    out = flex_matmul(a, b, Dataflow.WS, block=(64, 64, 64), interpret=True,
                      strip=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b), atol=1e-5)


def test_strip_grid_axes_are_megacore_parallel():
    """The strip grids' (s, j/i) axes are single-writer, so the builders
    must declare them "parallel"; the streamed grids stay all-arbitrary
    (multi-writer output blocks across the k planes)."""

    def semantics(fn):
        jx = jax.make_jaxpr(fn)(jnp.ones((128, 64)), jnp.ones((64, 128)))

        def find(jaxpr):
            for eqn in jaxpr.eqns:
                if eqn.primitive.name == "pallas_call":
                    return eqn
                for v in eqn.params.values():
                    for sub in (v if isinstance(v, (list, tuple)) else [v]):
                        if isinstance(sub, jax.core.ClosedJaxpr):
                            got = find(sub.jaxpr)
                            if got is not None:
                                return got
            return None

        eqn = find(jx.jaxpr)
        assert eqn is not None
        return eqn.params["compiler_params"]["mosaic"]["dimension_semantics"]

    blk = dict(block=(64, 64, 64), interpret=True)
    assert semantics(lambda a, b: fk.matmul_ws(a, b, strip=2, **blk)) == (
        "parallel", "parallel", "arbitrary", "arbitrary")
    assert semantics(lambda a, b: fk.matmul_is(a, b, strip=2, **blk)) == (
        "parallel", "parallel", "arbitrary", "arbitrary")
    assert semantics(lambda a, b: fk.matmul_ws(a, b, strip=1, **blk)) == (
        "arbitrary", "arbitrary", "arbitrary")


# ---------------------------------------------------------------------------
# traffic model: partial-sum elimination + schedule-walk agreement
# ---------------------------------------------------------------------------


def test_strip_eliminates_partial_sum_traffic():
    """For a strip-feasible shape the WS/IS strip traffic has no partial
    read-modify-write term: exactly one output write, with the stationary
    operand re-fetched once per strip."""
    g = GemmShape(1024, 1024, 1024)
    bm = bk = bn = 128
    kb = 8
    a, b, c = g.M * g.K * 2, g.K * g.N * 2, g.M * g.N * 4
    streamed = hbm_traffic_bytes(g, Dataflow.WS, bm, bk, bn).hbm_bytes
    assert streamed == b + (g.N // bn) * a + (2 * kb - 1) * c
    for strip in (2, 4, 8):
        got = hbm_traffic_bytes(g, Dataflow.WS, bm, bk, bn, strip=strip)
        sb = (g.M // bm) // strip
        assert got.hbm_bytes == sb * b + (g.N // bn) * a + c
        got_is = hbm_traffic_bytes(g, Dataflow.IS, bm, bk, bn, strip=strip)
        assert got_is.hbm_bytes == sb * a + (g.M // bm) * b + c
    # full-M residency: both the pinned operand and the outputs move once —
    # the WS floor, unreachable by any streamed schedule when Kb > 1
    full = hbm_traffic_bytes(g, Dataflow.WS, bm, bk, bn, strip=8).hbm_bytes
    assert full == b + (g.N // bn) * a + c < streamed


@pytest.mark.parametrize("df", ALL_DATAFLOWS)
def test_schedule_walk_matches_analytical_model(df):
    """The analytical model must agree with a walk over the exact grids and
    index maps the kernel builders emit (the CI perf smoke runs the same
    assertion on the benchmark shapes).

    The contract: byte-for-byte equality whenever every GEMM dimension
    spans >= 2 blocks (every shape the strip search targets), and a safe
    upper bound on degenerate single-block axes, where an idle grid axis
    leaves an index map constant and Pallas coalesces the refetch the
    closed form still charges."""
    for M, K, N, blk in [(256, 192, 256, (64, 64, 64)),
                         (512, 256, 256, (128, 128, 128)),
                         (128, 512, 512, (64, 128, 128))]:
        g = GemmShape(M, K, N)
        strips = [1] if df is Dataflow.OS else strip_candidates(
            strip_blocks(g, df, blk[0], blk[2]))
        for strip in strips:
            walk = fk.schedule_cost_bytes(df, M, K, N, blk, strip=strip,
                                          in_bytes=2, out_bytes=4)
            model = hbm_traffic_bytes(g, df, *blk, strip=strip).hbm_bytes
            assert walk == model, (df, strip, walk, model)
    # degenerate axes (single-block dims): the model upper-bounds the walk
    # (never undercounts, so VMEM/traffic pruning stays safe)
    for M, K, N, blk in [(512, 256, 128, (128, 128, 128)),
                         (64, 512, 64, (64, 64, 64)),
                         (64, 64, 640, (64, 64, 64))]:
        g = GemmShape(M, K, N)
        strips = [1] if df is Dataflow.OS else strip_candidates(
            strip_blocks(g, df, blk[0], blk[2]))
        for strip in strips:
            walk = fk.schedule_cost_bytes(df, M, K, N, blk, strip=strip,
                                          in_bytes=2, out_bytes=4)
            model = hbm_traffic_bytes(g, df, *blk, strip=strip).hbm_bytes
            assert walk <= model, (df, strip, walk, model)


def test_budget_property_every_candidate_fits_vmem():
    """Every (dataflow, block, strip) config the CMU ranks fits the unified
    VMEM budget including the strip's f32 scratch; strips tile their axis
    exactly; OS only ever emits strip=1."""
    for g in [GemmShape(4096, 1024, 4096), GemmShape(16, 896, 151_936),
              GemmShape(65_536, 2560, 9728)]:
        ranked = _ranked_candidates(g, VMEM_BUDGET_BYTES)
        assert ranked
        saw_strip = False
        for t, df, (bm, bk, bn), strip, _qd in ranked:
            cost = hbm_traffic_bytes(g, df, bm, bk, bn, strip=strip)
            assert cost.vmem_bytes <= VMEM_BUDGET_BYTES
            # strips charge the f32 accumulator strip PLUS the fused
            # kernels' same-extent copy-out buffer (4 + out_bytes per elem)
            acc = strip * bm * bn * 8 if strip > 1 else bm * bn * 4
            recomputed = (bm * bk + bk * bn) * 2 + acc
            assert cost.vmem_bytes == recomputed
            if df is Dataflow.OS:
                assert strip == 1
            else:
                assert strip_blocks(g, df, bm, bn) % strip == 0
                saw_strip = saw_strip or strip > 1
            assert t > 0
        assert saw_strip  # the 3-D schedule space is actually searched


def test_strip_beats_streamed_for_deep_k_ws():
    """The motivating shape: K spans many blocks, so streamed WS pays
    (2Kb-1) output round-trips and loses to OS for an artifact reason;
    the strip schedule removes them and the analytical argmin for a tall
    deep-K GEMM becomes a WS/IS strip schedule, not OS."""
    g = GemmShape(8192, 8192, 256)  # tall, deep K, narrow N
    ranked = _ranked_candidates(g, VMEM_BUDGET_BYTES)
    best_t, best_df, best_blk, best_strip, _qd = ranked[0]
    best = hbm_traffic_bytes(g, best_df, *best_blk, strip=best_strip)
    streamed_best = min(
        hbm_traffic_bytes(g, df, bm, bk, bn).hbm_bytes
        for _, df, (bm, bk, bn), s, _q in ranked if s == 1
    )
    assert best.hbm_bytes <= streamed_best
    stripped = [r for r in ranked if r[3] > 1]
    assert stripped and min(s[0] for s in stripped) <= ranked[0][0] + 1e-18


# ---------------------------------------------------------------------------
# skinny decode blocks
# ---------------------------------------------------------------------------


def test_skinny_block_candidates_for_small_m():
    assert kernel_block_candidates(8, sublane=True)[0] == 8
    assert kernel_block_candidates(32, sublane=True)[:3] == [8, 16, 32]
    # K/N dimensions keep the MXU-aligned floor of 128
    assert min(kernel_block_candidates(32)) == 128
    # large dims are unchanged by the sublane flag
    assert kernel_block_candidates(4096, sublane=True) == \
        kernel_block_candidates(4096)


def test_decode_geometry_plans_skinny_blocks():
    """A decode-step projection (M = batch = 16) must tune to a sublane
    block, not pad to 128+ rows, and the plan must survive the cache."""
    from repro.core import plan_matches, save_plan

    g = GemmShape(16, 896, 1024, name="attn.wq")
    plan = autotune_plan([g], top_k=2, iters=1)
    lp = plan.layers[0]
    assert lp.block is not None and lp.block[0] <= 64
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "plan.json")
        save_plan(p, plan)
        reloaded = load_plan(p)
        assert plan_matches(reloaded, [g])
        assert reloaded.layers[0].block == lp.block
        assert reloaded.layers[0].strip == lp.strip


# ---------------------------------------------------------------------------
# plan-cache schema v4: v1/v2/v3 load-and-migrate with strip=1 semantics
# ---------------------------------------------------------------------------


def _v3_payload():
    return {
        "version": 3,
        "layers": [{
            "name": "mlp.w1", "M": 128, "K": 96, "N": 128,
            "dataflow": "WS", "est_cost": 1.0,
            "block": [64, 96, 64], "source": "measured",
            "bwd_dx": {"dataflow": "IS", "block": [64, 64, 96],
                       "est_cost": 0.9, "source": "measured",
                       "trans": [False, True]},
            "bwd_dw": {"dataflow": "OS", "block": [96, 64, 64],
                       "est_cost": 0.8, "source": "measured",
                       "trans": [True, False]},
        }],
    }


@pytest.mark.parametrize("version", [1, 2, 3])
def test_old_caches_migrate_to_strip1_with_unchanged_dispatch(version):
    payload = _v3_payload()
    payload["version"] = version
    if version < 3:
        for sub in ("bwd_dx", "bwd_dw"):
            payload["layers"][0][sub].pop("trans")
    if version < 2:
        payload["layers"][0]["bwd_dx"] = None
        payload["layers"][0]["bwd_dw"] = None
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "plan.json")
        with open(p, "w") as f:
            json.dump(payload, f)
        plan = load_plan(p)
    lp = plan.layers[0]
    # dispatch unchanged: same dataflow/block as the old plan, strip=1
    # (exactly the streamed schedule every pre-v4 plan was tuned on)
    assert lp.dataflow is Dataflow.WS and lp.block == (64, 96, 64)
    assert lp.strip == 1
    if version >= 2:
        assert lp.bwd_dx.strip == 1 and lp.bwd_dw.strip == 1
        assert lp.bwd_dx.trans == TRANS_DX and lp.bwd_dw.trans == TRANS_DW


def test_migrated_v3_plan_drives_correct_end_to_end_grad():
    """End-to-end: a migrated v3 cache's specs (now carrying strip=1) reach
    the VJP, produce reference gradients, and match the streamed dispatch
    bit-for-bit — today's behaviour, reproduced."""
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "plan.json")
        with open(p, "w") as f:
            json.dump(_v3_payload(), f)
        lp = load_plan(p).layers[0]
    x, w = _rand((128, 96)), _rand((96, 128))
    dx_spec = (lp.bwd_dx.dataflow, lp.bwd_dx.block, lp.bwd_dx.trans,
               lp.bwd_dx.strip)
    dw_spec = (lp.bwd_dw.dataflow, lp.bwd_dw.block, lp.bwd_dw.trans,
               lp.bwd_dw.strip)

    def loss(x, w):
        return flex_linear(x, w, activation="gelu", dataflow=lp.dataflow,
                           block=lp.block, interpret=True, strip=lp.strip,
                           bwd_dx=dx_spec, bwd_dw=dw_spec).sum()

    def legacy(x, w):  # the pre-v4 dispatch: identical but with 3-tuple specs
        return flex_linear(x, w, activation="gelu", dataflow=lp.dataflow,
                           block=lp.block, interpret=True,
                           bwd_dx=dx_spec[:3], bwd_dw=dw_spec[:3]).sum()

    got = jax.grad(loss, (0, 1))(x, w)
    old = jax.grad(legacy, (0, 1))(x, w)
    want = jax.grad(
        lambda x, w: linear_ref(x, w, activation="gelu").sum(), (0, 1)
    )(x, w)
    for g, o, r in zip(got, old, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(o))
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   atol=2e-4, rtol=2e-4)


def test_train_plan_records_strips_and_runs():
    """A fresh measured train plan over a strip-favourable geometry records
    its (dataflow, block, strip) decisions and drives a correct grad."""
    plan = autotune_plan([GemmShape(64, 128, 64, name="l0")], top_k=2,
                         iters=1, train=True)
    lp = plan.layers[0]
    assert lp.strip >= 1 and lp.bwd_dx.strip >= 1 and lp.bwd_dw.strip >= 1
    x, w = _rand((64, 128)), _rand((128, 64))
    dx = (lp.bwd_dx.dataflow, lp.bwd_dx.block, lp.bwd_dx.trans, lp.bwd_dx.strip)
    dw = (lp.bwd_dw.dataflow, lp.bwd_dw.block, lp.bwd_dw.trans, lp.bwd_dw.strip)
    got = jax.grad(
        lambda x, w: flex_linear(x, w, activation="silu", dataflow=lp.dataflow,
                                 block=lp.block, strip=lp.strip, interpret=True,
                                 bwd_dx=dx, bwd_dw=dw).sum(), (0, 1)
    )(x, w)
    want = jax.grad(
        lambda x, w: linear_ref(x, w, activation="silu").sum(), (0, 1)
    )(x, w)
    for g, r in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   atol=2e-4, rtol=2e-4)
