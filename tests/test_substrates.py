"""Optimizer, schedules, checkpoint, data pipeline."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.data import DataConfig, TokenStream
from repro.optim import adamw_init, adamw_update, cosine, wsd
from repro.optim.adamw import _dequantize, _quantize

def _params():
    return {
        "w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 48)), jnp.float32),
        "b": jnp.zeros((48,)),
        "nested": {"e": jnp.ones((10, 8, 6))},
    }

def _grads():
    return jax.tree.map(
        lambda p: jnp.asarray(np.random.default_rng(1).normal(size=p.shape), jnp.float32) * 0.1,
        _params(),
    )

def test_adamw_fp32_basic():
    p, g = _params(), _grads()
    st_ = adamw_init(p)
    p2, st2 = adamw_update(p, g, st_, 1e-2)
    assert int(st2.step) == 1
    assert all(jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(p2))

def test_adamw_int8_close_to_fp32():
    p, g = _params(), _grads()
    pf, _ = adamw_update(p, g, adamw_init(p), 1e-2)
    pq, sq = adamw_update(p, g, adamw_init(p, quantize=True), 1e-2)
    d = max(
        float(jnp.abs(a - b).max()) for a, b in zip(jax.tree.leaves(pf), jax.tree.leaves(pq))
    )
    assert d < 2e-4, d
    assert all(x.dtype == jnp.int8 for x in jax.tree.leaves(sq.m))

def test_adamw_int8_multi_step_tracks_fp32():
    """int8-m/bf16-v drift stays a small fraction of actual parameter
    movement under realistic (varying) gradients."""
    p = _params()
    sf, sq = adamw_init(p), adamw_init(p, quantize=True)
    pf = pq = p
    for i in range(10):
        g = jax.tree.map(
            lambda q, i=i: jnp.asarray(
                np.random.default_rng(100 + i).normal(size=q.shape), jnp.float32
            ) * 0.1,
            p,
        )
        pf, sf = adamw_update(pf, g, sf, 1e-3)
        pq, sq = adamw_update(pq, g, sq, 1e-3)
    drift = max(
        float(jnp.abs(a - b).max()) for a, b in zip(jax.tree.leaves(pf), jax.tree.leaves(pq))
    )
    move = max(
        float(jnp.abs(a - b).max()) for a, b in zip(jax.tree.leaves(pf), jax.tree.leaves(p))
    )
    assert drift < 0.1 * move, (drift, move)

@given(st.integers(0, 2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_quantize_roundtrip_bound(seed):
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(7, 33)), jnp.float32)
    q, s = _quantize(x)
    rec = _dequantize(q, s, x.shape, x.size)
    # per-channel absmax int8: error <= scale/2 per element
    bound = np.asarray(s).max() * 0.51 + 1e-9
    assert float(jnp.abs(rec - x).max()) <= bound

def test_wsd_schedule_shape():
    total, peak, warm = 1000, 1.0, 100
    assert float(wsd(0, total, peak, warm)) < 0.02
    assert float(wsd(warm, total, peak, warm)) == pytest.approx(peak, rel=0.02)
    assert float(wsd(total // 2, total, peak, warm)) == pytest.approx(peak)
    assert float(wsd(total, total, peak, warm)) < 0.01

def test_cosine_schedule_monotone_decay():
    vals = [float(cosine(s, 1000, 1.0, warmup=10)) for s in range(10, 1000, 97)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))

# ---- checkpoint ------------------------------------------------------------

def test_checkpoint_roundtrip_and_latest():
    p = _params()
    with tempfile.TemporaryDirectory() as d:
        assert latest_step(d) is None
        save_checkpoint(d, 10, p, extra={"rng": 7})
        save_checkpoint(d, 20, jax.tree.map(lambda a: a + 1, p))
        assert latest_step(d) == 20
        loaded, extra = load_checkpoint(d, 10, p)
        np.testing.assert_array_equal(np.asarray(loaded["w"]), np.asarray(p["w"]))
        assert extra["rng"] == 7

def test_checkpoint_atomic_commit():
    """A partially-written (tmp) checkpoint is never visible."""
    p = _params()
    with tempfile.TemporaryDirectory() as d:
        os.makedirs(os.path.join(d, ".tmp_step_99"))  # simulated crash debris
        save_checkpoint(d, 5, p)
        assert latest_step(d) == 5

def test_checkpoint_async():
    import time

    p = _params()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, p, async_write=True)
        for _ in range(100):
            if latest_step(d) == 3:
                break
            time.sleep(0.05)
        assert latest_step(d) == 3

# ---- data pipeline ---------------------------------------------------------

def test_data_deterministic_per_step():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=3)
    s1, s2 = TokenStream(cfg), TokenStream(cfg)
    b1, b2 = s1.batch(17), s2.batch(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(s1.batch(18)["tokens"], b1["tokens"])

def test_data_shards_disjoint_and_stateless():
    kw = dict(vocab_size=1000, seq_len=16, global_batch=8, seed=0, num_shards=4)
    shards = [TokenStream(DataConfig(shard_id=i, **kw)) for i in range(4)]
    batches = [s.batch(5)["tokens"] for s in shards]
    assert all(b.shape == (2, 16) for b in batches)
    assert not np.array_equal(batches[0], batches[1])

def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
    b = TokenStream(cfg).batch(0)
    assert b["tokens"].shape == b["labels"].shape
    assert (b["labels"] < 100).all() and (b["labels"] >= 0).all()

def test_memmap_corpus_roundtrip(tmp_path):
    from repro.data import write_corpus

    toks = np.arange(10_000) % 50_000
    path = str(tmp_path / "corpus.bin")
    write_corpus(path, toks)
    cfg = DataConfig(vocab_size=50_000, seq_len=64, global_batch=4, corpus_path=path)
    b = TokenStream(cfg).batch(2)
    assert b["tokens"].shape == (4, 64)
    # consecutive labels continue the corpus sequence
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
