"""Validation of the reproduction against the paper's own claims.

Exact ScaleSim cycle counts aren't recoverable offline (topology CSVs and
simulator internals unavailable), so these tests validate the paper's
*claims* as bands/orderings — per-layer optima, speedup ranges, overhead
trends — which is what the paper itself argues from.
"""

import numpy as np
import pytest

from repro.core import (
    ALL_DATAFLOWS,
    Dataflow,
    PAPER_TABLE1,
    PAPER_TABLE2,
    WORKLOADS,
    overheads,
    plan_systolic,
    simulate_network,
    synthesize,
    utilization,
)


@pytest.fixture(scope="module")
def results32():
    return {n: simulate_network(n, l, 32) for n, l in WORKLOADS.items()}


def test_flex_speedup_band_table1(results32):
    """Paper Table I: flex speedup vs every static dataflow in [1.0, ~2.0]
    at S=32 (paper range 1.027-1.949; we allow modelling slack)."""
    for name, r in results32.items():
        for df in ALL_DATAFLOWS:
            s = r.speedup(df)
            assert 1.0 <= s <= 2.6, (name, df, s)


def test_flex_never_slower_than_static(results32):
    for name, r in results32.items():
        for df in ALL_DATAFLOWS:
            assert r.flex_cycles <= r.static_cycles(df), (name, df)


def test_os_is_best_static_on_average(results32):
    """Paper: avg speedups 1.612 (IS), 1.090 (OS), 1.400 (WS) -> OS closest."""
    avg = {df: np.mean([r.speedup(df) for r in results32.values()]) for df in ALL_DATAFLOWS}
    assert avg[Dataflow.OS] < avg[Dataflow.IS]
    assert avg[Dataflow.OS] < avg[Dataflow.WS]
    assert 1.0 < avg[Dataflow.OS] < 1.3  # paper: 1.090


def test_absolute_cycles_same_order_of_magnitude(results32):
    """Our reconstructed topologies land within ~4x of the paper's counts
    (AlexNet differs most: padded ifmaps + conv-expressed FC layers)."""
    for name, r in results32.items():
        paper = PAPER_TABLE1[name]["flex"]
        assert paper / 4.0 <= r.flex_cycles <= paper * 4.0, (name, r.flex_cycles, paper)


def test_fig1_resnet_layer_dataflow_structure(results32):
    """Fig. 1: ResNet-18's first five layers are fastest under WS; deeper
    layers move to OS/IS."""
    sched = results32["resnet18"].flex_schedule
    assert all(d is Dataflow.WS for d in sched[:5]), sched[:5]
    assert any(d is not Dataflow.WS for d in sched[8:]), sched[8:]


def test_per_layer_optimum_varies(results32):
    """The paper's core premise: no single dataflow is optimal per layer."""
    for name, r in results32.items():
        if name == "vgg13":
            continue  # nearly uniform conv shapes; schedule may collapse
        assert len(set(r.flex_schedule)) >= 2, name


def test_fig7_scalability_trend():
    """Fig. 7: flex advantage over static-OS GROWS with array size
    (paper: 1.090 @32 -> 1.238 @128 -> 1.349 @256)."""
    avgs = []
    for S in (32, 128, 256):
        sp = [simulate_network(n, l, S).speedup(Dataflow.OS) for n, l in WORKLOADS.items()]
        avgs.append(np.mean(sp))
    assert avgs[0] < avgs[1] < avgs[2], avgs


def test_cmu_plan_matches_simulation():
    plan = plan_systolic(WORKLOADS["resnet18"], 32)
    r = simulate_network("resnet18", WORKLOADS["resnet18"], 32)
    assert [l.dataflow for l in plan.layers] == r.flex_schedule
    assert sum(l.est_cost for l in plan.layers) == r.flex_cycles


def test_cmu_plan_json_roundtrip():
    plan = plan_systolic(WORKLOADS["alexnet"], 32)
    plan2 = type(plan).from_json(plan.to_json())
    assert [l.dataflow for l in plan2.layers] == [l.dataflow for l in plan.layers]


# ---- Table II: area / power / delay --------------------------------------


def test_table2_absolute_calibration():
    for S in (8, 16, 32):
        ref = PAPER_TABLE2[S]
        base = synthesize(S)
        fx = synthesize(S, flex=True)
        assert abs(base.area_mm2 - ref["tpu"]["area"]) / ref["tpu"]["area"] < 0.10
        assert abs(base.power_mw - ref["tpu"]["power"]) / ref["tpu"]["power"] < 0.10
        assert abs(base.delay_ns - ref["tpu"]["delay"]) / ref["tpu"]["delay"] < 0.05
        assert abs(fx.area_mm2 - ref["flex"]["area"]) / ref["flex"]["area"] < 0.10


def test_table2_overhead_bands():
    """Paper: area overhead <= 13.6% (shrinks with S), power <= 10.7%,
    delay <= 2.07%."""
    areas = []
    for S in (8, 16, 32):
        o = overheads(S)
        ref = PAPER_TABLE2[S]["overhead"]
        assert abs(o.area_pct - ref["area"]) < 3.0, (S, o.area_pct)
        assert abs(o.power_pct - ref["power"]) < 3.0, (S, o.power_pct)
        assert o.delay_pct <= 2.5
        areas.append(o.area_pct)
    assert areas[0] > areas[2], "area overhead must shrink with array size"


def test_systolic_array_dominates_area():
    """Paper Fig. 5: systolic array is 77-80% of TPU area (we accept 70-90)."""
    for S in (16, 32):
        frac = synthesize(S).systolic_area_fraction if hasattr(synthesize(S), 'systolic_area_fraction') else None
        r = synthesize(S)
        assert 0.70 <= r.systolic_area_fraction <= 0.92, r.systolic_area_fraction


def test_utilization_sane(results32):
    for name, r in results32.items():
        u = utilization(r)
        assert 0.0 < u <= 1.0, (name, u)
        # flex utilisation >= best static utilisation
        for df in ALL_DATAFLOWS:
            assert u >= utilization(r, df) - 1e-12
