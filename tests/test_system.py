"""End-to-end system behaviour: the full training loop with the real model,
data pipeline, optimizer, checkpointing and failure injection composed."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import DataConfig, TokenStream
from repro.launch.steps import init_train_state, make_train_step
from repro.models import Model, get_config
from repro.runtime import RunnerConfig, SimulatedNodeFailure, TrainRunner


def _make_system(ckpt_dir, max_steps=12, failure_hook=None):
    cfg = get_config("qwen3_4b", smoke=True)
    model = Model(cfg)
    stream = TokenStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4))
    jit_step = jax.jit(make_train_step(model, peak_lr=1e-3, warmup=2, total_steps=100))

    def init():
        params, opt = init_train_state(model, jax.random.PRNGKey(0))
        return {"params": params, "opt": opt}

    def step_fn(state, i):
        batch = {k: jnp.asarray(v) for k, v in stream.batch(i).items()}
        params, opt, metrics = jit_step(state["params"], state["opt"], batch)
        return {"params": params, "opt": opt}, {"loss": float(metrics["loss"])}

    return TrainRunner(
        step_fn, init,
        RunnerConfig(ckpt_dir=ckpt_dir, ckpt_every=4, max_steps=max_steps),
        failure_hook=failure_hook,
    )


def test_train_loop_loss_decreases():
    with tempfile.TemporaryDirectory() as d:
        r = _make_system(d, max_steps=12)
        state, step = r.run()
        assert step == 12
        losses = [m["loss"] for m in r.metrics_log]
        assert losses[-1] < losses[0]


def test_crash_recovery_is_bit_exact():
    """Full model + optimizer + data: kill at step 9, final params must equal
    the uninterrupted run exactly (deterministic replay from step-8 ckpt)."""
    with tempfile.TemporaryDirectory() as d:
        ref_state, _ = _make_system(d, max_steps=12).run()
    fired = []

    def bomb(step):
        if step == 9 and not fired:
            fired.append(1)
            raise SimulatedNodeFailure("ICI link down")

    with tempfile.TemporaryDirectory() as d:
        r = _make_system(d, max_steps=12, failure_hook=bomb)
        state, _ = r.run()
        assert r.restarts == 1
    for a, b in zip(jax.tree.leaves(ref_state["params"]), jax.tree.leaves(state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpointed_opt_state_roundtrip_through_runner():
    with tempfile.TemporaryDirectory() as d:
        r = _make_system(d, max_steps=8)
        state, _ = r.run()
        assert int(state["opt"].step) == 8


def test_serve_path_end_to_end():
    """prefill -> N greedy decode steps with the jitted public API."""
    from repro.launch.steps import make_decode_step, make_prefill_step

    cfg = get_config("gemma3_12b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prefill = jax.jit(make_prefill_step(model, cache_len=64))
    decode = jax.jit(make_decode_step(model))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 20), 0, cfg.vocab_size)
    cache, last = prefill(params, {"tokens": toks})
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    seq = [tok]
    for _ in range(6):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        seq.append(tok)
    assert int(cache["pos"]) == 20 + 6
    assert all(bool(jnp.all(t < cfg.padded_vocab)) for t in seq)
