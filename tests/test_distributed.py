"""Multi-device tests — each runs in a subprocess with its own fake-device
count (jax pins the device count at first init, so the main pytest process
stays single-device)."""

import os
import subprocess
import sys
import textwrap

import pytest

# every test here boots a fresh jax in a subprocess (~30s+ each); keep them
# out of the CI fast lane (-m "not slow")
pytestmark = pytest.mark.slow

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def run_py(body: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """One train step on a 2x2 mesh == the same step on 1 device."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import Model, get_config
        from repro.models.sharding import use_rules, param_shardings
        from repro.launch.steps import init_train_state, make_train_step
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = get_config('qwen3_4b', smoke=True).replace(dtype='float32')
        m = Model(cfg)
        params, opt = init_train_state(m, jax.random.PRNGKey(0))
        k = jax.random.PRNGKey(1)
        batch = {'tokens': jax.random.randint(k, (4, 32), 0, cfg.vocab_size),
                 'labels': jax.random.randint(k, (4, 32), 0, cfg.vocab_size)}
        step = make_train_step(m)
        p_ref, _, met_ref = jax.jit(step)(params, opt, batch)

        mesh = jax.make_mesh((2, 2), ('data', 'model'))
        with use_rules(mesh):
            p_sh = param_shardings(params)
            params_s = jax.device_put(params, p_sh)
            batch_s = {k2: jax.device_put(v, NamedSharding(mesh, P('data',))) for k2, v in batch.items()}
            p_out, _, met = jax.jit(step)(params_s, opt, batch_s)
        d = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_out)))
        assert d < 2e-4, d
        assert abs(float(met['loss']) - float(met_ref['loss'])) < 1e-3
        print('OK', d)
    """)
    assert "OK" in out


def test_context_parallel_attention_matches_local():
    """shard_map seq-sharded attention == single-device attention."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import layers as L
        from repro.models.config import ModelConfig
        from repro.models.sharding import use_rules

        cfg = ModelConfig(d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
                          attn_chunk=16, dtype='float32')
        p = L.init_attention(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 64), jnp.float32)
        ref = L.attention_full(cfg, p, x, window=0)
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        with use_rules(mesh):
            out = jax.jit(lambda x: L.attention_full(cfg, p, x, window=0))(x)
        d = float(jnp.abs(ref - out).max())
        assert d < 1e-3, d
        # windowed variant too
        refw = L.attention_full(cfg, p, x, window=8)
        with use_rules(mesh):
            outw = jax.jit(lambda x: L.attention_full(cfg, p, x, window=8))(x)
        dw = float(jnp.abs(refw - outw).max())
        assert dw < 1e-3, dw
        print('OK', d, dw)
    """)
    assert "OK" in out


def test_moe_block_local_dispatch_sharded_matches():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.models import layers as L
        from repro.models.config import ModelConfig
        from repro.models.sharding import use_rules

        cfg = ModelConfig(family='moe', d_model=32, num_experts=8, top_k=2,
                          expert_d_ff=64, capacity_factor=2.0, dtype='float32')
        p = L.init_moe(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 32), jnp.float32)
        ref, aux_ref = L.moe(cfg, p, x)   # NB=1 path
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        with use_rules(mesh):
            out, aux = jax.jit(lambda x: L.moe(cfg, p, x))(x)
        # block-local capacity differs from global capacity only via drops;
        # capacity_factor=2 + small T means no drops -> exact match
        d = float(jnp.abs(ref - out).max())
        assert d < 2e-3, d
        print('OK', d)
    """)
    assert "OK" in out


def test_compressed_psum_multidevice():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import shard_map
        from repro.runtime import compressed_psum

        mesh = jax.make_mesh((4,), ('x',))
        gs = jax.random.normal(jax.random.PRNGKey(0), (4, 256), jnp.float32)

        def f(g):
            out, _ = compressed_psum(g[0], 'x')
            return out[None]

        out = jax.jit(shard_map(f, mesh=mesh, in_specs=(P('x'),), out_specs=P('x')))(gs)
        want = jnp.mean(gs, axis=0)
        err = float(jnp.abs(out[0] - want).max()) / (float(jnp.abs(want).max()) + 1e-9)
        assert err < 0.05, err
        print('OK', err)
    """)
    assert "OK" in out


def test_elastic_reshard_2x2_to_4x1():
    """Checkpoint on one mesh, restore on another; train continues."""
    out = run_py("""
        import tempfile, jax, jax.numpy as jnp
        from repro.models import Model, get_config
        from repro.models.sharding import use_rules, param_shardings
        from repro.launch.steps import init_train_state, make_train_step
        from repro.checkpoint import save_checkpoint, load_checkpoint
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = get_config('minicpm_2b', smoke=True)
        m = Model(cfg)
        params, opt = init_train_state(m, jax.random.PRNGKey(0))
        mesh1 = jax.make_mesh((2, 2), ('data', 'model'))
        with use_rules(mesh1):
            p1 = jax.device_put(params, param_shardings(params))
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 1, p1)
            mesh2 = jax.make_mesh((4, 1), ('data', 'model'))
            with use_rules(mesh2):
                sh2 = param_shardings(params)
                p2, _ = load_checkpoint(d, 1, params, shardings=sh2)
                k = jax.random.PRNGKey(1)
                batch = {'tokens': jax.random.randint(k, (4, 16), 0, cfg.vocab_size),
                         'labels': jax.random.randint(k, (4, 16), 0, cfg.vocab_size)}
                step = make_train_step(m)
                p3, o3, met = jax.jit(step)(p2, opt, batch)
        assert jnp.isfinite(met['loss'])
        print('OK', float(met['loss']))
    """)
    assert "OK" in out


def test_dryrun_single_cell_small_mesh():
    """The dry-run path end-to-end on an 8-device 4x2 production-mesh stand-in."""
    out = run_py("""
        import jax
        import repro.launch.mesh as mesh_mod
        mesh_mod.make_production_mesh = lambda multi_pod=False: (
            jax.make_mesh((2, 2, 2), ('pod', 'data', 'model')) if multi_pod
            else jax.make_mesh((4, 2), ('data', 'model')))
        import repro.launch.dryrun as dr
        dr.make_production_mesh = mesh_mod.make_production_mesh
        import repro.launch.specs as specs
        from repro.models.registry import get_config
        orig = specs.model_for_cell
        def small(arch, shape, **kw):
            kw.setdefault('overrides', None)
            model, cell = orig(arch, shape, **kw)
            from repro.models.transformer import Model
            import dataclasses
            cfg = get_config(arch, smoke=True)
            cell2 = dataclasses.replace(cell, seq_len=64, global_batch=8)
            return Model(cfg, remat='full'), cell2
        dr.model_for_cell = small
        for shape in ('train_4k', 'decode_32k'):
            for mp in (False, True):
                rec = dr.lower_cell('qwen3_4b', shape, multi_pod=mp)
                assert rec['hlo_flops'] > 0
        print('OK')
    """, devices=8)
    assert "OK" in out
