"""Fault-tolerant serving: preempt-and-replay determinism, lifecycle
hardening, and the fault-injection harness.

The contract under test extends the scheduler's determinism guarantee to
degraded operation: whatever faults strike mid-flight — injected KV
allocation failures, NaN-poisoned logits, forced preemptions, latency
spikes — the run must never crash, every request must end in a terminal
``RequestStatus``, and every *completed* stream must remain bitwise
identical to the uninterrupted clean run (greedy decode is a pure function
of the prefix, so replaying ``prompt + generated`` through prefill resumes
a preempted stream exactly).  The property sweep randomizes fault schedules
over all four fault classes; the deterministic tests pin each mechanism in
isolation.  Plan-cache load hardening (corrupt / future-schema quarantine)
rides along because it protects the same launch path.
"""

import json
import os

import jax
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core import load_or_autotune, model_gemms, save_plan
from repro.core.plan_cache import PLAN_CACHE_VERSION
from repro.launch.scheduler import (
    Request,
    RequestStatus,
    ServeScheduler,
    poisson_trace,
)
from repro.launch.serve import sequential_reference
from repro.models import Model, get_config
from repro.runtime import FaultPlan


_MODEL_CACHE: list = []


def _get_model():
    """Module-cached smoke model (plain function, not a fixture, so the
    @given property sweep can use it too — the _propcheck fallback hides
    test parameters from pytest's fixture resolution)."""
    if not _MODEL_CACHE:
        cfg = get_config("qwen3_4b", smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _MODEL_CACHE.append((cfg, model, params))
    return _MODEL_CACHE[0]


@pytest.fixture(scope="module")
def smoke_model():
    return _get_model()


def _trace(cfg, n=6, rate=0.0, seed=3, max_prompt=14, max_gen=6):
    return poisson_trace(n, vocab=cfg.vocab_size, max_prompt=max_prompt,
                         max_gen=max_gen, rate=rate, seed=seed)


def _sched(model, params, faults=None, **kw):
    kw.setdefault("capacity", 4)
    kw.setdefault("block_size", 16)
    kw.setdefault("max_total_len", 14 + 6)
    return ServeScheduler(model, params, faults=faults, **kw)


def _clean_run(model, params, trace, **kw):
    results, _ = _sched(model, params, **kw).run(
        [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new,
                 arrival=r.arrival) for r in trace])
    return results


# ---------------------------------------------------------------------------
# FaultPlan: the schedule itself is deterministic and seeded
# ---------------------------------------------------------------------------


def test_fault_plan_spec_roundtrip_and_determinism():
    fp = FaultPlan.from_spec("alloc=0.1,nan=0.02,preempt=0.05,latency=0.5,seed=7")
    assert (fp.alloc_fail, fp.nan, fp.preempt, fp.latency, fp.seed) == \
        (0.1, 0.02, 0.05, 0.5, 7)
    draws = [(fp.fail_alloc(2), fp.pick_poison(s, 4), fp.pick_preempt(s, 4),
              fp.spike()) for s in range(64)]
    fp.reset()
    replay = [(fp.fail_alloc(2), fp.pick_poison(s, 4), fp.pick_preempt(s, 4),
               fp.spike()) for s in range(64)]
    assert draws == replay, "same seed must reproduce the same schedule"
    assert fp.total_injected > 0
    assert set(fp.injected) == {"alloc", "nan", "preempt", "latency"}
    with pytest.raises(ValueError):
        FaultPlan.from_spec("bogus=1")


def test_fault_plan_explicit_events():
    fp = FaultPlan(alloc_fail_at=(0, 2), poison_at=((5, 1),),
                   preempt_at=((7, 0),))
    assert fp.fail_alloc(1) and not fp.fail_alloc(1) and fp.fail_alloc(1)
    assert fp.pick_poison(4, 4) is None
    assert fp.pick_poison(5, 4) == 1
    assert fp.pick_poison(5, 1) is None  # row out of range: no-op
    assert fp.pick_preempt(7, 2) == 0
    assert fp.injected["alloc"] == 2 and fp.injected["nan"] == 1


# ---------------------------------------------------------------------------
# preempt-and-replay: deterministic resume
# ---------------------------------------------------------------------------


def test_preempt_replay_is_bitwise_deterministic(smoke_model):
    """A forced preemption mid-decode frees the victim's blocks, re-queues
    it carrying its generated-so-far tokens, and the resumed stream is
    bitwise identical to the uninterrupted run."""
    cfg, model, params = smoke_model
    trace = _trace(cfg)  # rate=0: decode steps are contiguous from 0
    clean = _clean_run(model, params, trace)
    faults = FaultPlan(preempt_at=((2, 0), (4, 1)))
    sched = _sched(model, params, faults=faults)
    results, stats = sched.run(trace)
    assert stats.preemptions >= 1 and stats.replays == stats.preemptions
    assert stats.faults_injected["preempt"] == stats.preemptions
    resumed = [rid for rid, r in results.items()
               if r.status is RequestStatus.PREEMPTED_RESUMED]
    assert resumed, "at least one request must have been preempted"
    for r in trace:
        got = results[r.rid]
        assert got.status.completed
        assert len(got.tokens) == r.max_new
        np.testing.assert_array_equal(got.tokens, clean[r.rid].tokens)
    for rid in resumed:
        assert results[rid].preemptions >= 1
    assert sched.kv.allocator.live_blocks == 0


def test_injected_alloc_faults_degrade_to_waiting(smoke_model):
    """Injected KV-allocation failures ride the organic exhaustion path:
    admission FIFO-waits and retries, every stream still completes and
    matches the clean run bitwise."""
    cfg, model, params = smoke_model
    trace = _trace(cfg, seed=5)
    clean = _clean_run(model, params, trace)
    faults = FaultPlan(alloc_fail=0.5, seed=2)
    sched = _sched(model, params, faults=faults)
    results, stats = sched.run(trace)
    assert stats.faults_injected["alloc"] >= 1
    for r in trace:
        assert results[r.rid].status.completed
        np.testing.assert_array_equal(results[r.rid].tokens,
                                      clean[r.rid].tokens)
    assert sched.kv.allocator.live_blocks == 0


# ---------------------------------------------------------------------------
# non-finite-logit guard: fail the slot, not the batch
# ---------------------------------------------------------------------------


def test_nan_poison_fails_only_the_poisoned_slot(smoke_model):
    cfg, model, params = smoke_model
    trace = _trace(cfg)
    clean = _clean_run(model, params, trace)
    faults = FaultPlan(poison_at=((1, 0),))
    sched = _sched(model, params, faults=faults)
    results, stats = sched.run(trace)
    assert stats.faults_injected["nan"] == 1
    failed = [rid for rid, r in results.items()
              if r.status is RequestStatus.FAILED]
    assert len(failed) == 1 and stats.failures == 1
    bad = results[failed[0]]
    if bad.tokens is not None:
        # the surviving prefix is the clean stream truncated at the poison
        n = len(bad.tokens)
        assert n < len(clean[failed[0]].tokens)
        np.testing.assert_array_equal(bad.tokens,
                                      clean[failed[0]].tokens[:n])
    for rid, r in results.items():
        if rid == failed[0]:
            continue
        assert r.status is RequestStatus.OK
        np.testing.assert_array_equal(r.tokens, clean[rid].tokens)
    assert sched.kv.allocator.live_blocks == 0


# ---------------------------------------------------------------------------
# lifecycle hardening: rejection, load-shed, deadlines
# ---------------------------------------------------------------------------


def test_oversized_request_rejected_among_normal_traffic(smoke_model):
    """One inadmissible request in a normal trace: it alone is REJECTED,
    every neighbor completes bitwise identical to a run without it."""
    cfg, model, params = smoke_model
    trace = _trace(cfg, n=4)
    clean = _clean_run(model, params, trace)
    # needs 3 blocks (33 positions) against a 2-block table: inadmissible
    huge = Request(rid=99, prompt=np.zeros(28, np.int32), max_new=6)
    mixed = trace[:2] + [huge] + trace[2:]
    results, stats = _sched(model, params).run(mixed)
    assert results[99].status is RequestStatus.REJECTED
    assert results[99].tokens is None
    assert stats.rejections == 1
    for r in trace:
        assert results[r.rid].status is RequestStatus.OK
        np.testing.assert_array_equal(results[r.rid].tokens,
                                      clean[r.rid].tokens)


def test_max_queue_load_sheds_newest_arrival(smoke_model):
    """With capacity 1 and max_queue 1, a burst of 5 simultaneous arrivals
    keeps the head of the queue and sheds from the back — the shed
    requests get REJECTED, survivors complete correctly."""
    cfg, model, params = smoke_model
    trace = _trace(cfg, n=5)
    results, stats = _sched(model, params, capacity=1, max_queue=1).run(trace)
    shed = [rid for rid, r in results.items()
            if r.status is RequestStatus.REJECTED]
    done = [rid for rid, r in results.items() if r.status.completed]
    assert stats.rejections == len(shed) >= 1
    assert len(done) + len(shed) == len(trace)
    # FIFO: the shed set is a suffix of the arrival order
    assert sorted(shed) == [r.rid for r in trace][-len(shed):]
    ref = sequential_reference(
        model, params, [r for r in trace if r.rid in done],
        _sched(model, params).max_blocks * 16)
    for rid in done:
        np.testing.assert_array_equal(results[rid].tokens, ref[rid])


def test_deadline_times_out_queued_requests(smoke_model):
    """A tiny block pool makes later arrivals queue behind long decodes;
    with a 1-step TTL they TIMEOUT instead of waiting forever.  Without a
    deadline the same trace fully completes (the TTL is the only cause)."""
    cfg, model, params = smoke_model
    trace = _trace(cfg)
    no_ttl, _ = _sched(model, params, capacity=8, num_blocks=3).run(trace)
    assert all(r.status.completed for r in no_ttl.values())
    results, stats = _sched(model, params, capacity=8, num_blocks=3,
                            deadline=1).run(trace)
    timed_out = [rid for rid, r in results.items()
                 if r.status is RequestStatus.TIMEOUT]
    assert stats.timeouts == len(timed_out) >= 1
    for rid, r in results.items():
        if rid in timed_out:
            assert r.tokens is None
        else:
            assert r.status.completed
            np.testing.assert_array_equal(r.tokens, no_ttl[rid].tokens)


def test_per_request_deadline_overrides_scheduler_default(smoke_model):
    cfg, model, params = smoke_model
    trace = _trace(cfg)
    # generous default, but one request insists on an impossible TTL while
    # the pool is busy — only it times out
    patient = [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new,
                       deadline=1 if i == len(trace) - 1 else None)
               for i, r in enumerate(trace)]
    results, stats = _sched(model, params, capacity=8, num_blocks=3,
                            deadline=10_000).run(patient)
    assert results[trace[-1].rid].status is RequestStatus.TIMEOUT
    assert stats.timeouts == 1


# ---------------------------------------------------------------------------
# the property sweep: randomized fault schedules never break the contract
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(fault_seed=st.integers(min_value=0, max_value=10_000),
       trace_seed=st.integers(min_value=0, max_value=99),
       heavy=st.booleans())
def test_scheduler_survives_randomized_fault_schedules(
        fault_seed, trace_seed, heavy):
    """Any seeded mix of alloc failures, NaN poison, preemptions and
    latency spikes: no crash, every request terminal, allocator fully
    restored, and every completed stream bitwise equals the clean run."""
    cfg, model, params = _get_model()
    trace = _trace(cfg, rate=0.5, seed=trace_seed)
    clean = _clean_run(model, params, trace)
    scale = 2.0 if heavy else 1.0
    faults = FaultPlan(seed=fault_seed, alloc_fail=0.15 * scale,
                       nan=0.02 * scale, preempt=0.04 * scale,
                       latency=0.05, latency_s=1e-5)
    sched = _sched(model, params, faults=faults, deadline=10_000)
    results, stats = sched.run(trace)

    assert set(results) == {r.rid for r in trace}, "every request terminal"
    assert sched.kv.allocator.live_blocks == 0, "allocator restored"
    for r in trace:
        got = results[r.rid]
        assert isinstance(got.status, RequestStatus)
        if got.status.completed:
            assert len(got.tokens) == r.max_new
            np.testing.assert_array_equal(got.tokens, clean[r.rid].tokens)
        elif got.tokens is not None:  # FAILED with a partial stream
            np.testing.assert_array_equal(
                got.tokens, clean[r.rid].tokens[:len(got.tokens)])
    assert stats.failures == sum(
        1 for r in results.values() if r.status is RequestStatus.FAILED)
    assert stats.replays == stats.preemptions
    assert stats.faults_injected == faults.injected


def test_fault_run_is_reproducible(smoke_model):
    """The same trace + the same FaultPlan seed → identical statuses,
    streams and injection counters across runs."""
    cfg, model, params = smoke_model
    trace = _trace(cfg, rate=0.5, seed=8)

    def go():
        faults = FaultPlan(seed=13, alloc_fail=0.2, nan=0.03, preempt=0.06)
        sched = _sched(model, params, faults=faults, deadline=10_000)
        results, stats = sched.run(
            [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new,
                     arrival=r.arrival) for r in trace])
        return results, stats

    a, sa = go()
    b, sb = go()
    assert sa.faults_injected == sb.faults_injected
    assert (sa.preemptions, sa.timeouts, sa.failures) == \
        (sb.preemptions, sb.timeouts, sb.failures)
    for rid in a:
        assert a[rid].status is b[rid].status
        if a[rid].tokens is None:
            assert b[rid].tokens is None
        else:
            np.testing.assert_array_equal(a[rid].tokens, b[rid].tokens)


# ---------------------------------------------------------------------------
# plan-cache load hardening: quarantine, don't crash
# ---------------------------------------------------------------------------

GEMMS = lambda cfg: model_gemms(cfg, tokens=64)  # noqa: E731


def test_corrupt_plan_cache_is_quarantined_and_retuned(tmp_path):
    cfg = get_config("qwen3_4b", smoke=True).replace(use_pallas=True)
    path = os.path.join(tmp_path, "plan.json")
    with open(path, "w") as f:
        f.write('{"version": 8, "layers": [truncated garbage')
    plan, loaded = load_or_autotune(path, GEMMS(cfg), measure=False)
    assert not loaded, "a corrupt cache must re-tune, not crash"
    assert os.path.exists(path + ".corrupt"), "evidence preserved"
    with open(path) as f:
        assert json.load(f)["version"] == PLAN_CACHE_VERSION  # fresh plan
    again, loaded = load_or_autotune(path, GEMMS(cfg), measure=False)
    assert loaded, "the re-tuned cache reloads cleanly next launch"


def test_future_schema_plan_cache_is_quarantined(tmp_path):
    """A cache written by a newer build (future schema version) is
    quarantined and re-tuned — a rollback must not kill the launch."""
    cfg = get_config("qwen3_4b", smoke=True).replace(use_pallas=True)
    from repro.core import autotune_plan

    plan = autotune_plan(GEMMS(cfg), measure=False)
    path = os.path.join(tmp_path, "plan.json")
    save_plan(path, plan)
    with open(path) as f:
        payload = json.load(f)
    payload["version"] = 99
    with open(path, "w") as f:
        json.dump(payload, f)
    plan2, loaded = load_or_autotune(path, GEMMS(cfg), measure=False)
    assert not loaded
    assert os.path.exists(path + ".corrupt")
    with open(path + ".corrupt") as f:
        assert json.load(f)["version"] == 99  # original preserved verbatim
    with open(path) as f:
        assert json.load(f)["version"] == PLAN_CACHE_VERSION
