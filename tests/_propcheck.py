"""Property-testing shim: real hypothesis when installed, else a small
deterministic example grid.

The test suite uses a narrow slice of the hypothesis API — ``given``,
``settings``, ``st.integers``, ``st.sampled_from``, ``st.booleans``.  In
offline environments where hypothesis can't be installed, this module
provides drop-in replacements that expand each ``@given`` into a fixed,
deterministic set of examples: the strategy's boundary values first, then
seeded-PRNG interior draws (seeded per test name, so failures reproduce).

Usage in test modules (replaces ``from hypothesis import given, settings``
and ``import hypothesis.strategies as st``):

    from _propcheck import given, settings, st
"""

from __future__ import annotations

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # offline fallback
    import functools
    import inspect
    import os
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    # Examples per @given in fallback mode.  Enough to cover boundaries plus
    # a few interior points without turning interpret-mode kernel sweeps
    # into minutes; raise via env for a more thorough local run.
    _DEFAULT_EXAMPLES = int(os.environ.get("PROPCHECK_EXAMPLES", "8"))

    class _Strategy:
        """A value source: boundary examples + seeded random draws."""

        def __init__(self, boundary, draw):
            self._boundary = list(boundary)
            self._draw = draw

        def examples(self, rng: random.Random, n: int) -> list:
            out = self._boundary[:n]
            while len(out) < n:
                out.append(self._draw(rng))
            return out

    class st:  # noqa: N801 — mimics the hypothesis.strategies module name
        @staticmethod
        def integers(min_value=0, max_value=2**31 - 1):
            lo, hi = int(min_value), int(max_value)
            mid = (lo + hi) // 2
            return _Strategy([lo, hi, mid], lambda rng: rng.randint(lo, hi))

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(elems, lambda rng: rng.choice(elems))

        @staticmethod
        def booleans():
            return _Strategy([False, True], lambda rng: rng.random() < 0.5)

    def settings(max_examples: int | None = None, deadline=None, **_kw):
        """Records the example budget for the enclosing @given."""

        def deco(fn):
            fn._propcheck_settings = {"max_examples": max_examples}
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*wargs, **wkw):
                cfg = getattr(wrapper, "_propcheck_settings", None) or getattr(
                    fn, "_propcheck_settings", {}
                )
                n = min(
                    cfg.get("max_examples") or _DEFAULT_EXAMPLES, _DEFAULT_EXAMPLES
                )
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                pos_grids = [s.examples(rng, n) for s in arg_strategies]
                kw_grids = {k: s.examples(rng, n) for k, s in kw_strategies.items()}
                for i in range(n):
                    pos = [g[i] for g in pos_grids]
                    kws = {k: g[i] for k, g in kw_grids.items()}
                    fn(*wargs, *pos, **kws, **wkw)

            # hide the strategy-filled params from pytest's fixture
            # resolution (real hypothesis does the same)
            wrapper.__signature__ = inspect.Signature()
            del wrapper.__wrapped__
            return wrapper

        return deco
