"""Flex attention schedule family: the property sweep that keeps a
multi-variant kernel family honest.

Pins four contracts:

  * **Value contract** — every (sweep, block, causal, GQA group, ragged
    length, dtype) point matches the jnp oracle, and the two sweep orders
    agree *bitwise* at a fixed effective geometry: both kernels run the
    identical ``_online_update`` op sequence, so changing the sweep (like
    changing a GEMM dataflow) may change traffic but never bits.
  * **Residency contract** — a jaxpr regression pins that the kv-stationary
    path materializes no (rows, Skv) score tile in HBM; scores only ever
    exist as (bq, bk) VMEM blocks.
  * **Planning contract** — fake-timer CMU tests: the measured ranking (not
    the analytical model) picks the prefill schedule and the per-bucket
    decode kind, mirroring ``test_serving.test_bucket_tuning_is_
    measurement_driven``.
  * **Schema contract** — v6 plan caches load with ``attention=None`` and
    upgrade incrementally: every GEMM/decode/mesh decision survives
    verbatim, and the file re-persists as v7.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core import (
    attn_traffic_bytes,
    autotune_plan,
    hbm_traffic_bytes,
    load_or_autotune,
    load_plan,
    model_attn_shape,
    model_epilogues,
    model_gemms,
    plan_matches,
    save_plan,
)
from repro.core.plan_cache import PLAN_CACHE_VERSION
from repro.core import cmu as cmu_mod
from repro.kernels import (
    ATTN_SWEEPS,
    attention_ref,
    flex_attention,
    mha_flash,
    paged_attention,
    paged_attention_reference,
)
from repro.models import get_config

RNG = np.random.default_rng(7)


def _qkv(B, S, H, Hkv, hd, dtype=jnp.float32, skv=None):
    skv = skv or S
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, skv, Hkv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, skv, Hkv, hd)), dtype)
    return q, k, v


def _bits(x) -> bytes:
    return np.asarray(x).tobytes()


# ---------------------------------------------------------------------------
# property sweep: schedule variant x causal x GQA group x ragged length x dtype
# ---------------------------------------------------------------------------


@given(
    causal=st.booleans(),
    group=st.sampled_from([1, 2, 4]),
    seq=st.sampled_from([40, 56, 64, 120, 128]),
    dtype_name=st.sampled_from(["float32", "bfloat16"]),
)
@settings(max_examples=10, deadline=None)
def test_schedule_family_property_sweep(causal, group, seq, dtype_name):
    """Every schedule point matches the oracle; the two sweep orders agree
    bitwise (same effective blocks -> same op sequence -> same bits)."""
    dtype = jnp.dtype(dtype_name)
    Hkv = 2
    q, k, v = _qkv(1, seq, Hkv * group, Hkv, 32, dtype)
    outs = {
        sweep: mha_flash(q, k, v, causal=causal, interpret=True, sweep=sweep)
        for sweep in ATTN_SWEEPS
    }
    ref = attention_ref(q, k, v, causal=causal)
    atol = 2e-5 if dtype == jnp.float32 else 0.06
    for sweep, out in outs.items():
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=atol, rtol=atol, err_msg=f"sweep={sweep}")
    assert _bits(outs["q"]) == _bits(outs["kv"]), \
        "sweep order changed the bits: the variants diverged"


@given(bq=st.sampled_from([64, 128, 256]), bk=st.sampled_from([64, 128]))
@settings(max_examples=6, deadline=None)
def test_sweep_orders_agree_bitwise_per_block_shape(bq, bk):
    """At every (bq, bk) schedule knob setting the q- and kv-stationary
    kernels are bit-identical — the dataflow guarantee, attention edition."""
    q, k, v = _qkv(2, 256, 4, 2, 32)
    a = mha_flash(q, k, v, causal=True, interpret=True, block_q=bq,
                  block_k=bk, sweep="q")
    b = mha_flash(q, k, v, causal=True, interpret=True, block_q=bq,
                  block_k=bk, sweep="kv")
    assert _bits(a) == _bits(b)


def test_cross_attention_and_gqa_fold_shapes():
    """The GQA fold round-trips: output layout matches the oracle exactly
    for a non-causal cross-attention shape (longer KV, 4:1 group)."""
    q, k, v = _qkv(1, 96, 8, 2, 32, skv=160)
    out = mha_flash(q, k, v, causal=False, interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# jaxpr regression: kv-stationary never materializes an HBM score tile
# ---------------------------------------------------------------------------


def _all_avals(jaxpr, acc):
    for eqn in jaxpr.eqns:
        acc.extend(v.aval for v in eqn.outvars)
        for val in eqn.params.values():
            for sub in _iter_jaxprs(val):
                _all_avals(sub, acc)
    return acc


def _iter_jaxprs(val):
    if isinstance(val, jax.core.ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, jax.core.Jaxpr):
        yield val
    elif isinstance(val, (list, tuple)):
        for item in val:
            yield from _iter_jaxprs(item)


def _has_score_matrix(fn, *args, S):
    avals = _all_avals(jax.make_jaxpr(fn)(*args).jaxpr, [])
    return any(
        getattr(a, "ndim", 0) >= 2 and a.shape[-1] == S and a.shape[-2] == S
        for a in avals)


def test_kv_stationary_materializes_no_score_tiles():
    """No intermediate anywhere in the jaxpr has a (rows, Skv) score shape:
    scores exist only as (bq, bk) VMEM tiles inside the kernel.  The jnp
    oracle (positive control) does materialize one."""
    S, hd = 256, 32
    q = jnp.zeros((4, S, hd), jnp.float32)
    kv = jnp.zeros((4, S, hd), jnp.float32)

    flex = lambda q, k, v: flex_attention(q, k, v, sweep="kv", causal=True,
                                          interpret=True)
    assert not _has_score_matrix(flex, q, kv, kv, S=S)

    q4 = jnp.zeros((1, S, 4, hd), jnp.float32)
    ref = lambda q, k, v: attention_ref(q, k, v, causal=True)
    assert _has_score_matrix(ref, q4, q4, q4, S=S), \
        "positive control failed: the detector no longer sees score tiles"


# ---------------------------------------------------------------------------
# paged decode kernel vs the gather oracle
# ---------------------------------------------------------------------------


def _paged_case(B=3, H=4, Hkv=2, hd=32, bs=16, nb=4, seed=0):
    rng = np.random.default_rng(seed)
    num_blocks = 1 + B * nb
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    pk = jnp.asarray(rng.normal(size=(num_blocks, bs, Hkv, hd)), jnp.float32)
    pv = jnp.asarray(rng.normal(size=(num_blocks, bs, Hkv, hd)), jnp.float32)
    table = 1 + jnp.arange(B * nb, dtype=jnp.int32).reshape(B, nb)
    # ragged positions: each slot at a different depth, none block-aligned
    positions = jnp.asarray([bs * nb - 1, 5, 2 * bs + 3][:B], jnp.int32)
    return q, pk, pv, table, positions


def test_paged_decode_matches_reference():
    q, pk, pv, table, positions = _paged_case()
    out = paged_attention(q, pk, pv, table, positions, interpret=True)
    ref = paged_attention_reference(q, pk, pv, table, positions)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_paged_decode_sliding_window_fully_masked_blocks():
    """Masking-contract regression: with a sliding window deep into the
    cache, *whole leading K/V blocks* are masked.  The kernel must zero
    those probabilities multiplicatively — additive -1e30 bias alone leaves
    exp(s - m) == 1 per masked key when a block is fully dead, which
    silently averages garbage into the output."""
    q, pk, pv, table, positions = _paged_case()
    positions = jnp.full_like(positions, 16 * 4 - 1)  # deepest slot depth
    out = paged_attention(q, pk, pv, table, positions, window=8,
                          interpret=True)
    ref = paged_attention_reference(q, pk, pv, table, positions, window=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_paged_decode_live_slots_invariant_to_pad_rows():
    """The scheduler's bucket-padding guarantee, kernel edition: a pad row
    (table all-scratch, position 0) never perturbs live rows' outputs, no
    matter what garbage sits in the scratch block."""
    q, pk, pv, table, positions = _paged_case(B=3)
    # slot 2 becomes a pad row: scratch table, position 0
    table = table.at[2].set(0)
    positions = positions.at[2].set(0)
    out_a = paged_attention(q, pk, pv, table, positions, interpret=True)
    pk_b = pk.at[0].set(1e3)  # rewrite scratch with large garbage
    pv_b = pv.at[0].set(-1e3)
    out_b = paged_attention(q, pk_b, pv_b, table, positions, interpret=True)
    assert _bits(out_a[:2]) == _bits(out_b[:2]), \
        "scratch-block contents leaked into live slots"


# ---------------------------------------------------------------------------
# CMU planning: fake-timer tests + v6 -> v7 migration
# ---------------------------------------------------------------------------


CFG = lambda: get_config("qwen3_4b", smoke=True).replace(  # noqa: E731
    use_pallas=True, attn_pallas=True)
GEMMS = lambda cfg: model_gemms(cfg, tokens=64)  # noqa: E731


def _fast_gemm_timer(monkeypatch):
    """Route GEMM measurement through the analytical model so the attention
    planning tests don't spend their budget timing projection kernels."""
    monkeypatch.setattr(
        cmu_mod, "measure_kernel",
        lambda gemm, df, blk, **kw: hbm_traffic_bytes(gemm, df, *blk).time_s())


def test_attention_tuning_is_measurement_driven(monkeypatch):
    """Under a fake timer that penalizes whatever schedule the analytical
    model ranks first, the measured plan lands on a different (sweep,
    block) — the schedule comes from the timed execution, not the ranking."""
    cfg = CFG()
    attn = model_attn_shape(cfg, 64)
    analytic = autotune_plan(GEMMS(cfg), measure=False, attn=attn)
    ap0 = analytic.attention_plan()
    assert ap0 is not None and ap0.source == "analytical"
    pick = (ap0.sweep, ap0.block)

    def fake(shape, sweep, block, **kw):
        base = attn_traffic_bytes(shape, sweep, *block).time_s()
        return base * 100.0 if (sweep, tuple(block)) == pick else base

    _fast_gemm_timer(monkeypatch)
    monkeypatch.setattr(cmu_mod, "measure_attention", fake)
    plan = autotune_plan(GEMMS(cfg), measure=True, iters=1, attn=attn)
    ap = plan.attention_plan()
    assert ap is not None and ap.source == "measured"
    assert (ap.sweep, ap.block) != pick, \
        "measured tuning returned the penalized analytical pick"


@pytest.mark.parametrize("slow", ["paged", "gather"])
def test_attn_decode_kind_is_measurement_driven(monkeypatch, slow):
    """Per-bucket decode-kind choice follows the fake timer both ways:
    penalize 'paged' and the plan picks 'gather', and vice versa."""
    cfg = CFG()
    attn = model_attn_shape(cfg, 64)
    fast = {"paged": "gather", "gather": "paged"}[slow]

    def fake_decode(shape, bucket, kind, **kw):
        return 1.0 if kind == slow else 1e-6

    _fast_gemm_timer(monkeypatch)
    monkeypatch.setattr(
        cmu_mod, "measure_attention",
        lambda shape, sweep, block, **kw:
            attn_traffic_bytes(shape, sweep, *block).time_s())
    monkeypatch.setattr(cmu_mod, "measure_attention_decode", fake_decode)
    plan = autotune_plan(GEMMS(cfg), measure=True, iters=1, attn=attn,
                         decode_buckets=(8, 16))
    ap = plan.attention_plan()
    assert ap is not None and set(ap.decode) == {8, 16}
    for b, sub in ap.decode.items():
        assert sub.sweep == fast, (b, sub)
        assert sub.source == "measured"


def test_v6_cache_loads_with_attention_none_and_upgrades(tmp_path):
    """A v6 file (no attention rows) loads with attention=None; an
    attention-requesting load_or_autotune upgrades it incrementally — every
    GEMM, decode and mesh decision survives verbatim, only the attention
    schedule is tuned, and the file re-persists as v7."""
    cfg = CFG()
    attn = model_attn_shape(cfg, 64)
    plan = autotune_plan(GEMMS(cfg), measure=False, decode_buckets=(8,),
                         epilogue=model_epilogues(cfg))
    path = os.path.join(tmp_path, "plan.json")
    save_plan(path, plan)
    with open(path) as f:
        payload = json.load(f)
    payload["version"] = 6
    for row in payload["layers"]:
        row.pop("attention", None)
    with open(path, "w") as f:
        json.dump(payload, f)

    v6 = load_plan(path)
    assert all(lp.attention is None for lp in v6.layers)
    assert plan_matches(v6, GEMMS(cfg), buckets=(8,))  # attention-less: fine
    assert not plan_matches(v6, GEMMS(cfg), buckets=(8,), attn=attn)

    before = {
        lp.name: (lp.dataflow, lp.block, lp.strip, lp.bwd_dx, lp.bwd_dw,
                  lp.mesh, lp.decode)
        for lp in v6.layers
    }
    up, loaded = load_or_autotune(path, GEMMS(cfg), buckets=(8,), attn=attn,
                                  measure=False,
                                  epilogue=model_epilogues(cfg))
    assert not loaded  # it had to tune (the attention row)
    assert up.has_attention((8,))
    ap = up.attention_plan()
    assert ap is not None and ap.sweep in ATTN_SWEEPS and 8 in ap.decode
    for lp in up.layers:
        assert (lp.dataflow, lp.block, lp.strip, lp.bwd_dx, lp.bwd_dw,
                lp.mesh, lp.decode) == before[lp.name], \
            f"incremental attention upgrade retuned {lp.name}"
    with open(path) as f:
        assert json.load(f)["version"] == PLAN_CACHE_VERSION
    again, loaded = load_or_autotune(path, GEMMS(cfg), buckets=(8,),
                                     attn=attn, measure=False)
    assert loaded  # second launch reloads, no tuning
