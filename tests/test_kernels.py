"""Pallas flex-matmul kernels vs the pure-jnp oracle (interpret=True on CPU).

Sweeps shapes x dtypes x dataflows per the deliverable spec; hypothesis
drives random rectangular shapes including non-block-multiples (the ops.py
wrapper pads).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core import ALL_DATAFLOWS, Dataflow, GemmShape, best_kernel_dataflow
from repro.kernels import (
    blocked_matmul_ref,
    flex_matmul,
    matmul_is,
    matmul_os,
    matmul_ref,
    matmul_ws,
)

RNG = np.random.default_rng(42)

def _rand(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)

SHAPES = [
    (128, 128, 128),
    (256, 256, 256),
    (256, 512, 128),
    (512, 128, 384),
    (384, 384, 384),
]

@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("df", ALL_DATAFLOWS)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_oracle(shape, df, dtype):
    M, K, N = shape
    a, b = _rand((M, K), dtype), _rand((K, N), dtype)
    ref = matmul_ref(a, b)
    out = flex_matmul(a, b, dataflow=df, block=(128, 128, 128), interpret=True)
    tol = 1e-4 if dtype == jnp.float32 else 0.35
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )

@pytest.mark.parametrize("df", ALL_DATAFLOWS)
def test_raw_kernels_divisible_shapes(df):
    fn = {Dataflow.OS: matmul_os, Dataflow.WS: matmul_ws, Dataflow.IS: matmul_is}[df]
    a, b = _rand((256, 384), jnp.float32), _rand((384, 256), jnp.float32)
    out = fn(a, b, block=(128, 128, 128), interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(matmul_ref(a, b)), atol=1e-4, rtol=1e-4
    )

@given(
    M=st.integers(1, 300),
    K=st.integers(1, 300),
    N=st.integers(1, 300),
    df=st.sampled_from(list(ALL_DATAFLOWS)),
)
@settings(max_examples=25, deadline=None)
def test_padded_arbitrary_shapes(M, K, N, df):
    a, b = _rand((M, K), jnp.float32), _rand((K, N), jnp.float32)
    out = flex_matmul(a, b, dataflow=df, block=(128, 128, 128), interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(matmul_ref(a, b)), atol=1e-3, rtol=1e-3
    )

def test_all_dataflows_bitwise_equal_f32():
    """Same math, same accumulation order over k-blocks -> identical results."""
    a, b = _rand((256, 256), jnp.float32), _rand((256, 256), jnp.float32)
    outs = [
        np.asarray(flex_matmul(a, b, dataflow=df, block=(128, 128, 128), interpret=True))
        for df in ALL_DATAFLOWS
    ]
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])

def test_blocked_oracle_agrees():
    a, b = _rand((256, 384), jnp.float32), _rand((384, 128), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(blocked_matmul_ref(a, b, 128, 128, 128)),
        np.asarray(matmul_ref(a, b)),
        atol=1e-4, rtol=1e-4,
    )

def test_cmu_dispatch_is_shape_static():
    """auto_matmul picks the same dataflow the CMU cost model picks."""
    from repro.kernels.ops import auto_matmul

    a, b = _rand((128, 256), jnp.float32), _rand((256, 128), jnp.float32)
    out = auto_matmul(a, b, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(matmul_ref(a, b)), atol=1e-4, rtol=1e-4
    )
    df, _ = best_kernel_dataflow(GemmShape(128, 256, 128))
    assert df in ALL_DATAFLOWS
