"""Fast-lane smoke: benchmarks/train_step.py --dry-run must stay green.

The dry run asserts fwd+bwd gradient correctness of the flex-kernel train
step against the XLA reference on tiny shapes, so this doubles as an
end-to-end check of the custom VJP + train-plan wiring from the benchmark's
angle (plan -> bwd_dx/bwd_dw specs -> value_and_grad).
"""

import json
import os
import runpy
import sys

BENCH = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "train_step.py")


def test_train_step_benchmark_dry_run(monkeypatch, capsys, tmp_path):
    out_json = str(tmp_path / "bench.json")
    monkeypatch.setattr(sys, "argv", [BENCH, "--dry-run", "--json", out_json])
    runpy.run_path(BENCH, run_name="__main__")
    out = capsys.readouterr().out
    assert "gradients match the XLA reference" in out
    assert "strip schedules bit-identical to streamed" in out
    assert "traffic model OK" in out
    assert "dry-run OK" in out
    with open(out_json) as f:
        record = json.load(f)
    assert set(record["walltime_s"]) == {"pallas", "pallas_streamed",
                                         "pallas_copy_bwd", "xla"}
    # the copy path must be charged its transpose round-trip in the estimate
    est = record["hbm_bytes_est"]
    assert est["bwd_via_copy"] > est["bwd_transpose_free"] > 0
    # the streamed schedules must be charged their partial-sum round-trips
    assert est["forced_streamed"] >= est["plan_strips"] > 0
    for layer in record["layers"]:
        assert "trans" in layer["dx"] and "trans" in layer["dw"]
        assert "strip" in layer["fwd"] and "strip" in layer["dx"]


def test_checked_in_bench_baseline_is_consistent():
    """BENCH_train_step.json (the trajectory baseline) stays parseable and
    structurally in sync with what --json emits today."""
    path = os.path.join(os.path.dirname(BENCH), "BENCH_train_step.json")
    with open(path) as f:
        record = json.load(f)
    assert record["config"]["interpret"] is True
    est = record["hbm_bytes_est"]
    assert est["bwd_via_copy"] > est["bwd_transpose_free"] > 0
    for layer in record["layers"]:
        assert set(layer) == {"name", "gemm", "fwd", "dx", "dw"}
