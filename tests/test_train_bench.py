"""Fast-lane smoke: benchmarks/train_step.py --dry-run must stay green.

The dry run asserts fwd+bwd gradient correctness of the flex-kernel train
step against the XLA reference on tiny shapes, so this doubles as an
end-to-end check of the custom VJP + train-plan wiring from the benchmark's
angle (plan -> bwd_dx/bwd_dw specs -> value_and_grad).
"""

import os
import runpy
import sys

BENCH = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "train_step.py")


def test_train_step_benchmark_dry_run(monkeypatch, capsys):
    monkeypatch.setattr(sys, "argv", [BENCH, "--dry-run"])
    runpy.run_path(BENCH, run_name="__main__")
    out = capsys.readouterr().out
    assert "gradients match the XLA reference" in out
    assert "dry-run OK" in out
