"""Training-grade flex kernels: custom VJP + grouped fwd/bwd CMU plans.

The PR's acceptance bar: ``jax.grad`` through ``flex_linear`` must match the
reference path to fp32 tolerance for all three dataflows x (bias,
relu/gelu/silu, residual) combinations; a train plan must carry distinct
fwd/bwd sub-plans when the tuner ranks them as such; and an old-version
plan-cache file must load (or be rejected with a clear re-tune message)
rather than crash.
"""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ALL_DATAFLOWS,
    Dataflow,
    GemmShape,
    activate_plan,
    autotune_plan,
    bwd_gemms,
    load_or_autotune,
    load_plan,
    model_gemms,
    save_plan,
)
from repro.kernels import flex_linear, flex_matmul, linear_ref

RNG = np.random.default_rng(11)


def _rand(shape, dtype=jnp.float32, scale=0.2):
    return jnp.asarray(RNG.normal(size=shape) * scale, np.float32).astype(dtype)


def _grads(fn, *args):
    return jax.grad(fn, argnums=tuple(range(len(args))))(*args)


def _assert_close(got, want, tol=2e-4):
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# gradient correctness vs the reference path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("df", ALL_DATAFLOWS)
@pytest.mark.parametrize("activation", [None, "relu", "gelu", "silu"])
def test_linear_grads_match_ref_all_dataflows(df, activation):
    """Full epilogue (bias + activation + residual): d(x, w, b, res)."""
    M, K, N = 96, 200, 130  # unaligned -> exercises the pad/unpad path too
    x, w = _rand((M, K)), _rand((K, N))
    b, res = _rand((N,)), _rand((M, N))
    ct = _rand((M, N), scale=1.0)  # non-trivial cotangent

    def loss(x, w, b, res):
        y = flex_linear(x, w, b, activation=activation, residual=res,
                        dataflow=df, block=(128, 128, 128), interpret=True)
        return (y * ct).sum()

    def ref(x, w, b, res):
        return (linear_ref(x, w, b, activation=activation, residual=res) * ct).sum()

    _assert_close(_grads(loss, x, w, b, res), _grads(ref, x, w, b, res))


@pytest.mark.parametrize("df", ALL_DATAFLOWS)
def test_linear_grads_epilogue_pieces_compose(df):
    """bias-only / residual-only / bare combinations all differentiate."""
    x, w = _rand((64, 96)), _rand((96, 72))
    b, res = _rand((72,)), _rand((64, 72))
    for bias in (None, b):
        for r in (None, res):
            args = [a for a in (x, w, bias, r) if a is not None]

            def loss(*a, _nb=bias is None, _nr=r is None):
                it = iter(a)
                xx, ww = next(it), next(it)
                bb = None if _nb else next(it)
                rr = None if _nr else next(it)
                return flex_linear(xx, ww, bb, activation="gelu", residual=rr,
                                   dataflow=df, block=(64, 96, 72),
                                   interpret=True).sum()

            def ref(*a, _nb=bias is None, _nr=r is None):
                it = iter(a)
                xx, ww = next(it), next(it)
                bb = None if _nb else next(it)
                rr = None if _nr else next(it)
                return linear_ref(xx, ww, bb, activation="gelu", residual=rr).sum()

            _assert_close(_grads(loss, *args), _grads(ref, *args))


@pytest.mark.parametrize("df", ALL_DATAFLOWS)
def test_matmul_grads_match_dot(df):
    a, b = _rand((64, 96)), _rand((96, 72))

    def loss(a, b):
        return (flex_matmul(a, b, dataflow=df, interpret=True) ** 2).sum()

    def ref(a, b):
        return (jnp.dot(a, b, preferred_element_type=jnp.float32) ** 2).sum()

    _assert_close(_grads(loss, a, b), _grads(ref, a, b), tol=1e-3)


def test_bwd_spec_overrides_are_honoured():
    """CMU-planned (dataflow, block) for dX/dW flow through the VJP; every
    combination still produces the reference gradient."""
    x, w, b = _rand((64, 96)), _rand((96, 72)), _rand((72,))
    ref_dx, ref_dw = _grads(
        lambda x, w: linear_ref(x, w, b, activation="silu").sum(), x, w
    )
    for df in ALL_DATAFLOWS:
        dx, dw = _grads(
            lambda x, w, _df=df: flex_linear(
                x, w, b, activation="silu", interpret=True,
                bwd_dx=(_df, (64, 72, 96)), bwd_dw=(_df, (96, 64, 72)),
            ).sum(),
            x, w,
        )
        _assert_close((dx, dw), (ref_dx, ref_dw))


def test_linear_grad_accepts_2d_bias():
    """A (1, N) bias works forward, so its cotangent must match that shape
    (regression: the VJP used to return (N,) and crash under grad)."""
    x, w = _rand((32, 64)), _rand((64, 48))
    b2 = _rand((1, 48))
    db2, = _grads(
        lambda b: flex_linear(x, w, b, activation="gelu", interpret=True).sum(), b2
    )
    assert db2.shape == (1, 48)
    ref_db, = _grads(
        lambda b: linear_ref(x, w, b, activation="gelu").sum(), b2
    )
    _assert_close((db2,), (ref_db.reshape(1, 48),))


def test_linear_grad_bf16_inputs_run_and_are_finite():
    """Mixed-precision training path: bf16 operands, f32 accumulation."""
    x, w = _rand((32, 64), jnp.bfloat16), _rand((64, 32), jnp.bfloat16)
    dx, dw = _grads(
        lambda x, w: flex_linear(x, w, activation="gelu",
                                 interpret=True).astype(jnp.float32).sum(),
        x, w,
    )
    assert dx.dtype == jnp.bfloat16 and dw.dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(dx.astype(jnp.float32))))
    ref_dx = jax.grad(
        lambda x: linear_ref(x, w, activation="gelu").astype(jnp.float32).sum()
    )(x)
    np.testing.assert_allclose(
        np.asarray(dx, np.float32), np.asarray(ref_dx, np.float32),
        atol=0.1, rtol=0.1,
    )


# ---------------------------------------------------------------------------
# grouped train plans (fwd + dX + dW per layer)
# ---------------------------------------------------------------------------


def test_bwd_gemms_shapes():
    g = GemmShape(128, 512, 64, name="mlp.w2")
    dx, dw = bwd_gemms(g)
    assert (dx.M, dx.K, dx.N) == (128, 64, 512) and dx.name == "mlp.w2.dx"
    assert (dw.M, dw.K, dw.N) == (512, 128, 64) and dw.name == "mlp.w2.dw"


def test_train_plan_carries_bwd_subplans():
    gemms = [GemmShape(64, 96, 64, name="attn.wq")]
    plan = autotune_plan(gemms, top_k=1, iters=1, train=True)
    assert plan.has_bwd()
    lp = plan.layers[0]
    assert lp.bwd_dx.block is not None and lp.bwd_dw.block is not None
    assert lp.bwd_dx.est_cost > 0 and lp.bwd_dw.est_cost > 0
    # serve plans stay fwd-only
    assert not autotune_plan(gemms, measure=False).has_bwd()


def test_train_plan_subplans_can_differ_from_fwd():
    """The backward shapes transpose the fwd aspect ratio; on this shape the
    tuner's ranking lands fwd/dX/dW on three different dataflows."""
    plan = autotune_plan(
        [GemmShape(128, 32768, 128, name="probe")], measure=False, train=True
    )
    lp = plan.layers[0]
    picked = {lp.dataflow, lp.bwd_dx.dataflow, lp.bwd_dw.dataflow}
    assert picked == {Dataflow.OS, Dataflow.IS, Dataflow.WS}


def test_train_plan_roundtrip_and_activation():
    gemms = [GemmShape(64, 96, 64, name="attn.wq"),
             GemmShape(64, 64, 128, name="mlp.w1")]
    plan = autotune_plan(gemms, top_k=1, iters=1, train=True)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "plan.json")
        save_plan(p, plan)
        plan2 = load_plan(p)
        assert plan2.layers == plan.layers  # GemmPlan/LayerPlan frozen dataclasses
        plan3, loaded = load_or_autotune(p, gemms, require_bwd=True)
        assert loaded and plan3.has_bwd()


def test_fwd_only_cache_upgraded_incrementally_for_training():
    """Serving cache (no bwd sub-plans) must not silently drive training —
    and the upgrade keeps the (possibly measured) forward decisions, tuning
    only the missing dX/dW sub-GEMMs."""
    gemms = [GemmShape(64, 96, 64, name="attn.wq")]
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "plan.json")
        serve_plan = autotune_plan(gemms, top_k=1, iters=1)  # measured fwd
        save_plan(p, serve_plan)
        plan, loaded = load_or_autotune(p, gemms, require_bwd=True,
                                        measure=False)
        assert not loaded and plan.has_bwd()
        lp, old = plan.layers[0], serve_plan.layers[0]
        # fwd decision preserved verbatim (incl. its measured provenance)
        assert (lp.dataflow, lp.block, lp.est_cost, lp.source) == (
            old.dataflow, old.block, old.est_cost, old.source)
        # and the upgraded cache now satisfies training directly
        plan2, loaded2 = load_or_autotune(p, gemms, require_bwd=True,
                                          measure=False)
        assert loaded2 and plan2.has_bwd()


# ---------------------------------------------------------------------------
# plan-cache schema migration
# ---------------------------------------------------------------------------


def _v1_payload():
    return {
        "version": 1,
        "layers": [{
            "name": "attn.wq", "M": 64, "K": 96, "N": 64,
            "dataflow": "OS", "est_cost": 1.0,
            "block": [64, 128, 64], "source": "measured",
        }],
    }


def test_v1_cache_file_loads_without_bwd():
    """A pre-upgrade cache file loads (rows are a subset of v2) — serving
    keeps working across the schema bump."""
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "plan.json")
        with open(p, "w") as f:
            json.dump(_v1_payload(), f)
        plan = load_plan(p)
        assert plan.layers[0].dataflow is Dataflow.OS
        assert plan.layers[0].bwd_dx is None and not plan.has_bwd()


def test_v1_cache_satisfies_serve_but_not_train():
    gemms = [GemmShape(64, 96, 64, name="attn.wq")]
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "plan.json")
        with open(p, "w") as f:
            json.dump(_v1_payload(), f)
        plan, loaded = load_or_autotune(p, gemms, measure=False)
        assert loaded  # serve path: v1 cache still honoured
        plan2, loaded2 = load_or_autotune(p, gemms, require_bwd=True,
                                          measure=False)
        assert not loaded2 and plan2.has_bwd()  # train path: re-tuned


def test_future_version_rejected_with_retune_message():
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "plan.json")
        with open(p, "w") as f:
            json.dump({"version": 99, "layers": []}, f)
        with pytest.raises(ValueError, match="re-tune"):
            load_plan(p)


# ---------------------------------------------------------------------------
# model integration: jax.grad through the full stack, pallas == XLA
# ---------------------------------------------------------------------------


def test_model_grads_pallas_match_xla():
    from repro.models import Model, get_config

    cfg = get_config("qwen3_4b", smoke=True).replace(
        dtype="float32", param_dtype="float32"
    )
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    lref, gref = jax.value_and_grad(lambda p: m.loss(p, batch)[0])(params)

    plan = autotune_plan(model_gemms(cfg, tokens=32), top_k=1, iters=1,
                         train=True)
    assert plan.has_bwd()
    activate_plan(plan)
    try:
        mp = Model(cfg.replace(use_pallas=True))
        lp, gp = jax.value_and_grad(lambda p: mp.loss(p, batch)[0])(params)
    finally:
        activate_plan(None)

    assert abs(float(lref) - float(lp)) < 1e-5
    flat_ref, _ = jax.tree.flatten(gref)
    flat_pal, _ = jax.tree.flatten(gp)
    for a, b in zip(flat_ref, flat_pal):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-3)
