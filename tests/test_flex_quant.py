"""Weight-quantized flex kernels + the CMU precision axis.

Pins five contracts:

  * **Value contract** — every (dataflow, strip, qdtype) point matches the
    XLA dequant reference (``x @ dequantize(quantize(w))``) to f32
    tolerance, and all schedule points agree *bitwise* with each other:
    the quantized lattice is fixed by the shared ``kernels.quantize``
    scale math, and a schedule decides residency, never bits.
  * **Epilogue contract** — dequant fuses at the flush *before* the
    epilogue, so ``act((x @ q) * scale + b) + res`` composes exactly like
    the full-precision epilogue path.
  * **Gate contract** — a quantized candidate can win only when the
    accuracy gate passes: with a fake calibration-error hook over budget
    the verdict is the recorded ``"bf16"`` fallback even when a fake timer
    says the quantized kernel is faster.
  * **Schema contract** — v8 plan caches (no qdtype/qerror keys) load
    bit-for-bit with ``qdtype=None``; a quant-requesting load upgrades
    incrementally — ``add_quant_subplans`` keeps every schedule decision
    verbatim and only annotates verdicts — and the file re-persists as v9.
  * **One-quantizer contract** — ``runtime.compression`` computes the same
    abs-max scale as the kernels (bitwise), and the int8/fp8 round-trip
    error bounds that budget the accuracy gate hold.
"""

import dataclasses
import importlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core import (
    ALL_DATAFLOWS,
    Dataflow,
    GemmShape,
    add_quant_subplans,
    autotune_plan,
    hbm_traffic_bytes,
    load_or_autotune,
    load_plan,
    plan_matches,
    save_plan,
)
from repro.core import cmu as cmu_mod
from repro.core.plan_cache import PLAN_CACHE_VERSION
from repro.kernels import (
    QDTYPES,
    abs_max_scale,
    dequantize_channel,
    flex_linear,
    flex_matmul,
    quantize_channel,
)
from repro.runtime import compression as comp

fk = importlib.import_module("repro.kernels.flex_matmul")

RNG = np.random.default_rng(17)


def _operands(M, K, N, seed=0):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(K, N)) * 0.1, jnp.float32)
    return a, b


def _bits(x) -> bytes:
    return np.asarray(x).tobytes()


# ---------------------------------------------------------------------------
# property sweep: dataflow x schedule x qdtype vs the XLA dequant reference
# ---------------------------------------------------------------------------


@given(
    qd=st.sampled_from(list(QDTYPES)),
    shape=st.sampled_from([(48, 64, 32), (64, 96, 64), (16, 64, 96)]),
)
@settings(max_examples=6, deadline=None)
def test_quant_schedule_family_property_sweep(qd, shape):
    """Every (dataflow, strip) schedule of the quantized GEMM matches the
    XLA dequant reference, and all schedule points are mutually bitwise:
    the quantized lattice is a property of the operands, not the schedule."""
    M, K, N = shape
    a, b = _operands(M, K, N, seed=sum(shape))
    ref = np.asarray(a @ dequantize_channel(*quantize_channel(b, qd, axis=0)))
    blk = (16, 32, 16)
    outs = {}
    for df in ALL_DATAFLOWS:
        strips = [1] if df is Dataflow.OS else [1, 2]
        for strip in strips:
            outs[(df, strip)] = flex_matmul(
                a, b, dataflow=df, block=blk, interpret=True, strip=strip,
                qdtype=qd)
    for key, out in outs.items():
        np.testing.assert_allclose(np.asarray(out), ref, atol=2e-4, rtol=2e-4,
                                   err_msg=f"schedule={key} qdtype={qd}")
    bits = {_bits(o) for o in outs.values()}
    assert len(bits) == 1, \
        f"quantized schedules diverged bitwise for {qd}: {list(outs)}"


@pytest.mark.parametrize("qd", QDTYPES)
def test_quant_epilogue_composition(qd):
    """Dequant fuses *before* the epilogue: the fused quantized linear is
    act((x @ q) * scale + bias) + residual — same composition contract as
    the full-precision epilogue, on the dequantized weight."""
    M, K, N = 32, 64, 48
    x, w = _operands(M, K, N, seed=3)
    bias = jnp.asarray(RNG.normal(size=(N,)), jnp.float32)
    res = jnp.asarray(RNG.normal(size=(M, N)), jnp.float32)
    out = flex_linear(x, w, bias, activation="gelu", residual=res,
                      dataflow=Dataflow.WS, block=(16, 32, 16),
                      interpret=True, qdtype=qd)
    wq = dequantize_channel(*quantize_channel(w, qd, axis=0))
    ref = jax.nn.gelu(x @ wq + bias[None, :], approximate=True) + res
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


def test_quant_rejects_transposed_operands():
    a, b = _operands(32, 32, 32)
    with pytest.raises(ValueError, match="untransposed"):
        flex_matmul(a.T, b, trans_a=True, interpret=True, qdtype="int8")


# ---------------------------------------------------------------------------
# accuracy gate: quant wins only when the calibration error fits the budget
# ---------------------------------------------------------------------------

GEMMS = lambda: [GemmShape(64, 64, 96, name="mlp.w1"),  # noqa: E731
                 GemmShape(64, 96, 64, name="mlp.w2")]


def test_gate_rejects_over_budget_error(monkeypatch):
    """A fake calibration hook over budget forces the recorded "bf16"
    fallback; under budget the analytic ranking quantizes (1-byte weight
    stream beats bf16 on every bandwidth-bound shape)."""
    monkeypatch.setattr(cmu_mod, "measure_quant_error",
                        lambda gemm, qd: 10.0)
    plan = autotune_plan(GEMMS(), measure=False, quant=("int8",))
    assert all(lp.qdtype == "bf16" and lp.qerror is None
               for lp in plan.layers)

    monkeypatch.setattr(cmu_mod, "measure_quant_error",
                        lambda gemm, qd: 1e-4)
    plan = autotune_plan(GEMMS(), measure=False, quant=("int8",))
    assert all(lp.qdtype == "int8" and lp.qerror == 1e-4
               for lp in plan.layers)


def test_gate_budget_is_configurable(monkeypatch):
    monkeypatch.setattr(cmu_mod, "measure_quant_error",
                        lambda gemm, qd: 0.03)
    tight = autotune_plan(GEMMS(), measure=False, quant=("int8",),
                          quant_budget=0.01)
    loose = autotune_plan(GEMMS(), measure=False, quant=("int8",),
                          quant_budget=0.05)
    assert all(lp.qdtype == "bf16" for lp in tight.layers)
    assert all(lp.qdtype == "int8" for lp in loose.layers)


def test_quant_candidate_wins_only_when_gate_passes(monkeypatch):
    """Fake-timer planning: the timer says the quantized kernel is 100x
    faster, but the verdict follows the gate — quantized when calibration
    fits the budget, the "bf16" fallback when it does not."""

    def fake_timer(gemm, df, blk, qdtype=None, **kw):
        base = hbm_traffic_bytes(gemm, df, *blk).time_s()
        return base * 0.01 if qdtype else base

    monkeypatch.setattr(cmu_mod, "measure_kernel", fake_timer)

    monkeypatch.setattr(cmu_mod, "measure_quant_error",
                        lambda gemm, qd: 1e-4)
    plan = autotune_plan(GEMMS(), measure=True, iters=1, quant=("int8",))
    assert all(lp.qdtype == "int8" for lp in plan.layers)
    assert all(lp.source == "measured" for lp in plan.layers)

    monkeypatch.setattr(cmu_mod, "measure_quant_error",
                        lambda gemm, qd: 10.0)
    plan = autotune_plan(GEMMS(), measure=True, iters=1, quant=("int8",))
    assert all(lp.qdtype == "bf16" for lp in plan.layers), \
        "an over-budget dtype won on speed — the gate must run first"


def test_gate_ties_break_to_lower_error(monkeypatch):
    """int8 and fp8 both cost 1 byte/element, so they tie on traffic; the
    eligible list is sorted by calibration error and the stable ranking
    keeps the lower-error dtype first."""
    errs = {"int8": 0.02, "fp8": 0.002}
    monkeypatch.setattr(cmu_mod, "measure_quant_error",
                        lambda gemm, qd: errs[qd])
    plan = autotune_plan(GEMMS(), measure=False, quant=("int8", "fp8"))
    assert all(lp.qdtype == "fp8" and lp.qerror == 0.002
               for lp in plan.layers)


def test_real_calibration_admits_both_dtypes():
    """The real hook on Gaussian weights: int8 lands well under fp8 (3
    mantissa bits), and both fit the default budget — the empirical fact
    the default ``QUANT_ERROR_BUDGET`` encodes."""
    g = GEMMS()[0]
    e8 = cmu_mod.measure_quant_error(g, "int8")
    ef8 = cmu_mod.measure_quant_error(g, "fp8")
    assert e8 < ef8 < cmu_mod.QUANT_ERROR_BUDGET
    assert e8 < 0.01 and ef8 < 0.04


# ---------------------------------------------------------------------------
# schema: v8 -> v9 migration + incremental quant upgrade
# ---------------------------------------------------------------------------


def _strip_quant_keys(node):
    """Remove the v9-only keys everywhere — the file a v8 build wrote."""
    if isinstance(node, dict):
        node.pop("qdtype", None)
        node.pop("qerror", None)
        for v in node.values():
            _strip_quant_keys(v)
    elif isinstance(node, list):
        for v in node:
            _strip_quant_keys(v)


def _as_v8_file(v9_path, v8_path):
    payload = json.load(open(v9_path))
    payload["version"] = 8
    _strip_quant_keys(payload)
    json.dump(payload, open(v8_path, "w"))


def _unquant(lp):
    dec = ({b: dataclasses.replace(g, qdtype=None, qerror=None)
            for b, g in lp.decode.items()} if lp.decode else lp.decode)
    return dataclasses.replace(lp, qdtype=None, qerror=None, decode=dec)


def test_v8_cache_loads_bit_for_bit_with_qdtype_none(tmp_path):
    gemms = GEMMS()
    plan = autotune_plan(gemms, measure=False, decode_buckets=(8,))
    v9, v8 = os.path.join(tmp_path, "v9.json"), os.path.join(tmp_path, "v8.json")
    save_plan(v9, plan)
    _as_v8_file(v9, v8)
    loaded = load_plan(v8)
    assert all(lp.qdtype is None and lp.qerror is None for lp in loaded.layers)
    assert all(gp.qdtype is None for lp in loaded.layers
               for gp in lp.decode.values())
    # every schedule decision identical — dispatch is bit-for-bit (the plan
    # was never quant-tuned, so its own rows carry qdtype=None already)
    assert list(loaded.layers) == list(plan.layers)
    assert loaded.to_json() == plan.to_json()
    # a quant-less request loads without re-tune...
    assert plan_matches(loaded, gemms, buckets=(8,))
    # ...but a quant request does not match as-is
    assert not plan_matches(loaded, gemms, buckets=(8,), quant=("int8",))


def test_v8_cache_upgrades_to_v9_quant_incrementally(tmp_path, monkeypatch):
    monkeypatch.setattr(cmu_mod, "measure_quant_error", lambda gemm, qd: 1e-3)
    gemms = GEMMS()
    plan = autotune_plan(gemms, measure=False, decode_buckets=(8,))
    v9, v8 = os.path.join(tmp_path, "v9.json"), os.path.join(tmp_path, "v8.json")
    save_plan(v9, plan)
    _as_v8_file(v9, v8)

    up, loaded = load_or_autotune(v8, gemms, buckets=(8,), measure=False,
                                  quant=("int8",))
    assert not loaded  # it had to annotate the quant verdicts
    assert up.has_quant((8,))
    for lp, lp0 in zip(up.layers, plan.layers):
        assert lp.qdtype in ("int8", "bf16")
        assert _unquant(lp) == _unquant(lp0), \
            f"incremental quant upgrade retuned {lp.name}"
    with open(v8) as f:
        assert json.load(f)["version"] == PLAN_CACHE_VERSION == 9
    again, loaded = load_or_autotune(v8, gemms, buckets=(8,), measure=False,
                                     quant=("int8",))
    assert loaded  # second launch reloads, no tuning


def test_add_quant_subplans_keeps_decisions_verbatim(monkeypatch):
    monkeypatch.setattr(cmu_mod, "measure_quant_error", lambda gemm, qd: 1e-3)
    plan = autotune_plan(GEMMS(), measure=False, decode_buckets=(8, 16),
                         train=True)
    up = add_quant_subplans(plan, ("int8",), measure=False)
    assert up.has_quant((8, 16))
    for lp, lp0 in zip(up.layers, plan.layers):
        assert _unquant(lp) == _unquant(lp0)
        # bwd GEMMs stay unquantized: straight-through estimator territory
        assert lp.bwd_dx == lp0.bwd_dx and lp.bwd_dw == lp0.bwd_dw
        assert lp.bwd_dx.qdtype is None and lp.bwd_dw.qdtype is None
    # idempotent: already-annotated rows are untouched
    assert add_quant_subplans(up, ("int8",), measure=False) == up


def test_quant_plan_roundtrips_through_json(monkeypatch):
    monkeypatch.setattr(cmu_mod, "measure_quant_error", lambda gemm, qd: 1e-3)
    plan = autotune_plan(GEMMS(), measure=False, decode_buckets=(8,),
                         quant=("int8", "fp8"))
    from repro.core import DataflowPlan

    back = DataflowPlan.from_json(plan.to_json())
    assert list(back.layers) == list(plan.layers)
    assert back.has_quant((8,))


# ---------------------------------------------------------------------------
# one quantizer: shared scale math + round-trip error bounds
# ---------------------------------------------------------------------------


def test_compression_uses_the_shared_scale_bitwise():
    """The gradient compressor's per-block scale is ``abs_max_scale`` —
    bitwise equal to the legacy inline formula it replaced, so error
    feedback telescopes exactly as before."""
    g = jnp.asarray(RNG.normal(size=(1000,)) * 0.3, jnp.float32)
    q, scale, meta = comp.quantize_int8(g)
    b, _ = comp._blockify(g)
    legacy = jnp.max(jnp.abs(b), axis=1, keepdims=True) / 127.0 + 1e-12
    assert _bits(scale) == _bits(legacy)
    assert _bits(scale) == _bits(abs_max_scale(b, "int8", axis=1))


@pytest.mark.parametrize("qd,bound", [("int8", 0.01), ("fp8", 0.04)])
def test_channel_roundtrip_error_bounds(qd, bound):
    """Round-trip relative RMS error on Gaussian weights stays within the
    per-dtype bound the accuracy gate budgets against (int8: ~7.9 bits of
    mantissa; fp8 e4m3: 3 bits -> ~2.6% per element)."""
    w = jnp.asarray(np.random.default_rng(qd == "fp8").normal(size=(128, 64)),
                    jnp.float32)
    back = dequantize_channel(*quantize_channel(w, qd, axis=0))
    err = float(jnp.linalg.norm(back - w) / jnp.linalg.norm(w))
    assert 0.0 < err < bound, (qd, err)


def test_compression_roundtrip_error_bound():
    """Block-int8 gradient compression round-trip: per-element error is at
    most half a quantization step (scale/2), and the relative RMS error on
    Gaussian gradients stays under 1%."""
    g = jnp.asarray(RNG.normal(size=(3000,)) * 0.05, jnp.float32)
    q, scale, meta = comp.quantize_int8(g)
    back = comp.dequantize_int8(q, scale, meta)
    b, _ = comp._blockify(g)
    step = np.broadcast_to(np.asarray(scale), b.shape).reshape(-1)[:g.size]
    assert np.all(np.abs(np.asarray(back - g)) <= step / 2 + 1e-9)
    rel = float(jnp.linalg.norm(back - g) / jnp.linalg.norm(g))
    assert rel < 0.01, rel
