"""Continuous-batching serving: block allocator properties, paged-decode
equivalence, scheduler invariants, and the v6 bucketed plan-cache schema.

The scheduler's contract is deterministic serving: greedy token streams
bitwise identical to classic per-request ``prefill``/``decode_step``
decoding, independent of arrival order, co-scheduled batch composition,
and bucket padding.  These tests pin that contract, the paged KV cache's
allocator safety (no double-allocation, frees return, graceful exhaustion),
and the CMU side: decode sub-plans keyed on batch-size buckets survive a
save/load roundtrip, v5 caches migrate and upgrade incrementally without
touching their measured forward rows, and the pallas dispatch actually
consults the bucket plans."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core import (
    DECODE_BUCKETS,
    activate_plan,
    autotune_plan,
    decode_bucket,
    load_or_autotune,
    load_plan,
    model_epilogues,
    model_gemms,
    plan_matches,
    save_plan,
)
from repro.core.plan_cache import PLAN_CACHE_VERSION
from repro.core import cmu as cmu_mod
from repro.core.cmu import Dataflow, LayerPlan
from repro.launch.scheduler import (
    Request,
    RequestStatus,
    ServeScheduler,
    poisson_trace,
    run_fixed_batch,
    serve_buckets,
)
from repro.launch.serve import sequential_reference
from repro.models import Model, get_config
from repro.runtime import BlockAllocator, PagedKVCache, SCRATCH_BLOCK


# ---------------------------------------------------------------------------
# block allocator
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(num_blocks=st.integers(min_value=2, max_value=24),
       seed=st.integers(min_value=0, max_value=999))
def test_allocator_never_double_allocates(num_blocks, seed):
    """A random alloc/free interleaving: every live block id is unique,
    scratch is never handed out, frees return capacity, and the allocator
    ends empty when everything is freed."""
    rng = np.random.default_rng(seed)
    alloc = BlockAllocator(num_blocks)
    live: list[list[int]] = []
    seen_live: set[int] = set()
    for _ in range(40):
        if live and rng.random() < 0.4:
            blocks = live.pop(rng.integers(len(live)))
            alloc.free(blocks)
            seen_live -= set(blocks)
        else:
            n = int(rng.integers(1, max(2, num_blocks // 2)))
            got = alloc.alloc(n)
            if got is None:
                assert alloc.free_blocks < n  # refusal only when short
                continue
            assert len(got) == n
            assert SCRATCH_BLOCK not in got
            assert not (set(got) & seen_live), "block handed out twice"
            seen_live |= set(got)
            live.append(got)
        assert alloc.live_blocks == len(seen_live)
    for blocks in live:
        alloc.free(blocks)
    assert alloc.live_blocks == 0
    assert alloc.free_blocks == num_blocks - 1  # all but scratch


def test_allocator_exhaustion_returns_none_and_recovers():
    alloc = BlockAllocator(4)  # 3 usable
    a = alloc.alloc(2)
    assert a is not None and alloc.alloc(2) is None  # graceful, no raise
    b = alloc.alloc(1)
    assert b is not None and alloc.free_blocks == 0
    alloc.free(a)
    assert alloc.alloc(2) is not None


def test_allocator_rejects_foreign_and_double_free():
    alloc = BlockAllocator(4)
    a = alloc.alloc(2)
    alloc.free(a)
    with pytest.raises(ValueError):
        alloc.free(a)  # double free
    with pytest.raises(ValueError):
        alloc.free([SCRATCH_BLOCK])  # scratch is never owned


# ---------------------------------------------------------------------------
# bucket quantization
# ---------------------------------------------------------------------------


@settings(max_examples=16, deadline=None)
@given(m=st.integers(min_value=1, max_value=80))
def test_decode_bucket_is_smallest_fitting(m):
    b = decode_bucket(m)
    fitting = [x for x in DECODE_BUCKETS if m <= x]
    assert b == (min(fitting) if fitting else None)


def test_serve_buckets_caps_at_capacity():
    assert serve_buckets(8) == (8,)
    assert serve_buckets(16) == (8, 16)
    assert serve_buckets(12) == (8, 12)   # capacity itself is always a bucket
    assert serve_buckets(64) == (8, 16, 32, 64)


# ---------------------------------------------------------------------------
# scheduler vs classic sequential decode
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("qwen3_4b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _trace(cfg, n=8, rate=0.7, seed=11, max_prompt=14, max_gen=6):
    return poisson_trace(n, vocab=cfg.vocab_size, max_prompt=max_prompt,
                         max_gen=max_gen, rate=rate, seed=seed)


def test_scheduler_matches_sequential_reference(smoke_model):
    """Every admitted request finishes with exactly max_new tokens, all
    KV blocks return to the pool, and each stream is bitwise identical to
    classic per-request prefill/decode_step serving."""
    cfg, model, params = smoke_model
    trace = _trace(cfg)
    sched = ServeScheduler(model, params, capacity=8, block_size=16,
                           max_total_len=14 + 6)
    results, stats = sched.run(trace)
    assert set(results) == {r.rid for r in trace}
    assert stats.prefills == len(trace)
    assert sched.kv.allocator.live_blocks == 0
    ref = sequential_reference(model, params, trace,
                               sched.max_blocks * sched.block_size)
    for r in trace:
        got = results[r.rid]
        assert got.tokens is not None and len(got.tokens) == r.max_new
        assert got.admitted_step <= got.finished_step
        np.testing.assert_array_equal(got.tokens, ref[r.rid])


def test_streams_independent_of_batch_composition(smoke_model):
    """The same trace served at capacity 2 and capacity 8 co-schedules
    entirely different batches (and hits different buckets) — the token
    streams must not change."""
    cfg, model, params = smoke_model
    trace = _trace(cfg, seed=5)
    wide = ServeScheduler(model, params, capacity=8, block_size=16,
                          max_total_len=14 + 6).run(trace)[0]
    narrow = ServeScheduler(model, params, capacity=2, block_size=16,
                            max_total_len=14 + 6).run(trace)[0]
    for r in trace:
        np.testing.assert_array_equal(wide[r.rid].tokens, narrow[r.rid].tokens)


def test_streams_independent_of_arrival_order(smoke_model):
    cfg, model, params = smoke_model
    trace = _trace(cfg, seed=7)
    all_at_once = [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
                   for r in trace]
    a = ServeScheduler(model, params, capacity=8, block_size=16,
                       max_total_len=14 + 6).run(trace)[0]
    b = ServeScheduler(model, params, capacity=8, block_size=16,
                       max_total_len=14 + 6).run(all_at_once)[0]
    for r in trace:
        np.testing.assert_array_equal(a[r.rid].tokens, b[r.rid].tokens)


def test_scheduler_queues_gracefully_on_block_exhaustion(smoke_model):
    """A pool sized for ~2 concurrent requests forces later arrivals to
    FIFO-wait for evictions; everyone still finishes, correctly."""
    cfg, model, params = smoke_model
    trace = _trace(cfg, n=6, rate=0.0, seed=3)  # all arrive at step 0
    sched = ServeScheduler(model, params, capacity=8, block_size=16,
                           max_total_len=14 + 6,
                           num_blocks=3)  # 2 usable blocks + scratch
    results, stats = sched.run(trace)
    assert max(stats.active_per_step) <= 2  # the pool really was the limit
    assert max(stats.active_per_step) < len(trace)  # admission throttled
    assert sched.kv.allocator.live_blocks == 0
    ref = sequential_reference(model, params, trace,
                               sched.max_blocks * sched.block_size)
    for r in trace:
        np.testing.assert_array_equal(results[r.rid].tokens, ref[r.rid])


def test_oversized_request_rejected_up_front(smoke_model):
    """An inadmissible request (prompt + max_new exceeds the cache) gets a
    per-request REJECTED result instead of crashing the whole batch."""
    cfg, model, params = smoke_model
    sched = ServeScheduler(model, params, capacity=4, block_size=16,
                           max_total_len=32)
    huge = [Request(rid=0, prompt=np.zeros(30, np.int32), max_new=10)]
    results, stats = sched.run(huge)
    assert results[0].status is RequestStatus.REJECTED
    assert results[0].tokens is None
    assert stats.rejections == 1


def test_fixed_batch_baseline_same_model(smoke_model):
    """The legacy loop still serves: right answer count, one token stream
    per request at its own max_new."""
    cfg, model, params = smoke_model
    trace = _trace(cfg, n=4, seed=2)
    results, st_ = run_fixed_batch(model, params, trace)
    assert set(results) == {r.rid for r in trace}
    for r in trace:
        assert len(results[r.rid]) == r.max_new
    assert st_["row_steps"] == len(trace) * max(r.max_new for r in trace)


# ---------------------------------------------------------------------------
# plan cache v6: bucketed decode sub-plans
# ---------------------------------------------------------------------------


GEMMS = lambda cfg: model_gemms(cfg, tokens=64)  # noqa: E731


def test_v6_roundtrip_and_bucket_lookup(tmp_path):
    cfg = get_config("qwen3_4b", smoke=True).replace(use_pallas=True)
    plan = autotune_plan(GEMMS(cfg), measure=False, decode_buckets=(8, 16),
                         epilogue=model_epilogues(cfg))
    path = os.path.join(tmp_path, "plan.json")
    save_plan(path, plan)
    with open(path) as f:
        assert json.load(f)["version"] == PLAN_CACHE_VERSION
    plan2 = load_plan(path)
    assert plan2.has_decode((8, 16)) and not plan2.has_decode((8, 16, 32))
    assert plan_matches(plan2, GEMMS(cfg), buckets=(8, 16))
    assert not plan_matches(plan2, GEMMS(cfg), buckets=(8, 16, 32))
    for lp in plan2.layers:
        # lookup quantizes up: m=5 -> bucket 8; m=9 -> 16; m=17 -> None
        assert lp.decode_plan(5) == lp.decode[8]
        assert lp.decode_plan(9) == lp.decode[16]
        assert lp.decode_plan(17) is None


def test_v5_cache_loads_with_decode_none_and_upgrades(tmp_path):
    """A v5 file (no decode sub-plans) loads with decode=None; a bucketed
    load_or_autotune upgrades it incrementally — the measured forward rows
    survive verbatim and only the buckets are tuned."""
    cfg = get_config("qwen3_4b", smoke=True).replace(use_pallas=True)
    plan = autotune_plan(GEMMS(cfg), measure=False,
                         epilogue=model_epilogues(cfg))
    path = os.path.join(tmp_path, "plan.json")
    save_plan(path, plan)
    with open(path) as f:
        payload = json.load(f)
    payload["version"] = 5
    for row in payload["layers"]:
        row.pop("decode", None)
    with open(path, "w") as f:
        json.dump(payload, f)

    v5 = load_plan(path)
    assert all(lp.decode is None for lp in v5.layers)
    assert plan_matches(v5, GEMMS(cfg))          # bucketless request: fine
    assert not plan_matches(v5, GEMMS(cfg), buckets=(8,))

    before = {lp.name: (lp.dataflow, lp.block, lp.strip) for lp in v5.layers}
    up, loaded = load_or_autotune(path, GEMMS(cfg), buckets=(8,),
                                  measure=False,
                                  epilogue=model_epilogues(cfg))
    assert not loaded  # it had to tune (the buckets)
    assert up.has_decode((8,))
    for lp in up.layers:
        assert (lp.dataflow, lp.block, lp.strip) == before[lp.name], \
            "incremental bucket upgrade must not retune forward rows"
    # and the upgrade was persisted as the current schema version
    with open(path) as f:
        assert json.load(f)["version"] == PLAN_CACHE_VERSION
    again, loaded = load_or_autotune(path, GEMMS(cfg), buckets=(8,),
                                     measure=False)
    assert loaded  # second launch reloads, no tuning


def test_widening_slots_adds_only_missing_buckets(tmp_path):
    cfg = get_config("qwen3_4b", smoke=True).replace(use_pallas=True)
    plan = autotune_plan(GEMMS(cfg), measure=False, decode_buckets=(8,))
    path = os.path.join(tmp_path, "plan.json")
    save_plan(path, plan)
    before = {lp.name: lp.decode[8] for lp in plan.layers}
    up, loaded = load_or_autotune(path, GEMMS(cfg), buckets=(8, 16),
                                  measure=False)
    assert not loaded and up.has_decode((8, 16))
    for lp in up.layers:
        assert lp.decode[8] == before[lp.name], \
            "existing buckets must survive a widening verbatim"


def test_bucket_tuning_is_measurement_driven(monkeypatch):
    """Under a fake timer that penalizes whatever the analytical model would
    pick for each decode bucket, the measured sub-plan lands on a different
    (dataflow, block) — the bucket decisions come from the measurements, not
    from the analytical ranking or the forward dataflow."""
    from repro.core import hbm_traffic_bytes

    cfg = get_config("qwen3_4b", smoke=True).replace(use_pallas=True)
    analytic = autotune_plan(GEMMS(cfg), measure=False, decode_buckets=(8,))
    pick = {lp.name: (lp.decode[8].dataflow, lp.decode[8].block)
            for lp in analytic.layers}

    def fake(gemm, df, blk, **kw):
        base = hbm_traffic_bytes(gemm, df, *blk).time_s()
        # decode-tune GEMMs are named "<layer>@b<bucket>"
        name = gemm.name.split("@")[0]
        if "@b" in gemm.name and (df, blk) == pick[name]:
            return base * 100.0
        return base

    monkeypatch.setattr(cmu_mod, "measure_kernel", fake)
    plan = autotune_plan(GEMMS(cfg), measure=True, iters=1,
                         decode_buckets=(8,))
    for lp in plan.layers:
        got = (lp.decode[8].dataflow, lp.decode[8].block)
        assert got != pick[lp.name], lp.name
        assert lp.decode[8].source == "measured"


def test_paged_decode_dispatches_bucket_plan(smoke_model):
    """End to end on the pallas path: a scheduler run consults
    LayerPlan.decode_plan at decode-trace time, only with bucket-sized row
    counts, and its streams still match sequential decode."""
    cfg, _, _ = smoke_model
    cfg = cfg.replace(use_pallas=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    buckets = serve_buckets(4)
    plan = autotune_plan(model_gemms(cfg, tokens=64), measure=False,
                         decode_buckets=buckets,
                         epilogue=model_epilogues(cfg))
    activate_plan(plan)
    try:
        lookups = []
        orig = LayerPlan.decode_plan

        def recording(self, m):
            sub = orig(self, m)
            if sub is not None:
                lookups.append((self.name, m))
            return sub

        trace = _trace(cfg, n=4, max_prompt=10, max_gen=4, seed=1)
        sched = ServeScheduler(model, params, capacity=4, block_size=16,
                               max_total_len=10 + 4)
        with pytest.MonkeyPatch.context() as mp:
            mp.setattr(LayerPlan, "decode_plan", recording)
            results, _ = sched.run(trace)
        assert lookups, "decode never consulted the bucket sub-plans"
        assert {m for _, m in lookups} <= set(buckets)
        ref = sequential_reference(model, params, trace,
                                   sched.max_blocks * sched.block_size)
        for r in trace:
            np.testing.assert_array_equal(results[r.rid].tokens, ref[r.rid])
    finally:
        activate_plan(None)


def test_scheduler_matches_sequential_with_pallas_attention():
    """Masking-contract regression, end to end: with the Pallas decode-
    attention path enabled (``attn_pallas``), bucket-pad rows are *fully
    masked* — the kernel must zero their probabilities multiplicatively
    (additive -1e30 bias alone leaves exp(0)=1 per dead key once a whole
    block is masked) so the scheduler's pad-row exact-zero guarantee still
    composes.  Pin stream-vs-sequential token equality for every bucket the
    capacities exercise, and that the Pallas kernel really dispatched."""
    import importlib

    # the package re-exports the flash_attention *function*, shadowing the
    # submodule attribute; import_module resolves the real module
    fa = importlib.import_module("repro.kernels.flash_attention")

    cfg = get_config("qwen3_4b", smoke=True).replace(use_pallas=True,
                                                     attn_pallas=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trace = _trace(cfg)
    calls = []
    orig = fa.paged_attention

    def recording(*args, **kw):
        calls.append(args[0].shape[0])  # decode batch (bucket) sizes
        return orig(*args, **kw)

    with pytest.MonkeyPatch.context() as mp:
        mp.setattr(fa, "paged_attention", recording)
        ref = sequential_reference(model, params, trace, 14 + 6 + 12)
        for capacity in (2, 8):  # different co-scheduling -> buckets 2 and 8
            sched = ServeScheduler(model, params, capacity=capacity,
                                   block_size=16, max_total_len=14 + 6)
            results, _ = sched.run(trace)
            for r in trace:
                np.testing.assert_array_equal(results[r.rid].tokens,
                                              ref[r.rid])
    assert calls, "scheduler decode never dispatched the Pallas kernel"
    assert set(calls) <= set(serve_buckets(2)) | set(serve_buckets(8))


# ---------------------------------------------------------------------------
# paged KV cache pools
# ---------------------------------------------------------------------------


def test_paged_cache_geometry(smoke_model):
    cfg, _, _ = smoke_model
    kv = PagedKVCache(cfg, num_blocks=6, block_size=16)
    assert kv.k.shape == (cfg.num_layers, 6, 16, cfg.num_kv_heads, cfg.head_dim)
    assert kv.k.dtype == jnp.bfloat16
    assert kv.blocks_for(1) == 1 and kv.blocks_for(16) == 1
    assert kv.blocks_for(17) == 2
    blocks = kv.alloc(33)
    assert blocks is not None and len(blocks) == 3
    kv.free(blocks)
    assert kv.allocator.live_blocks == 0
