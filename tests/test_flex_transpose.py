"""Transpose-free backward GEMMs: transposed-operand kernels, CMU
re-ranking, plan-cache schema v3.

Three acceptance bars:

* **Property sweep** — for every dataflow x (trans_a, trans_b) x ragged
  (non-block-multiple) shape x dtype, ``ops.flex_matmul`` (interpret mode)
  must match ``jnp.matmul`` on the logical operands to tolerance.
* **Jaxpr regression** — the backward of ``flex_linear``/``flex_matmul``
  under the (default) transposed-operand specs must contain **no**
  ``transpose`` equations anywhere (the HBM copy must not sneak back); the
  explicit copy-based spec must still produce one (proving the probe sees
  transposes at all).
* **Honest CMU** — backward sub-GEMMs are timed as the transposed-variant
  kernels plus the copy-based fallback *with its transpose cost included*;
  the winning operand layout lands in ``GemmPlan.trans``, survives the v3
  cache roundtrip, and v1/v2 files load-and-migrate.
"""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core import (
    ALL_DATAFLOWS,
    NO_TRANS,
    TRANS_DX,
    TRANS_DW,
    Dataflow,
    EpilogueSig,
    GemmShape,
    autotune_plan,
    hbm_traffic_bytes,
    load_plan,
    measure_kernel,
    save_plan,
)
from repro.core import cmu as cmu_mod
from repro.core import plan_cache as plan_cache_mod
from repro.kernels import flex_linear, flex_matmul, linear_ref

RNG = np.random.default_rng(7)


def _rand(shape, dtype=jnp.float32, scale=0.2):
    return jnp.asarray(RNG.normal(size=shape) * scale, np.float32).astype(dtype)


def _physical(arr, trans: bool):
    """Store ``arr`` in transposed physical layout when ``trans``."""
    return jnp.asarray(np.asarray(arr).T.copy()) if trans else arr


# ---------------------------------------------------------------------------
# property-based kernel sweep: dataflow x trans x ragged shapes x dtypes
# ---------------------------------------------------------------------------


@settings(max_examples=24, deadline=None)
@given(
    st.sampled_from(ALL_DATAFLOWS),
    st.booleans(),
    st.booleans(),
    st.integers(min_value=1, max_value=200),
    st.integers(min_value=1, max_value=200),
    st.integers(min_value=1, max_value=200),
    st.sampled_from(["float32", "bfloat16"]),
)
def test_flex_matmul_matches_jnp_under_transposition(df, ta, tb, M, K, N, dt):
    dtype = jnp.dtype(dt)
    A = _rand((M, K), dtype)
    B = _rand((K, N), dtype)
    out = flex_matmul(
        _physical(A, ta), _physical(B, tb), dataflow=df, interpret=True,
        trans_a=ta, trans_b=tb,
    )
    ref = jnp.matmul(A, B, preferred_element_type=jnp.float32).astype(out.dtype)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol,
    )


@settings(max_examples=12, deadline=None)
@given(
    st.sampled_from(ALL_DATAFLOWS),
    st.booleans(),
    st.booleans(),
    st.integers(min_value=1, max_value=160),
    st.integers(min_value=1, max_value=160),
    st.integers(min_value=1, max_value=160),
)
def test_flex_matmul_grads_match_under_transposition(df, ta, tb, M, K, N):
    """The VJP is itself transpose-free for every flag combination and must
    produce the reference cotangents in the *stored* layouts."""
    A, B = _rand((M, K)), _rand((K, N))
    a, b = _physical(A, ta), _physical(B, tb)

    def loss(a, b):
        return (flex_matmul(a, b, dataflow=df, interpret=True,
                            trans_a=ta, trans_b=tb) ** 2).sum()

    def ref(a, b):
        aa = a.T if ta else a
        bb = b.T if tb else b
        return (jnp.matmul(aa, bb, preferred_element_type=jnp.float32) ** 2).sum()

    got = jax.grad(loss, (0, 1))(a, b)
    want = jax.grad(ref, (0, 1))(a, b)
    for g, w in zip(got, want):
        assert g.shape == w.shape
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=5e-3, rtol=5e-3)


# ---------------------------------------------------------------------------
# jaxpr regression: the HBM transpose copy must not sneak back
# ---------------------------------------------------------------------------


def _all_primitives(jaxpr, out=None):
    """Every primitive name in ``jaxpr``, recursing into sub-jaxprs (pjit
    bodies, custom-vjp closures, pallas kernels)."""
    out = set() if out is None else out
    for eqn in jaxpr.eqns:
        out.add(eqn.primitive.name)
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else [v]):
                if isinstance(sub, jax.core.ClosedJaxpr):
                    _all_primitives(sub.jaxpr, out)
                elif isinstance(sub, jax.core.Jaxpr):
                    _all_primitives(sub, out)
    return out


def _grad_prims(fn, *args):
    argnums = tuple(range(len(args)))
    return _all_primitives(jax.make_jaxpr(jax.grad(fn, argnums))(*args).jaxpr)


@pytest.mark.parametrize("df", ALL_DATAFLOWS)
def test_linear_backward_issues_no_transpose(df):
    """dX/dW under the default (transposed-operand) specs: zero transpose
    equations anywhere in the grad jaxpr, for all three dataflows."""
    x, w, b = _rand((96, 200)), _rand((200, 130)), _rand((130,))

    def loss(x, w, b):
        return flex_linear(x, w, b, activation="gelu", dataflow=df,
                           interpret=True).sum()

    assert "transpose" not in _grad_prims(loss, x, w, b)


def test_linear_backward_planned_trans_specs_issue_no_transpose():
    """Plan-supplied 3-tuple specs with the zero-copy layouts stay clean."""
    x, w = _rand((64, 96)), _rand((96, 72))

    def loss(x, w):
        return flex_linear(
            x, w, activation="silu", interpret=True,
            bwd_dx=(Dataflow.WS, (64, 72, 96), TRANS_DX),
            bwd_dw=(Dataflow.IS, (96, 64, 72), TRANS_DW),
        ).sum()

    assert "transpose" not in _grad_prims(loss, x, w)


def test_matmul_backward_issues_no_transpose():
    a, b = _rand((64, 96)), _rand((96, 72))

    def loss(a, b):
        return (flex_matmul(a, b, interpret=True) ** 2).sum()

    assert "transpose" not in _grad_prims(loss, a, b)


def test_copy_based_spec_still_issues_transpose():
    """Sanity check of the probe itself: an explicit (False, False) spec —
    the copy-based fallback a measured plan may legitimately program — does
    materialise the HBM transpose, so the assertions above are meaningful."""
    x, w = _rand((64, 96)), _rand((96, 72))

    def loss(x, w):
        return flex_linear(
            x, w, interpret=True,
            bwd_dx=(Dataflow.OS, None, NO_TRANS),
            bwd_dw=(Dataflow.OS, None, NO_TRANS),
        ).sum()

    assert "transpose" in _grad_prims(loss, x, w)


def test_legacy_2tuple_bwd_specs_default_to_zero_copy():
    """Pre-v3 (dataflow, block) specs inherit the transposed-operand default
    — and still produce reference gradients."""
    x, w, b = _rand((64, 96)), _rand((96, 72)), _rand((72,))

    def loss(x, w):
        return flex_linear(x, w, b, activation="gelu", interpret=True,
                           bwd_dx=(Dataflow.WS, (64, 72, 96)),
                           bwd_dw=(Dataflow.IS, (96, 64, 72))).sum()

    assert "transpose" not in _grad_prims(loss, x, w)
    got = jax.grad(loss, (0, 1))(x, w)
    want = jax.grad(
        lambda x, w: linear_ref(x, w, b, activation="gelu").sum(), (0, 1)
    )(x, w)
    for g, r in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# honest CMU: transposed-variant vs copy-based candidates
# ---------------------------------------------------------------------------


def test_measure_kernel_times_transposed_and_copy_variants():
    g = GemmShape(64, 96, 64, name="probe.dx")
    t_zero = measure_kernel(g, Dataflow.OS, (64, 96, 64), iters=1,
                            trans=TRANS_DX, interpret=True)
    t_copy = measure_kernel(g, Dataflow.OS, (64, 96, 64), iters=1,
                            trans=TRANS_DX, via_copy=True, interpret=True)
    assert t_zero > 0 and t_copy > 0


def test_train_plan_bwd_subplans_carry_trans(monkeypatch):
    """Under a deterministic fake timer that charges the copy variant a
    penalty, both sub-plans pick the zero-copy layout; when the fake makes
    the copy free, the plan records the copy-based fallback instead — the
    re-ranking is driven by the measurement, not hardwired."""
    def fake_cheap_zero_copy(gemm, df, blk, **kw):
        base = hbm_traffic_bytes(gemm, df, *blk).time_s()
        return base * 10.0 if kw.get("via_copy") else base

    monkeypatch.setattr(cmu_mod, "measure_kernel", fake_cheap_zero_copy)
    plan = autotune_plan([GemmShape(64, 96, 64, name="l0")], top_k=2,
                         iters=1, train=True)
    lp = plan.layers[0]
    assert lp.bwd_dx.trans == TRANS_DX and lp.bwd_dw.trans == TRANS_DW
    assert lp.bwd_dx.source == "measured"

    def fake_cheap_copy(gemm, df, blk, **kw):
        base = hbm_traffic_bytes(gemm, df, *blk).time_s()
        return base * 0.1 if kw.get("via_copy") else base

    monkeypatch.setattr(cmu_mod, "measure_kernel", fake_cheap_copy)
    plan2 = autotune_plan([GemmShape(64, 96, 64, name="l0")], top_k=2,
                          iters=1, train=True)
    lp2 = plan2.layers[0]
    assert lp2.bwd_dx.trans == NO_TRANS and lp2.bwd_dw.trans == NO_TRANS


def test_unmeasured_bwd_subplans_default_to_zero_copy():
    """Analytically the zero-copy variant strictly dominates (same kernel
    traffic minus the copy), so measurement-off plans program it."""
    plan = autotune_plan([GemmShape(64, 96, 64, name="l0")], measure=False,
                         train=True)
    lp = plan.layers[0]
    assert lp.bwd_dx.trans == TRANS_DX and lp.bwd_dw.trans == TRANS_DW
    assert lp.bwd_dx.source == "analytical"


def test_real_measured_train_plan_runs_end_to_end():
    """No fakes: a real measured train plan tunes both layouts and its specs
    drive a correct grad through flex_linear."""
    plan = autotune_plan([GemmShape(32, 64, 32, name="l0")], top_k=1,
                         iters=1, train=True)
    lp = plan.layers[0]
    assert lp.bwd_dx.source == "measured"
    x, w = _rand((32, 64)), _rand((64, 32))
    dx_spec = (lp.bwd_dx.dataflow, lp.bwd_dx.block, lp.bwd_dx.trans)
    dw_spec = (lp.bwd_dw.dataflow, lp.bwd_dw.block, lp.bwd_dw.trans)
    got = jax.grad(
        lambda x, w: flex_linear(x, w, activation="gelu", interpret=True,
                                 bwd_dx=dx_spec, bwd_dw=dw_spec).sum(), (0, 1)
    )(x, w)
    want = jax.grad(
        lambda x, w: linear_ref(x, w, activation="gelu").sum(), (0, 1)
    )(x, w)
    for g, r in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# epilogue-aware autotune under a deterministic fake timer
# ---------------------------------------------------------------------------


def _rank_reversing_timer(seen):
    """Fake timer keyed on measurement order: bare candidates cost their
    call index (the first-measured, i.e. analytically-best, survivor wins);
    epilogue-sig candidates cost the *negated* index (the last-measured
    survivor wins).  Deterministic, and guarantees the two plans pick
    distinct (dataflow, block) configs whenever ``top_k > 1``."""

    def fake(gemm, df, blk, **kw):
        seen.append(kw.get("epilogue"))
        idx = float(len(seen))
        sig = kw.get("epilogue")
        if isinstance(sig, EpilogueSig) and sig.activation:
            return -idx
        return idx

    return fake


def test_epilogue_sig_reaches_the_timer_and_reranks(monkeypatch):
    seen = []
    monkeypatch.setattr(cmu_mod, "measure_kernel", _rank_reversing_timer(seen))
    gemms = [GemmShape(256, 512, 128, name="mlp.w1")]
    sig = {"mlp.w1": EpilogueSig(activation="gelu")}
    bare = autotune_plan(gemms, top_k=3, iters=1)
    fused = autotune_plan(gemms, top_k=3, iters=1, epilogue=sig)
    assert any(isinstance(s, EpilogueSig) for s in seen)
    b, f = bare.layers[0], fused.layers[0]
    assert (b.dataflow, b.block) != (f.dataflow, f.block)
    # determinism: identical inputs -> identical plans, both runs
    bare2 = autotune_plan(gemms, top_k=3, iters=1)
    fused2 = autotune_plan(gemms, top_k=3, iters=1, epilogue=sig)
    assert (bare2.layers[0].dataflow, bare2.layers[0].block) == (b.dataflow, b.block)
    assert (fused2.layers[0].dataflow, fused2.layers[0].block) == (f.dataflow, f.block)


def test_epilogue_dict_miss_means_bare_probe(monkeypatch):
    """A layer absent from the epilogue dict is timed as the bare matmul —
    its plan equals the bool-False plan under the same fake timer."""
    seen = []
    monkeypatch.setattr(cmu_mod, "measure_kernel", _rank_reversing_timer(seen))
    gemms = [GemmShape(256, 512, 128, name="attn.wq")]
    miss = autotune_plan(gemms, top_k=3, iters=1,
                         epilogue={"other": EpilogueSig(activation="gelu")})
    bare = autotune_plan(gemms, top_k=3, iters=1)
    assert (miss.layers[0].dataflow, miss.layers[0].block) == (
        bare.layers[0].dataflow, bare.layers[0].block)


def test_measure_kernel_accepts_full_epilogue_signature():
    g = GemmShape(32, 64, 32, name="mlp.w2")
    t = measure_kernel(g, Dataflow.OS, (32, 64, 32), iters=1, interpret=True,
                       epilogue=EpilogueSig(activation="silu", bias=True,
                                            residual=True))
    assert t > 0


def test_model_epilogues_match_layer_call_sites():
    from repro.core import model_epilogues
    from repro.models import get_config

    cfg = get_config("qwen3_4b", smoke=True)
    sigs = model_epilogues(cfg)
    assert sigs["mlp.w1"].activation in ("silu", "gelu")
    assert sigs["mlp.w2"].residual and sigs["attn.wo"].residual
    assert sigs["lm_head"] == EpilogueSig()
    assert sigs["attn.wq"].bias == cfg.qkv_bias


# ---------------------------------------------------------------------------
# plan-cache schema v3 + v1/v2 load-and-migrate
# ---------------------------------------------------------------------------


def _v2_payload():
    return {
        "version": 2,
        "layers": [{
            "name": "attn.wq", "M": 64, "K": 96, "N": 64,
            "dataflow": "OS", "est_cost": 1.0,
            "block": [64, 128, 64], "source": "measured",
            "bwd_dx": {"dataflow": "IS", "block": [64, 64, 128],
                       "est_cost": 0.9, "source": "measured"},
            "bwd_dw": {"dataflow": "WS", "block": [128, 64, 64],
                       "est_cost": 0.8, "source": "measured"},
        }],
    }


def test_v2_cache_migrates_bwd_subplans_to_zero_copy():
    """v2 sub-plans (tuned on pre-transposed operands) keep their
    (dataflow, block) — valid for the same logical GEMM — and are assigned
    their role's zero-copy layout."""
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "plan.json")
        with open(p, "w") as f:
            json.dump(_v2_payload(), f)
        plan = load_plan(p)
        lp = plan.layers[0]
        assert plan.has_bwd()
        assert lp.bwd_dx.trans == TRANS_DX and lp.bwd_dw.trans == TRANS_DW
        assert lp.bwd_dx.dataflow is Dataflow.IS
        assert lp.bwd_dx.block == (64, 64, 128)


def test_v1_cache_still_loads_fwd_only():
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "plan.json")
        with open(p, "w") as f:
            json.dump({"version": 1, "layers": [{
                "name": "attn.wq", "M": 64, "K": 96, "N": 64,
                "dataflow": "OS", "est_cost": 1.0,
                "block": [64, 128, 64], "source": "measured"}]}, f)
        plan = load_plan(p)
        assert plan.layers[0].bwd_dx is None and not plan.has_bwd()


def test_roundtrip_preserves_trans_and_writes_current_schema():
    plan = autotune_plan([GemmShape(64, 96, 64, name="l0")], measure=False,
                         train=True)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "plan.json")
        save_plan(p, plan)
        with open(p) as f:
            payload = json.load(f)
        assert payload["version"] == plan_cache_mod.PLAN_CACHE_VERSION
        assert payload["layers"][0]["bwd_dx"]["trans"] == [False, True]
        assert "strip" in payload["layers"][0]
        assert "strip" in payload["layers"][0]["bwd_dx"]
        plan2 = load_plan(p)
        assert plan2.layers == plan.layers


def test_migrated_v2_plan_drives_transpose_free_backward():
    """End-to-end: a migrated v2 cache's specs reach the VJP and the grad
    jaxpr stays free of transpose equations."""
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "plan.json")
        with open(p, "w") as f:
            json.dump(_v2_payload(), f)
        lp = load_plan(p).layers[0]
    x, w = _rand((64, 96)), _rand((96, 64))
    dx_spec = (lp.bwd_dx.dataflow, lp.bwd_dx.block, lp.bwd_dx.trans)
    dw_spec = (lp.bwd_dw.dataflow, lp.bwd_dw.block, lp.bwd_dw.trans)

    def loss(x, w):
        return flex_linear(x, w, activation="gelu", interpret=True,
                           bwd_dx=dx_spec, bwd_dw=dw_spec).sum()

    assert "transpose" not in _grad_prims(loss, x, w)
    got = jax.grad(loss, (0, 1))(x, w)
    want = jax.grad(
        lambda x, w: linear_ref(x, w, activation="gelu").sum(), (0, 1)
    )(x, w)
    for g, r in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   atol=2e-4, rtol=2e-4)


def test_migration_is_idempotent_and_counts():
    # a v2 row migrating to v4 gains: 2 sub-plan trans layouts + 3 strip=1
    # defaults (fwd row + both sub-plans) = 5 migrated fields
    rows = _v2_payload()["layers"]
    assert plan_cache_mod._migrate_rows(rows, 2) == 5
    assert plan_cache_mod._migrate_rows(rows, 2) == 0  # already migrated
    # a v3 row only gains the strip=1 fields
    v3_rows = _v2_payload()["layers"]
    for row in v3_rows:
        row["bwd_dx"]["trans"] = [False, True]
        row["bwd_dw"]["trans"] = [True, False]
    assert plan_cache_mod._migrate_rows(v3_rows, 3) == 3
    assert plan_cache_mod._migrate_rows(v3_rows, 4) == 0  # v4 untouched
