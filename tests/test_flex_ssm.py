"""Flex chunked-scan schedule family: the property sweep that keeps the
SSM kernel family honest — the scan edition of ``test_flex_attention``.

Pins four contracts:

  * **Value contract** — every (sweep, chunk, convention, ragged length,
    dtype) point matches the jnp chunked reference, and the two sweeps
    agree *bitwise* at a fixed chunk: both kernels run the identical
    ``_chunk_update`` op sequence, so changing where the running state
    lives (like changing a GEMM dataflow) may change traffic but never
    bits.  The fused decode step matches ``recurrent_step`` likewise.
  * **Pad contract** — zero pad rows are exact no-ops: output rows and the
    final state are bitwise invariant to ``T % chunk`` (this is what lets
    the planner pick arbitrary chunk lengths — and why the historical
    ``where(lw == 0, ...)`` guard was dead; see ``ssm._pad_chunks``).
  * **Planning contract** — fake-timer CMU tests: the measured ranking
    (not the analytical model) picks the prefill (sweep, chunk) and the
    per-bucket decode kind, mirroring the attention planning tests.
  * **Schema contract** — v7 plan caches load with ``scan=None`` and
    upgrade incrementally: every GEMM/decode/attention decision survives
    verbatim, and the file re-persists as v8.
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, st

from repro.core import (
    SCAN_CHUNK_CANDIDATES,
    autotune_plan,
    hbm_traffic_bytes,
    load_or_autotune,
    load_plan,
    model_epilogues,
    model_gemms,
    model_scan_shape,
    plan_matches,
    save_plan,
    scan_decode_traffic_bytes,
    scan_traffic_bytes,
)
from repro.core.plan_cache import PLAN_CACHE_VERSION
from repro.core import cmu as cmu_mod
from repro.kernels import SCAN_SWEEPS, flex_recurrent_step, flex_scan
from repro.models import get_config
from repro.models import ssm as S

RNG = np.random.default_rng(11)


def _inputs(B, T, H, N, M, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.normal(size=(B, T, H, N)), dtype)
    k = jnp.asarray(rng.normal(size=(B, T, H, N)), dtype)
    v = jnp.asarray(rng.normal(size=(B, T, H, M)), dtype)
    lw = jnp.clip(
        jnp.asarray(-np.abs(rng.normal(size=(B, T, H, N))), jnp.float32),
        S.LOG_DECAY_MIN, -1e-6,
    )
    return r, k, v, lw


def _bits(x) -> bytes:
    return np.asarray(x).tobytes()


# ---------------------------------------------------------------------------
# property sweep: schedule variant x chunk x convention x ragged T x dtype
# ---------------------------------------------------------------------------


@given(
    post=st.booleans(),
    chunk=st.sampled_from(list(SCAN_CHUNK_CANDIDATES)),
    seq=st.sampled_from([8, 24, 29, 40, 48]),
    dtype_name=st.sampled_from(["float32", "bfloat16"]),
)
@settings(max_examples=10, deadline=None)
def test_schedule_family_property_sweep(post, chunk, seq, dtype_name):
    """Every schedule point matches the jnp chunked reference; the two
    sweeps agree bitwise (same chunk -> same op sequence -> same bits)."""
    dtype = jnp.dtype(dtype_name)
    B, H, N, M = 1, 2, 8, 8
    r, k, v, lw = _inputs(B, seq, H, N, M, seed=seq * 31 + chunk, dtype=dtype)
    u = (None if post
         else jnp.asarray(RNG.normal(size=(H, N)), jnp.float32) * 0.5)
    pad = (-seq) % chunk
    rp, kp, vp, lwp = (S._pad_chunks(a, pad) for a in (r, k, v, lw))
    outs = {
        sweep: flex_scan(rp, kp, vp, lwp, u, chunk=chunk, sweep=sweep,
                         post_update=post, interpret=True)
        for sweep in SCAN_SWEEPS
    }
    pad_ref = (-seq) % S.LA_CHUNK  # reference needs its own chunk multiple
    rr, kr, vr, lwr = (S._pad_chunks(a.astype(jnp.float32), pad_ref)
                       for a in (r, k, v, lw))
    o_ref, S_ref = S.chunked_diag_linear_attn(rr, kr, vr, lwr, u,
                                              post_update=post)
    o_ref = o_ref[:, :seq]
    atol = 2e-4 if dtype == jnp.float32 else 0.1
    for sweep, (o, St) in outs.items():
        np.testing.assert_allclose(
            np.asarray(o[:, :seq], np.float32), np.asarray(o_ref, np.float32),
            atol=atol, rtol=atol, err_msg=f"sweep={sweep} output")
        np.testing.assert_allclose(
            np.asarray(St), np.asarray(S_ref),
            atol=atol, rtol=atol, err_msg=f"sweep={sweep} state")
    (o_a, S_a), (o_b, S_b) = outs["state"], outs["out"]
    assert _bits(o_a) == _bits(o_b) and _bits(S_a) == _bits(S_b), \
        "sweep order changed the bits: the variants diverged"


@given(seed=st.integers(0, 10_000), post=st.booleans(),
       chunk=st.sampled_from(list(SCAN_CHUNK_CANDIDATES)))
@settings(max_examples=8, deadline=None)
def test_pad_rows_are_exact_noops(seed, post, chunk):
    """Output rows and final state are *bitwise* invariant to the pad
    amount: running T rows padded to one chunk boundary vs. two extra
    chunks of zeros gives identical live outputs and state."""
    B, T, H, N, M = 1, 19, 2, 4, 8
    r, k, v, lw = _inputs(B, T, H, N, M, seed)
    pad = (-T) % chunk
    a = [S._pad_chunks(x, pad) for x in (r, k, v, lw)]
    b = [S._pad_chunks(x, pad + 2 * chunk) for x in (r, k, v, lw)]
    o_a, S_a = flex_scan(*a, None, chunk=chunk, post_update=post,
                         interpret=True)
    o_b, S_b = flex_scan(*b, None, chunk=chunk, post_update=post,
                         interpret=True)
    assert _bits(o_a[:, :T]) == _bits(o_b[:, :T])
    assert _bits(S_a) == _bits(S_b), \
        "final state depends on the pad amount — pad rows are not no-ops"


@pytest.mark.parametrize("post", [True, False])
def test_fused_decode_step_matches_recurrence(post):
    """The Pallas decode step is the jnp recurrence, fused: same outputs
    and same updated state to f32 tolerance."""
    B, H, N, M = 3, 2, 8, 8
    r, k, v, lw = _inputs(B, 1, H, N, M, seed=5)
    r, k, v, lw = r[:, 0], k[:, 0], v[:, 0], lw[:, 0]
    St = jnp.asarray(RNG.normal(size=(B, H, N, M)), jnp.float32)
    u = (None if post
         else jnp.asarray(RNG.normal(size=(H, N)), jnp.float32) * 0.5)
    o_f, S_f = flex_recurrent_step(r, k, v, lw, St, u, post_update=post,
                                   interpret=True)
    o_r, S_r = S.recurrent_step(r, k, v, lw, St, u, post_update=post)
    np.testing.assert_allclose(np.asarray(o_f), np.asarray(o_r),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(S_f), np.asarray(S_r),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# cost model cross-checks: the traffic trade the sweep knob buys
# ---------------------------------------------------------------------------


def test_sweep_traffic_trade():
    """state-stationary trades VMEM residency for HBM traffic: at the same
    chunk it moves strictly fewer HBM bytes (the state never streams) and
    holds strictly more VMEM (the whole state slab stays resident)."""
    shape = cmu_mod.ScanShape(batch=2, seq=4096, heads=8, key_dim=64,
                              val_dim=64)
    for chunk in SCAN_CHUNK_CANDIDATES:
        st_cost = scan_traffic_bytes(shape, "state", chunk)
        out_cost = scan_traffic_bytes(shape, "out", chunk)
        assert st_cost.hbm_bytes < out_cost.hbm_bytes, chunk
        assert st_cost.vmem_bytes > out_cost.vmem_bytes, chunk


def test_decode_traffic_einsum_pays_intermediate():
    """The jnp decode recurrence materializes the k v^T intermediate in
    HBM; the fused step kernel never does — the analytical model must
    reflect that or the planner's default ranking is meaningless."""
    shape = cmu_mod.ScanShape(batch=1, seq=1, heads=8, key_dim=64,
                              val_dim=64)
    for bucket in (1, 8, 32):
        fused = scan_decode_traffic_bytes(shape, "fused", bucket)
        einsum = scan_decode_traffic_bytes(shape, "einsum", bucket)
        assert fused.hbm_bytes < einsum.hbm_bytes, bucket


# ---------------------------------------------------------------------------
# CMU planning: fake-timer tests + v7 -> v8 migration
# ---------------------------------------------------------------------------


CFG = lambda: get_config("zamba2_7b", smoke=True).replace(  # noqa: E731
    use_pallas=True, ssm_pallas=True)
GEMMS = lambda cfg: model_gemms(cfg, tokens=64)  # noqa: E731


def _fast_gemm_timer(monkeypatch):
    """Route GEMM measurement through the analytical model so the scan
    planning tests don't spend their budget timing projection kernels."""
    monkeypatch.setattr(
        cmu_mod, "measure_kernel",
        lambda gemm, df, blk, **kw: hbm_traffic_bytes(gemm, df, *blk).time_s())


def test_scan_tuning_is_measurement_driven(monkeypatch):
    """Under a fake timer that penalizes whatever schedule the analytical
    model ranks first, the measured plan lands on a different (sweep,
    chunk) — the schedule comes from the timed execution, not the ranking."""
    cfg = CFG()
    scan = model_scan_shape(cfg, 64)
    analytic = autotune_plan(GEMMS(cfg), measure=False, scan=scan)
    sp0 = analytic.scan_plan()
    assert sp0 is not None and sp0.source == "analytical"
    pick = (sp0.sweep, sp0.chunk)

    def fake(shape, sweep, chunk, **kw):
        base = scan_traffic_bytes(shape, sweep, chunk).time_s()
        return base * 100.0 if (sweep, chunk) == pick else base

    _fast_gemm_timer(monkeypatch)
    monkeypatch.setattr(cmu_mod, "measure_scan", fake)
    plan = autotune_plan(GEMMS(cfg), measure=True, iters=1, scan=scan)
    sp = plan.scan_plan()
    assert sp is not None and sp.source == "measured"
    assert (sp.sweep, sp.chunk) != pick, \
        "measured tuning returned the penalized analytical pick"


@pytest.mark.parametrize("slow", ["fused", "einsum"])
def test_scan_decode_kind_is_measurement_driven(monkeypatch, slow):
    """Per-bucket decode-kind choice follows the fake timer both ways:
    penalize 'fused' and the plan picks 'einsum', and vice versa."""
    cfg = CFG()
    scan = model_scan_shape(cfg, 64)
    fast = {"fused": "einsum", "einsum": "fused"}[slow]

    def fake_decode(shape, bucket, kind, **kw):
        return 1.0 if kind == slow else 1e-6

    _fast_gemm_timer(monkeypatch)
    monkeypatch.setattr(
        cmu_mod, "measure_scan",
        lambda shape, sweep, chunk, **kw:
            scan_traffic_bytes(shape, sweep, chunk).time_s())
    monkeypatch.setattr(cmu_mod, "measure_scan_decode", fake_decode)
    plan = autotune_plan(GEMMS(cfg), measure=True, iters=1, scan=scan,
                         decode_buckets=(8, 16))
    sp = plan.scan_plan()
    assert sp is not None and set(sp.decode) == {8, 16}
    for b, sub in sp.decode.items():
        assert sub.sweep == fast, (b, sub)
        assert sub.source == "measured"


def test_v7_cache_loads_with_scan_none_and_upgrades(tmp_path):
    """A v7 file (no scan rows) loads with scan=None; a scan-requesting
    load_or_autotune upgrades it incrementally — every GEMM, decode and
    attention decision survives verbatim, only the scan schedule is tuned,
    and the file re-persists as v8."""
    cfg = CFG()
    scan = model_scan_shape(cfg, 64)
    plan = autotune_plan(GEMMS(cfg), measure=False, decode_buckets=(8,),
                         epilogue=model_epilogues(cfg))
    path = os.path.join(tmp_path, "plan.json")
    save_plan(path, plan)
    with open(path) as f:
        payload = json.load(f)
    payload["version"] = 7
    for row in payload["layers"]:
        row.pop("scan", None)
    with open(path, "w") as f:
        json.dump(payload, f)

    v7 = load_plan(path)
    assert all(lp.scan is None for lp in v7.layers)
    assert plan_matches(v7, GEMMS(cfg), buckets=(8,))  # scan-less: fine
    assert not plan_matches(v7, GEMMS(cfg), buckets=(8,), scan=scan)

    before = {
        lp.name: (lp.dataflow, lp.block, lp.strip, lp.bwd_dx, lp.bwd_dw,
                  lp.mesh, lp.decode, lp.attention)
        for lp in v7.layers
    }
    up, loaded = load_or_autotune(path, GEMMS(cfg), buckets=(8,), scan=scan,
                                  measure=False,
                                  epilogue=model_epilogues(cfg))
    assert not loaded  # it had to tune (the scan row)
    assert up.has_scan((8,))
    sp = up.scan_plan()
    assert sp is not None and sp.sweep in SCAN_SWEEPS and 8 in sp.decode
    assert sp.chunk in SCAN_CHUNK_CANDIDATES
    for lp in up.layers:
        assert (lp.dataflow, lp.block, lp.strip, lp.bwd_dx, lp.bwd_dw,
                lp.mesh, lp.decode, lp.attention) == before[lp.name], \
            f"incremental scan upgrade retuned {lp.name}"
    with open(path) as f:
        assert json.load(f)["version"] == PLAN_CACHE_VERSION
    again, loaded = load_or_autotune(path, GEMMS(cfg), buckets=(8,),
                                     scan=scan, measure=False)
    assert loaded  # second launch reloads, no tuning
