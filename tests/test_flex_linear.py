"""Fused-epilogue flex kernels + measured-autotune CMU + plan cache.

The PR's acceptance bar: fused ``flex_linear`` (bias + activation + residual
+ dtype cast inside the kernel flush) must match the unfused f32 reference
to <= 1e-5 across all three dataflows and padded/unpadded shapes, and an
autotuned plan must survive a save -> load roundtrip bit-identically.
"""

import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ALL_DATAFLOWS,
    DataflowPlan,
    GemmShape,
    activate_plan,
    autotune_plan,
    load_or_autotune,
    load_plan,
    measure_kernel,
    model_gemms,
    save_plan,
)
from repro.kernels import flex_linear, linear_ref

RNG = np.random.default_rng(7)


def _rand(shape, dtype=jnp.float32, scale=0.2):
    return jnp.asarray(RNG.normal(size=shape) * scale, np.float32).astype(dtype)


# aligned (block-multiple) and unaligned (exercises the pad/unpad path)
SHAPES = [(128, 128, 128), (256, 384, 128), (96, 200, 130), (57, 300, 111)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("df", ALL_DATAFLOWS)
def test_fused_equals_unfused_all_dataflows(shape, df):
    M, K, N = shape
    x, w = _rand((M, K)), _rand((K, N))
    b, res = _rand((N,)), _rand((M, N))
    out = flex_linear(
        x, w, b, activation="gelu", residual=res, dataflow=df,
        block=(128, 128, 128), interpret=True,
    )
    ref = linear_ref(x, w, b, activation="gelu", residual=res)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("df", ALL_DATAFLOWS)
@pytest.mark.parametrize("activation", [None, "relu", "silu"])
def test_epilogue_pieces_compose(df, activation):
    """bias-only / act-only / residual-only combinations all match."""
    x, w = _rand((130, 96)), _rand((96, 140))
    b, res = _rand((140,)), _rand((130, 140))
    for bias in (None, b):
        for r in (None, res):
            out = flex_linear(
                x, w, bias, activation=activation, residual=r, dataflow=df,
                block=(128, 128, 128), interpret=True,
            )
            ref = linear_ref(x, w, bias, activation=activation, residual=r)
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5
            )


@pytest.mark.parametrize("df", ALL_DATAFLOWS)
def test_fused_output_dtype_cast(df):
    """The dtype cast runs inside the kernel: output arrives as bf16."""
    x, w, b = _rand((64, 64)), _rand((64, 64)), _rand((64,))
    out = flex_linear(
        x, w, b, activation="gelu", dataflow=df, block=(64, 64, 64),
        interpret=True, out_dtype=jnp.bfloat16,
    )
    assert out.dtype == jnp.bfloat16
    ref = linear_ref(x, w, b, activation="gelu")
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), atol=0.02, rtol=0.02
    )


def test_fused_big_blocks_honoured():
    """CMU-tuned blocks above 128 must not be silently clamped."""
    x, w = _rand((300, 500)), _rand((500, 260))
    out = flex_linear(
        x, w, None, dataflow=ALL_DATAFLOWS[0], block=(256, 512, 256),
        interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(linear_ref(x, w)), atol=1e-5, rtol=1e-5
    )


# ---------------------------------------------------------------------------
# measured autotune + plan cache
# ---------------------------------------------------------------------------

GEMMS = [
    GemmShape(64, 96, 64, name="attn.wq"),
    GemmShape(64, 64, 128, name="mlp.w1"),
    GemmShape(64, 128, 64, name="mlp.w2"),
]


def test_measure_kernel_returns_walltime():
    t = measure_kernel(GEMMS[0], ALL_DATAFLOWS[0], (64, 128, 64), iters=1)
    assert 0.0 < t < 60.0


def test_autotune_plan_measures_and_records_blocks():
    plan = autotune_plan(GEMMS, top_k=2, iters=1)
    assert len(plan.layers) == len(GEMMS)
    for lp in plan.layers:
        assert lp.source == "measured"
        assert lp.block is not None and len(lp.block) == 3
        assert lp.dataflow in ALL_DATAFLOWS
        assert lp.est_cost > 0.0


def test_autotune_falls_back_to_analytical_when_unmeasurable():
    plan = autotune_plan(GEMMS[:1], measure=False)
    assert plan.layers[0].source == "analytical"
    # a GEMM too large for interpret-mode timing also falls back
    big = [GemmShape(4096, 4096, 4096, name="big")]
    plan = autotune_plan(big, interpret=True)
    assert plan.layers[0].source == "analytical"


def test_plan_save_load_roundtrip_identical():
    plan = autotune_plan(GEMMS, top_k=2, iters=1)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "plan.json")
        save_plan(p, plan)
        plan2 = load_plan(p)
        assert plan2.layers == plan.layers  # LayerPlan is a frozen dataclass
        # serve/train entry point: second call must reload, not re-tune
        plan3, loaded = load_or_autotune(p, GEMMS)
        assert loaded and plan3.layers == plan.layers


def test_stale_plan_for_other_shapes_is_retuned():
    """A cache tuned for different GEMMs must not be silently applied."""
    plan = autotune_plan(GEMMS, measure=False)
    other = [GemmShape(128, 256, 512, name="attn.wq")]
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "plan.json")
        save_plan(p, plan)
        plan2, loaded = load_or_autotune(p, other, measure=False)
        assert not loaded  # shape mismatch -> re-tuned
        assert [l.gemm for l in plan2.layers] == other
        # and the cache now holds the re-tuned plan
        plan3, loaded3 = load_or_autotune(p, other, measure=False)
        assert loaded3 and plan3.layers == plan2.layers


def test_plan_cache_version_guard():
    import json

    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "plan.json")
        with open(p, "w") as f:
            json.dump({"version": 999, "layers": []}, f)
        with pytest.raises(ValueError, match="version"):
            load_plan(p)


def test_legacy_plan_json_roundtrip_without_block():
    """Plans serialized before block/source existed still load."""
    import json

    rows = [{"name": "l0", "M": 8, "K": 8, "N": 8, "dataflow": "OS", "est_cost": 1.0}]
    plan = DataflowPlan.from_json(json.dumps(rows))
    assert plan.layers[0].block is None
    assert plan.layers[0].source == "analytical"


# ---------------------------------------------------------------------------
# model integration: pallas path == XLA path under an activated plan
# ---------------------------------------------------------------------------


def test_model_forward_pallas_matches_xla():
    import jax

    from repro.models import Model, get_config

    cfg = get_config("qwen3_4b", smoke=True).replace(
        dtype="float32", param_dtype="float32"
    )
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab_size)}
    ref, _ = m.forward(params, batch)

    plan = autotune_plan(model_gemms(cfg, tokens=32), top_k=1, iters=1)
    activate_plan(plan)
    try:
        out, _ = Model(cfg.replace(use_pallas=True)).forward(params, batch)
    finally:
        activate_plan(None)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5
    )
