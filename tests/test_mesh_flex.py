"""Mesh-native flex kernel tests.

Two tiers:

* single-device tests (always run): the mesh planning level of the CMU —
  local-shape math, ``MeshPlan`` serialization, plan-cache schema v5 with
  the mesh fingerprint, v4 migration + incremental upgrade, and the
  ``dp_size`` single-definition pin.
* multi-device tests (skipped unless jax has >= 8 devices — the CI
  ``multi-device`` lane runs this file under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``): the
  shard_map-composed kernels themselves — each mesh dataflow against the
  XLA reference for forward and ``jax.grad``, the ``models.layers.linear``
  routing + fallback contract, and the involuntary-replication warning.
"""

import dataclasses
import json
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Dataflow,
    GemmShape,
    MeshSpec,
    autotune_plan,
    mesh_local_gemm,
    mesh_shardable,
)
from repro.core.plan_cache import (
    PLAN_CACHE_VERSION,
    activate_plan,
    load_or_autotune,
    load_plan,
    plan_matches,
    save_plan,
)

multi_device = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

MESH_SPEC = MeshSpec(axes=(("data", 2), ("model", 4)), dp_axes=("data",))
TUNE_KW = dict(measure=False)  # analytical-only: no kernel timing in tests


# ---------------------------------------------------------------------------
# single-device: the mesh planning level
# ---------------------------------------------------------------------------


def test_mesh_local_gemm_shapes():
    g = GemmShape(256, 64, 128, name="p")
    assert mesh_local_gemm(g, Dataflow.WS, tp=4, dp=2) == GemmShape(128, 16, 128, name="p.shard")
    assert mesh_local_gemm(g, Dataflow.IS, tp=4, dp=2) == GemmShape(32, 64, 128, name="p.shard")
    assert mesh_local_gemm(g, Dataflow.OS, tp=4, dp=2) == GemmShape(32, 16, 128, name="p.shard")


def test_mesh_shardable_gate():
    assert mesh_shardable(GemmShape(256, 64, 128), tp=4, dp=2)
    assert not mesh_shardable(GemmShape(250, 64, 128), tp=4, dp=2)  # M ragged
    assert not mesh_shardable(GemmShape(256, 62, 128), tp=4, dp=2)  # K ragged
    assert not mesh_shardable(GemmShape(256, 64, 128), tp=1)        # no TP


def _tuned_plan(train=True):
    gemms = [GemmShape(256, 64, 128, name="mlp.w1"),
             GemmShape(256, 128, 64, name="mlp.w2")]
    return gemms, autotune_plan(gemms, train=train, mesh=MESH_SPEC, **TUNE_KW)


def test_mesh_subplans_tuned_for_post_collective_shapes():
    _, plan = _tuned_plan()
    assert plan.mesh == MESH_SPEC
    for lp in plan.layers:
        mp = lp.mesh
        assert mp is not None and mp.tp == 4 and mp.dp == 2
        assert mp.axis == "model"
        assert mp.local is not None and mp.local_dx is not None
        lshape = mesh_local_gemm(lp.gemm, mp.dataflow, mp.tp, mp.dp)
        # the local block never exceeds the (rounded) local shard dims —
        # evidence the chip-level tuner saw the post-collective shape
        bm, bk, bn = mp.local.block
        assert bm <= max(lshape.M, 128) and bk <= max(lshape.K, 128)
        assert mp.comm_bytes > 0


def test_non_dividing_layer_gets_no_mesh_subplan():
    gemms = [GemmShape(250, 64, 128, name="ragged")]
    plan = autotune_plan(gemms, mesh=MESH_SPEC, **TUNE_KW)
    assert plan.layers[0].mesh is None  # falls back at dispatch


def test_plan_json_roundtrip_with_mesh(tmp_path):
    from repro.core import DataflowPlan

    _, plan = _tuned_plan()
    assert DataflowPlan.from_json(plan.to_json()).layers == plan.layers
    p = tmp_path / "plan.json"
    save_plan(str(p), plan)
    loaded = load_plan(str(p))
    assert loaded.mesh == MESH_SPEC
    assert loaded.layers == plan.layers
    assert json.load(open(p))["version"] == PLAN_CACHE_VERSION


def _as_v4_file(v5_path, v4_path):
    """Strip the v5-only fields, producing the file a v4 build would write."""
    payload = json.load(open(v5_path))
    payload["version"] = 4
    payload.pop("mesh")
    for row in payload["layers"]:
        row.pop("mesh")
    json.dump(payload, open(v4_path, "w"))


def test_v4_cache_loads_as_single_device_bit_for_bit(tmp_path):
    gemms, plan = _tuned_plan()
    v5, v4 = tmp_path / "v5.json", tmp_path / "v4.json"
    save_plan(str(v5), plan)
    _as_v4_file(v5, v4)
    loaded = load_plan(str(v4))
    assert loaded.mesh is None
    # every single-device decision identical — dispatch is bit-for-bit
    assert [dataclasses.replace(l, mesh=None) for l in plan.layers] \
        == list(loaded.layers)
    # and it still matches a single-device request (loads without re-tune)
    assert plan_matches(loaded, gemms, require_bwd=True)
    got, was_loaded = load_or_autotune(str(v4), gemms, require_bwd=True,
                                       **TUNE_KW)
    assert was_loaded and got.layers == loaded.layers


def test_v4_cache_migrates_to_v5_mesh_incrementally(tmp_path):
    gemms, plan = _tuned_plan()
    v5, v4 = tmp_path / "v5.json", tmp_path / "v4.json"
    save_plan(str(v5), plan)
    _as_v4_file(v5, v4)
    # a mesh request on the v4 file must not match as-is...
    assert not plan_matches(load_plan(str(v4)), gemms, mesh=MESH_SPEC)
    # ...and upgrades incrementally: single-device rows kept verbatim,
    # mesh sub-plans added, file rewritten at v5
    got, was_loaded = load_or_autotune(str(v4), gemms, require_bwd=True,
                                       mesh=MESH_SPEC, **TUNE_KW)
    assert not was_loaded
    assert [dataclasses.replace(l, mesh=None) for l in got.layers] \
        == [dataclasses.replace(l, mesh=None) for l in plan.layers]
    assert got.mesh == MESH_SPEC
    assert all(l.mesh is not None for l in got.layers)
    payload = json.load(open(v4))
    assert payload["version"] == PLAN_CACHE_VERSION and payload["mesh"] is not None


def test_plan_matches_rejects_other_mesh():
    gemms, plan = _tuned_plan()
    other = MeshSpec(axes=(("data", 1), ("model", 8)), dp_axes=("data",))
    assert plan_matches(plan, gemms, mesh=MESH_SPEC)
    assert not plan_matches(plan, gemms, mesh=other)
    # a mesh-tuned plan still serves a single-device request
    assert plan_matches(plan, gemms)


class _FakeMesh:
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_dp_size_single_definition():
    """The canonical launch.mesh.dp_size and the rules-context wrapper
    models.sharding.dp_size agree on the production meshes."""
    from repro.launch.mesh import dp_axes, dp_size
    from repro.models import sharding

    for shape in ({"data": 16, "model": 16},
                  {"pod": 2, "data": 16, "model": 16},
                  {"data": 4, "model": 2}):
        mesh = _FakeMesh(shape)
        with sharding.use_rules(mesh):
            assert sharding.dp_size() == dp_size(mesh)
        assert dp_size(mesh) == dp_size(mesh, dp_axes(mesh))
    assert sharding.dp_size() == 1  # outside any rules context


# ---------------------------------------------------------------------------
# multi-device: the shard_map-composed kernels
# ---------------------------------------------------------------------------


def _mesh24():
    return jax.make_mesh((2, 4), ("data", "model"))


def _linear_case(M=64, K=32, N=48, bias=True, residual=True):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (M, K), jnp.float32)
    w = jax.random.normal(ks[1], (K, N), jnp.float32) * 0.1
    b = jax.random.normal(ks[2], (N,), jnp.float32) if bias else None
    r = jax.random.normal(ks[3], (M, N), jnp.float32) if residual else None
    return x, w, b, r


@multi_device
@pytest.mark.parametrize("mesh_df", [Dataflow.WS, Dataflow.IS, Dataflow.OS])
@pytest.mark.parametrize("epilogue", [(None, False, False), ("gelu", True, True)])
def test_sharded_matches_reference_fwd_and_grad(mesh_df, epilogue):
    """Acceptance: each mesh dataflow == the XLA/GSPMD reference to f32
    tolerance, forward and jax.grad."""
    from repro.core.cmu import GemmPlan, MeshPlan
    from repro.kernels import linear_ref
    from repro.kernels.mesh_ops import flex_linear_sharded

    activation, bias, residual = epilogue
    x, w, b, r = _linear_case(bias=bias, residual=residual)
    mesh = _mesh24()
    plan = MeshPlan(dataflow=mesh_df, axis="model", tp=4, dp=2,
                    local=GemmPlan(dataflow=Dataflow.OS, block=(64, 64, 64),
                                   est_cost=0.0))

    def f(x, w, b, r):
        return flex_linear_sharded(
            x, w, b, mesh=mesh, axis="model", dp_axes=("data",),
            activation=activation, residual=r, plan=plan, interpret=True,
        )

    ref = linear_ref(x, w, b, activation=activation, residual=r)
    out = jax.jit(f)(x, w, b, r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)

    argnums = (0, 1) + ((2,) if bias else ()) + ((3,) if residual else ())
    g = jax.grad(lambda *a: (f(*a) ** 2).sum(), argnums=argnums)(x, w, b, r)
    g_ref = jax.grad(
        lambda *a: (linear_ref(a[0], a[1], a[2], activation=activation,
                               residual=a[3]) ** 2).sum(),
        argnums=argnums,
    )(x, w, b, r)
    for got, want in zip(g, g_ref):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-4, rtol=2e-4)


@multi_device
def test_sharded_trace_time_fallback_plan_none():
    """plan=None picks the mesh dataflow from the analytical ICI model at
    trace time — same numbers, no plan required."""
    from repro.kernels import linear_ref
    from repro.kernels.mesh_ops import flex_linear_sharded

    x, w, b, r = _linear_case()
    out = flex_linear_sharded(
        x, w, b, mesh=_mesh24(), axis="model", dp_axes=("data",),
        activation="relu", residual=r, plan=None, interpret=True,
    )
    ref = linear_ref(x, w, b, activation="relu", residual=r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


@multi_device
def test_layers_linear_routes_mesh_native_and_falls_back():
    """models.layers.linear under a rules context matches the single-device
    kernel path; a non-dividing GEMM falls back cleanly (attention-path
    contract)."""
    from repro.models.config import ModelConfig
    from repro.models.layers import linear
    from repro.models.sharding import use_rules

    cfg = ModelConfig(use_pallas=True, dtype="float32")
    mesh = _mesh24()
    kx, kw = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (2, 16, 64), jnp.float32)   # M = 32 divides 8
    w = jax.random.normal(kw, (64, 128), jnp.float32) * 0.1
    ref = linear(cfg, x, w, activation="silu", name="mlp.w1")
    with use_rules(mesh):
        out = jax.jit(lambda x: linear(cfg, x, w, activation="silu",
                                       name="mlp.w1"))(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)

    # gradient through the routed path
    loss = lambda w: (linear(cfg, x, w, activation="silu", name="mlp.w1") ** 2).mean()
    g_ref = jax.grad(loss)(w)
    with use_rules(mesh):
        g = jax.jit(jax.grad(loss))(w)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               atol=2e-4, rtol=2e-4)

    # ragged K: 62 % 4 != 0 -> single-device fallback, same numbers
    w_r = jax.random.normal(kw, (62, 128), jnp.float32) * 0.1
    x_r = jax.random.normal(kx, (2, 16, 62), jnp.float32)
    ref_r = linear(cfg, x_r, w_r, name="mlp.w1")
    with use_rules(mesh):
        out_r = linear(cfg, x_r, w_r, name="mlp.w1")
    np.testing.assert_allclose(np.asarray(out_r), np.asarray(ref_r),
                               atol=1e-5, rtol=1e-5)


@multi_device
def test_layers_linear_uses_planned_mesh_subplan():
    """An activated plan's mesh sub-plan drives the routed dispatch."""
    from repro.models.config import ModelConfig
    from repro.models.layers import linear
    from repro.models.sharding import use_rules

    gemms = [GemmShape(32, 64, 128, name="mlp.w1")]
    spec = MeshSpec(axes=(("data", 2), ("model", 4)), dp_axes=("data",))
    plan = autotune_plan(gemms, mesh=spec, **TUNE_KW)
    assert plan.layers[0].mesh is not None
    cfg = ModelConfig(use_pallas=True, dtype="float32")
    kx, kw = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(kx, (2, 16, 64), jnp.float32)
    w = jax.random.normal(kw, (64, 128), jnp.float32) * 0.1
    ref = linear(cfg, x, w, name="mlp.w1")
    activate_plan(plan)
    try:
        with use_rules(_mesh24()):
            out = jax.jit(lambda x: linear(cfg, x, w, name="mlp.w1"))(x)
    finally:
        activate_plan(None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


@multi_device
def test_constrain_warns_once_on_involuntary_replication(caplog):
    """An axis whose dim doesn't divide the mesh extent is replicated with
    one warning per (axis, shape) site — visible in logs, not silent."""
    from repro.models import sharding

    mesh = _mesh24()
    x = jnp.zeros((2, 6, 8))  # 6 % 4 != 0 on the model axis
    sharding._REPLICATION_WARNED.clear()
    with sharding.use_rules(mesh):
        with caplog.at_level(logging.WARNING, logger="repro.models.sharding"):
            sharding.constrain(x, "act_batch", "act_seq", None)
            warned = [r for r in caplog.records if "act_seq" in r.message]
            assert len(warned) == 1
            assert "replicating" in warned[0].message
            # second identical call: no new warning (once per site)
            sharding.constrain(x, "act_batch", "act_seq", None)
            assert len([r for r in caplog.records
                        if "act_seq" in r.message]) == 1
            # a different shape is a different site
            sharding.constrain(jnp.zeros((2, 10, 8)), "act_batch", "act_seq",
                               None)
            assert len([r for r in caplog.records
                        if "act_seq" in r.message]) == 2
