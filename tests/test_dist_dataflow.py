"""Tests for core.dist_dataflow — the mesh-level CMU.

Property tests pin the WS/IS/OS ICI comm-byte formulas (the wire bytes of
the schedules ``kernels.mesh_ops`` emits) and the crossover regimes
``plan_mesh``'s module docstring claims: decode -> WS, train -> IS,
square-huge-both -> OS.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent))
from _propcheck import given, settings, st  # noqa: E402

from repro.core.dataflow import ALL_DATAFLOWS, Dataflow, GemmShape  # noqa: E402
from repro.core.dist_dataflow import (  # noqa: E402
    MESH_GATHER_BUDGET_BYTES,
    MeshSpec,
    best_mesh_dataflow,
    mesh_gemm_cost,
    plan_mesh,
)

TPS = [2, 4, 8, 16]


# ---------------------------------------------------------------------------
# comm-byte formulas
# ---------------------------------------------------------------------------


@settings(max_examples=24, deadline=None)
@given(
    st.integers(min_value=8, max_value=65536),
    st.integers(min_value=64, max_value=16384),
    st.integers(min_value=64, max_value=16384),
    st.sampled_from(TPS),
)
def test_comm_byte_formulas(M, K, N, tp):
    g = GemmShape(M, K, N)
    b = 2
    ring = (tp - 1) / tp
    ws = mesh_gemm_cost(g, Dataflow.WS, tp)
    is_ = mesh_gemm_cost(g, Dataflow.IS, tp)
    os_ = mesh_gemm_cost(g, Dataflow.OS, tp)
    # WS: all-gather(A) at input dtype + reduce-scatter of f32 partials
    # (4 B on the wire — what mesh_ops actually psum-scatters), both exposed
    assert ws.comm_bytes == int((M * K * b + M * N * 4) * ring)
    assert ws.gather_bytes == M * K * b and not ws.pipelined
    # IS: all-gather(B), prefetchable; materialises the full weight
    assert is_.comm_bytes == int(K * N * b * ring)
    assert is_.gather_bytes == K * N * b and is_.pipelined
    # OS: rotate(B) — same wire bytes as the IS gather, 1/tp residency,
    # one local launch per ring hop
    assert os_.comm_bytes == is_.comm_bytes
    assert os_.gather_bytes == 2 * K * N * b // tp
    assert os_.pipelined and os_.ring_steps == tp
    # FLOPs split evenly in every schedule
    assert ws.flops_per_chip == is_.flops_per_chip == g.flops // tp


@settings(max_examples=12, deadline=None)
@given(
    st.integers(min_value=64, max_value=4096),
    st.integers(min_value=64, max_value=4096),
    st.integers(min_value=64, max_value=4096),
    st.sampled_from(TPS),
)
def test_time_model_structure(M, K, N, tp):
    g = GemmShape(M, K, N)
    ws = mesh_gemm_cost(g, Dataflow.WS, tp)
    # WS comm is exposed: overlap=0 adds, overlap=1 hides down to max()
    t_c = ws.flops_per_chip / 197e12
    t_m = ws.comm_bytes / 50e9
    assert abs(ws.time_s(overlap=0.0) - (t_c + t_m)) < 1e-12
    assert abs(ws.time_s(overlap=1.0) - max(t_c, t_m)) < 1e-12
    # pipelined schedules overlap: IS runs at max(compute, gather); the OS
    # ring's comm floor is the full ring period, comm * tp/(tp-1)
    is_ = mesh_gemm_cost(g, Dataflow.IS, tp)
    t_is = is_.comm_bytes / 50e9
    assert abs(is_.time_s() - max(is_.flops_per_chip / 197e12, t_is)) < 1e-12
    os_ = mesh_gemm_cost(g, Dataflow.OS, tp)
    t_os = os_.comm_bytes / 50e9 * tp / (tp - 1)
    assert abs(os_.time_s() - max(os_.flops_per_chip / 197e12, t_os)) < 1e-12
    # so OS is never faster than the IS gather it replaces — only cheaper
    # in per-chip residency
    assert os_.time_s() >= is_.time_s() - 1e-15


# ---------------------------------------------------------------------------
# crossover regimes (the plan_mesh docstring's claims)
# ---------------------------------------------------------------------------


@settings(max_examples=16, deadline=None)
@given(
    st.integers(min_value=8, max_value=256),
    st.integers(min_value=1024, max_value=4096),
    st.integers(min_value=1024, max_value=4096),
    st.sampled_from(TPS),
)
def test_decode_shapes_prefer_ws(M, K, N, tp):
    """Decode: M ~ batch << K, N — moving the tiny activations wins."""
    df, cost = best_mesh_dataflow(GemmShape(M, K, N), tp)
    assert df is Dataflow.WS, (M, K, N, tp, df)
    assert cost.comm_bytes < mesh_gemm_cost(GemmShape(M, K, N), Dataflow.IS, tp).comm_bytes


@settings(max_examples=16, deadline=None)
@given(
    st.integers(min_value=16384, max_value=131072),
    st.integers(min_value=1024, max_value=4096),
    st.integers(min_value=1024, max_value=4096),
    st.sampled_from(TPS),
)
def test_train_shapes_prefer_is(M, K, N, tp):
    """Training: M = tokens >> K*N/(K+N) and the weight fits the gather
    budget — gather the small static weights, keep the fused local kernel."""
    assert K * N * 2 <= MESH_GATHER_BUDGET_BYTES  # the regime's premise
    df, _ = best_mesh_dataflow(GemmShape(M, K, N), tp)
    assert df is Dataflow.IS, (M, K, N, tp, df)


@settings(max_examples=12, deadline=None)
@given(
    st.integers(min_value=12288, max_value=32768),
    st.sampled_from(TPS),
)
def test_square_huge_shapes_prefer_os(S, tp):
    """Square-ish with both operands huge: gathering either full operand
    busts the per-chip budget — only the OS ring stays feasible."""
    g = GemmShape(S, S, S)
    assert S * S * 2 > MESH_GATHER_BUDGET_BYTES  # IS and WS both infeasible
    df, cost = best_mesh_dataflow(g, tp)
    assert df is Dataflow.OS, (S, tp, df)
    # OS residency is 1/tp of the gathered-weight footprint (double-buffered)
    assert cost.gather_bytes == 2 * S * S * 2 // tp


def test_os_is_always_feasible():
    """OS is the escape hatch: even a zero gather budget returns a plan."""
    df, _ = best_mesh_dataflow(GemmShape(4096, 4096, 4096), 8, gather_budget=0)
    assert df is Dataflow.OS


def test_plan_mesh_is_per_layer_argmin():
    gemms = [
        GemmShape(64, 2048, 2048, name="decode.proj"),
        GemmShape(65536, 2048, 2048, name="train.proj"),
        GemmShape(16384, 16384, 16384, name="square.proj"),
    ]
    plan = plan_mesh(gemms, tp=8)
    assert plan["decode.proj"] is Dataflow.WS
    assert plan["train.proj"] is Dataflow.IS
    assert plan["square.proj"] is Dataflow.OS
    for g in gemms:
        assert plan[g.name] is best_mesh_dataflow(g, 8)[0]


@settings(max_examples=12, deadline=None)
@given(
    st.integers(min_value=64, max_value=8192),
    st.integers(min_value=64, max_value=8192),
    st.integers(min_value=64, max_value=8192),
    st.sampled_from(TPS),
)
def test_best_never_slower_than_feasible_alternatives(M, K, N, tp):
    g = GemmShape(M, K, N)
    df, _ = best_mesh_dataflow(g, tp)
    best_t = mesh_gemm_cost(g, df, tp).time_s()
    for other in ALL_DATAFLOWS:
        c = mesh_gemm_cost(g, other, tp)
        if c.gather_bytes <= MESH_GATHER_BUDGET_BYTES:
            assert best_t <= c.time_s() + 1e-15


# ---------------------------------------------------------------------------
# MeshSpec fingerprint
# ---------------------------------------------------------------------------


class _FakeMesh:
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_mesh_spec_roundtrip_and_extents():
    spec = MeshSpec(axes=(("data", 2), ("model", 4)), dp_axes=("data",))
    assert spec.tp == 4 and spec.dp == 2
    assert MeshSpec.from_row(spec.to_row()) == spec
    assert MeshSpec.from_row(None) is None


def test_mesh_spec_from_mesh():
    spec = MeshSpec.from_mesh(_FakeMesh({"pod": 2, "data": 16, "model": 16}))
    assert spec.axes == (("pod", 2), ("data", 16), ("model", 16))
    assert spec.tp == 16 and spec.dp == 32
    assert spec.dp_axes == ("pod", "data")  # filtered to present axes
    spec2 = MeshSpec.from_mesh(_FakeMesh({"data": 4, "model": 2}))
    assert spec2.dp_axes == ("data",) and spec2.dp == 4 and spec2.tp == 2
