"""paligemma-3b [vlm]: 18L, d=2048, 8H (GQA kv=1), d_ff=16384, vocab=257216.

SigLIP frontend is a STUB: input_specs provide patch embeddings
(B, 256, 1152) projected into the gemma backbone. [arXiv:2407.07726]
"""
import math

from repro.models.config import ModelConfig

VISION_EMBED_DIM = 1152


def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma_3b", family="vlm",
        num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
        head_dim=256, d_ff=16384, vocab_size=257216,
        activation="gelu", vision_tokens=256, vision_embed_dim=VISION_EMBED_DIM, emb_scale=math.sqrt(2048.0),
        max_seq_len=32768,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=256, vision_tokens=8, emb_scale=8.0,
        max_seq_len=128, attn_chunk=16,
    )
