"""Assigned architecture configs and (arch x shape) cell definitions."""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    step: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: run only for SSM / hybrid /
# local-attention-dominant archs (DESIGN.md §4); pure full-attention skips.
LONG_CONTEXT_ARCHS = {"zamba2_7b", "rwkv6_7b", "gemma3_12b"}


def applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


def all_cells() -> list[tuple[str, str]]:
    from repro.models.registry import ARCHS

    return [(a, s) for a in ARCHS for s in SHAPES if applicable(a, s)]
