"""qwen3-4b [dense]: 36L, d=2560, 32H (GQA kv=8), d_ff=9728, vocab=151936.

qk_norm, head_dim=128. [hf:Qwen/Qwen3-8B]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3_4b", family="dense",
        num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=9728, vocab_size=151936, qk_norm=True,
        rope_theta=1e6, max_seq_len=32768,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, max_seq_len=128, attn_chunk=16,
    )
