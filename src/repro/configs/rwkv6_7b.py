"""rwkv6-7b [ssm] "Finch": 32L, d=4096, attention-free, d_ff=14336, vocab=65536.

Data-dependent decay via low-rank projection. [arXiv:2404.05892]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6_7b", family="ssm",
        num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
        d_ff=14336, vocab_size=65536, rwkv_head_size=64, rwkv_decay_lora=64,
        max_seq_len=524288,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=256, rwkv_head_size=16, rwkv_decay_lora=8,
        max_seq_len=128, attn_chunk=16,
    )
