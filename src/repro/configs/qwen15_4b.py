"""qwen1.5-4b [dense]: 40L, d=2560, 20H (kv=20), d_ff=6912, vocab=151936.

QKV bias. [hf:Qwen/Qwen1.5-0.5B]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen15_4b", family="dense",
        num_layers=40, d_model=2560, num_heads=20, num_kv_heads=20,
        d_ff=6912, vocab_size=151936, qkv_bias=True, rope_theta=1e6,
        max_seq_len=32768,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=256, max_seq_len=128, attn_chunk=16,
    )
