"""arctic-480b [moe]: 35L, d=7168, 56H (GQA kv=8), vocab=32000.

Dense-MoE hybrid: every layer has a dense FFN residual branch in parallel
with a 128-expert top-2 MoE (expert d_ff=4864). [hf:Snowflake/snowflake-arctic-base]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic_480b", family="moe",
        num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
        d_ff=4864, expert_d_ff=4864, moe_dense_ff=4864,
        num_experts=128, top_k=2, vocab_size=32000,
        max_seq_len=32768,
        # 480B on one 256-chip pod: bf16 params + int8 moments (DESIGN.md §5)
        param_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=96,
        expert_d_ff=96, moe_dense_ff=96, num_experts=8, top_k=2,
        vocab_size=256, max_seq_len=128, attn_chunk=16,
    )
