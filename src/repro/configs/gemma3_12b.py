"""gemma3-12b [dense]: 48L, d=3840, 16H (GQA kv=8), d_ff=15360, vocab=262144.

5 local (1024-window) : 1 global attention pattern, qk_norm, GeGLU,
embed scale sqrt(d), 128k+ context. [hf:google/gemma-3-1b-pt]
"""
import math

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3_12b", family="dense",
        num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8,
        head_dim=256, d_ff=15360, vocab_size=262144,
        qk_norm=True, activation="gelu",
        window_pattern=(1024, 1024, 1024, 1024, 1024, 0),
        emb_scale=math.sqrt(3840.0), rope_theta=1e6,
        max_seq_len=524288, logit_softcap=0.0,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, window_pattern=(8, 0),
        emb_scale=8.0, max_seq_len=128, attn_chunk=16,
    )
