"""zamba2-7b [hybrid]: 81 Mamba2 layers + shared attention block every 6.

d=3584, ssm_state=64; shared block 32H/kv32, d_ff=14336. [arXiv:2411.15242]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2_7b", family="hybrid",
        num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
        d_ff=14336, vocab_size=32000,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, attn_every=6,
        max_seq_len=524288,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=256, ssm_state=16, ssm_head_dim=16, attn_every=2,
        max_seq_len=128, attn_chunk=16,
    )
