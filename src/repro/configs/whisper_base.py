"""whisper-base [audio]: 6L enc-dec, d=512, 8H, d_ff=2048, vocab=51865.

Conv audio frontend is a STUB: input_specs provide precomputed frame
embeddings (B, 1500, 512).  [arXiv:2212.04356]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper_base", family="encdec",
        num_layers=6, num_enc_layers=6, d_model=512,
        num_heads=8, num_kv_heads=8, d_ff=2048, vocab_size=51865,
        norm="layernorm", activation="gelu_mlp", enc_seq_len=1500,
        max_seq_len=32768,  # shape-coverage override of whisper's native 448
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, num_enc_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, enc_seq_len=32, max_seq_len=64, attn_chunk=16,
    )
