"""qwen3-moe-235b-a22b [moe]: 94L, d=4096, 64H (GQA kv=4), vocab=151936.

128 experts top-8, expert d_ff=1536, qk_norm. [hf:Qwen/Qwen3-30B-A3B]
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3_moe_235b", family="moe",
        num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
        head_dim=128, d_ff=1536, expert_d_ff=1536,
        num_experts=128, top_k=8, vocab_size=151936, qk_norm=True,
        rope_theta=1e6, max_seq_len=32768,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=96, expert_d_ff=96, num_experts=8, top_k=2,
        vocab_size=256, max_seq_len=128, attn_chunk=16,
    )
