"""minicpm-2b [dense]: 40L, d=2304, 36H (kv=36), d_ff=5760, vocab=122753.

WSD schedule (optim feature); mup-style embed scale 12 and depth-scaled
residuals (1.4/sqrt(L)); tied embeddings. [arXiv:2404.06395]
"""
import math

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm_2b", family="dense",
        num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
        d_ff=5760, vocab_size=122753, tie_embeddings=True,
        emb_scale=12.0, residual_scale=1.4 / math.sqrt(40),
        max_seq_len=32768,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=72, num_heads=4, num_kv_heads=4, d_ff=144,
        vocab_size=256, residual_scale=1.4 / math.sqrt(2),
        max_seq_len=128, attn_chunk=16,
    )
