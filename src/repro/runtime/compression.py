"""Gradient compression for cross-pod all-reduce.

The pod axis rides DCN (slow); int8 block-quantised gradients with error
feedback cut that traffic 4x.  ``compressed_psum`` is the shard_map-side op:
quantise locally -> all-reduce int32 (sums of int8 fit easily) -> dequantise,
with the quantisation residual carried to the next step (error feedback keeps
SGD/Adam convergence — tests/test_runtime.py checks the residual telescopes).

The per-block scale math is ``kernels.quantize.abs_max_scale`` — the same
abs-max formula the weight-quantized flex kernels and the CMU accuracy gate
use, so there is one quantizer convention in the repo.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.quantize import abs_max_scale

Params = Any
BLOCK = 256


def _blockify(g: jax.Array) -> tuple[jax.Array, tuple]:
    n = g.size
    blocks = -(-n // BLOCK)
    flat = jnp.pad(g.reshape(-1).astype(jnp.float32), (0, blocks * BLOCK - n))
    return flat.reshape(blocks, BLOCK), (g.shape, n)


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array, tuple]:
    b, meta = _blockify(g)
    scale = abs_max_scale(b, "int8", axis=1)
    q = jnp.clip(jnp.round(b / scale), -127, 127).astype(jnp.int8)
    return q, scale, meta


def dequantize_int8(q: jax.Array, scale: jax.Array, meta: tuple) -> jax.Array:
    shape, n = meta
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n].reshape(shape)


def compress_residual(g: jax.Array, residual: jax.Array | None):
    """Error feedback: quantise (g + residual), return (q, scale, new_residual)."""
    g32 = g.astype(jnp.float32)
    if residual is not None:
        g32 = g32 + residual
    q, scale, meta = quantize_int8(g32)
    deq = dequantize_int8(q, scale, meta)
    return q, scale, meta, g32 - deq


def compressed_psum(g: jax.Array, axis_name: str, residual: jax.Array | None = None):
    """int8-compressed psum over ``axis_name`` (use inside shard_map).

    Two-phase scheme: (1) agree on a per-block GLOBAL scale via a tiny f32
    pmax (1/256 of the payload), (2) quantise against it and psum the int8
    payload in int32 — so the sum is exact up to one shared quantisation step
    per element, and the error feedback residual carries the rest.

    Returns (mean_gradient, new_residual).
    """
    g32 = g.astype(jnp.float32)
    if residual is not None:
        g32 = g32 + residual
    b, meta = _blockify(g32)
    n = jax.lax.psum(1, axis_name)
    scale = abs_max_scale(b, "int8", axis=1)
    scale = jax.lax.pmax(scale, axis_name)  # shared scale (tiny collective)
    q = jnp.clip(jnp.round(b / scale), -127, 127).astype(jnp.int8)
    qs = jax.lax.psum(q.astype(jnp.int32), axis_name)
    deq = (qs.astype(jnp.float32) / n) * scale
    shape, cnt = meta
    sent = (q.astype(jnp.float32) * scale).reshape(-1)[:cnt].reshape(shape)
    new_res = g32 - sent
    return deq.reshape(-1)[:cnt].reshape(shape), new_res


def compression_ratio(g: jax.Array) -> float:
    q, scale, _ = quantize_int8(g)
    return (g.size * 4) / (q.size * 1 + scale.size * 4)
