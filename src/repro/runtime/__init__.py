"""Distributed runtime: fault tolerance, stragglers, gradient compression."""

from .compression import compressed_psum, compression_ratio, dequantize_int8, quantize_int8
from .fault_tolerance import ElasticController, RunnerConfig, SimulatedNodeFailure, TrainRunner
from .straggler import ShardAssignment, StragglerConfig, StragglerTracker

__all__ = [
    "ElasticController",
    "RunnerConfig",
    "ShardAssignment",
    "SimulatedNodeFailure",
    "StragglerConfig",
    "StragglerTracker",
    "TrainRunner",
    "compressed_psum",
    "compression_ratio",
    "dequantize_int8",
    "quantize_int8",
]
