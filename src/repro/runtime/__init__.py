"""Distributed runtime: fault tolerance, stragglers, gradient compression, paged KV."""

from .compression import compressed_psum, compression_ratio, dequantize_int8, quantize_int8
from .fault_injection import FaultPlan
from .fault_tolerance import ElasticController, RunnerConfig, SimulatedNodeFailure, TrainRunner
from .kv_cache import SCRATCH_BLOCK, BlockAllocator, PagedKVCache, write_prefill_blocks
from .straggler import ShardAssignment, StragglerConfig, StragglerTracker

__all__ = [
    "BlockAllocator",
    "ElasticController",
    "FaultPlan",
    "PagedKVCache",
    "SCRATCH_BLOCK",
    "RunnerConfig",
    "ShardAssignment",
    "SimulatedNodeFailure",
    "StragglerConfig",
    "StragglerTracker",
    "TrainRunner",
    "compressed_psum",
    "compression_ratio",
    "dequantize_int8",
    "quantize_int8",
    "write_prefill_blocks",
]
