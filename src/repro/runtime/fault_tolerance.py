"""Fault-tolerant training loop: checkpoint/restart + failure injection.

At 1000+ nodes the MTBF of the job is hours, so the loop treats failure as
the common case: every ``ckpt_every`` steps a checkpoint is committed
atomically; any exception (including injected ``SimulatedNodeFailure``)
rolls the runner back to the last commit and replays.  Because the data
pipeline is a pure function of (seed, step, shard), replay is bit-exact —
there is no divergence window.

Elastic scaling reuses the same mechanism: ``ElasticController.resize``
checkpoints, rebuilds the mesh/shardings at the new size, and restores —
the checkpoint layer re-shards on load (checkpoint/store.py).
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint

log = logging.getLogger("repro.runtime")


class SimulatedNodeFailure(RuntimeError):
    """Injected in tests/CI to exercise the restart path."""


@dataclasses.dataclass
class RunnerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_steps: int = 1000
    max_restarts: int = 10
    async_ckpt: bool = False
    # exception types the restart loop recovers from.  The default covers
    # only the injected test failure; production configs widen it to the
    # runtime's actual failure surface, e.g. (SimulatedNodeFailure,
    # jax.errors.JaxRuntimeError) for XLA device loss / preemption —
    # anything else (a programming error) still propagates.
    recoverable: tuple[type[BaseException], ...] = (SimulatedNodeFailure,)


class TrainRunner:
    """Drives step_fn(state, step) -> (state, metrics) with restart-on-failure.

    ``state`` is any pytree (params + optimizer + rng).  ``failure_hook`` may
    raise at chosen steps to inject faults (tests) — in production the same
    path recovers from whatever ``cfg.recoverable`` names (XLA device
    errors / preemptions).  On restart, ``metrics_log`` is truncated back
    to the last committed checkpoint so replayed steps never append
    duplicate entries — the log always reads as one consistent history.
    """

    def __init__(
        self,
        step_fn: Callable[[Any, int], tuple[Any, dict]],
        init_state: Callable[[], Any],
        cfg: RunnerConfig,
        failure_hook: Callable[[int], None] | None = None,
        shardings: Any | None = None,
    ):
        self.step_fn = step_fn
        self.init_state = init_state
        self.cfg = cfg
        self.failure_hook = failure_hook
        self.shardings = shardings
        self.restarts = 0
        self.metrics_log: list[dict] = []

    def _restore_or_init(self) -> tuple[Any, int]:
        last = latest_step(self.cfg.ckpt_dir)
        if last is None:
            return self.init_state(), 0
        like = self.init_state()
        state, extra = load_checkpoint(
            self.cfg.ckpt_dir, last, like, shardings=self.shardings
        )
        log.info("restored step %d (restart #%d)", last, self.restarts)
        return state, last

    def run(self) -> tuple[Any, int]:
        while True:
            state, step = self._restore_or_init()
            # drop metrics from steps past the restored checkpoint: they are
            # about to be replayed (bit-exactly) and would otherwise appear
            # twice in the log
            self.metrics_log = [m for m in self.metrics_log
                                if m["step"] <= step]
            try:
                while step < self.cfg.max_steps:
                    if self.failure_hook is not None:
                        self.failure_hook(step)
                    state, metrics = self.step_fn(state, step)
                    step += 1
                    metrics = dict(metrics, step=step)
                    self.metrics_log.append(metrics)
                    if step % self.cfg.ckpt_every == 0 or step == self.cfg.max_steps:
                        save_checkpoint(
                            self.cfg.ckpt_dir, step, state,
                            async_write=self.cfg.async_ckpt,
                        )
                return state, step
            except self.cfg.recoverable as e:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError("restart budget exhausted") from e
                log.warning("node failure at step %d: %s — restarting", step, e)


@dataclasses.dataclass
class ElasticController:
    """Checkpoints, rebuilds shardings for a new mesh, restores — no retrain."""

    ckpt_dir: str

    def resize(
        self,
        state: Any,
        step: int,
        new_shardings: Any,
    ) -> Any:
        save_checkpoint(self.ckpt_dir, step, state)
        like = state
        new_state, _ = load_checkpoint(
            self.ckpt_dir, step, like, shardings=new_shardings
        )
        return new_state
