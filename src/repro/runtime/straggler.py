"""Straggler detection and mitigation.

Per-host step-time heartbeats feed an online p50/p99 tracker; a host whose
EWMA exceeds ``threshold x p50`` for ``patience`` consecutive steps is flagged
and its data shards re-assigned to healthy hosts (possible because the
pipeline is stateless — data/pipeline.py).  On CPU CI this runs against
simulated clocks (tests/test_runtime.py); on a real pod the same tracker is
fed from host heartbeat timestamps.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StragglerConfig:
    threshold: float = 1.5   # x median
    patience: int = 3
    ewma: float = 0.5


class StragglerTracker:
    def __init__(self, num_hosts: int, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.num_hosts = num_hosts
        self.ewma_times = np.zeros(num_hosts)
        # explicit first-observation flag: a zero EWMA is a legitimate value
        # (a host reporting ~0 step times must not be re-seeded forever)
        self._seeded = False
        self.strikes = np.zeros(num_hosts, dtype=int)
        self.history: list[np.ndarray] = []

    def observe(self, step_times: np.ndarray) -> list[int]:
        """step_times: per-host seconds for this step. Returns flagged hosts."""
        a = self.cfg.ewma
        if not self._seeded:
            self.ewma_times = np.asarray(step_times, float).copy()
            self._seeded = True
        else:
            self.ewma_times = a * step_times + (1 - a) * self.ewma_times
        self.history.append(step_times)
        med = np.median(self.ewma_times)
        slow = self.ewma_times > self.cfg.threshold * med
        self.strikes = np.where(slow, self.strikes + 1, 0)
        return [int(h) for h in np.nonzero(self.strikes >= self.cfg.patience)[0]]

    def p99_step_time(self) -> float:
        if not self.history:
            return 0.0
        return float(np.percentile(np.concatenate(self.history), 99))


@dataclasses.dataclass
class ShardAssignment:
    """Maps data shards -> hosts; rebalances away from flagged hosts."""

    num_shards: int
    num_hosts: int

    def __post_init__(self):
        self.assignment = {s: s % self.num_hosts for s in range(self.num_shards)}

    def reassign(self, flagged: list[int]) -> dict[int, int]:
        healthy = [h for h in range(self.num_hosts) if h not in flagged]
        if not healthy:
            return self.assignment
        i = 0
        for s, h in self.assignment.items():
            if h in flagged:
                self.assignment[s] = healthy[i % len(healthy)]
                i += 1
        return self.assignment
