"""Seeded, deterministic fault injection for the serving runtime.

Datacenter serving is governed by tail behavior and availability, not peak
throughput — the scheduler has to survive allocation failures, poisoned
device math, preemptions and latency spikes without taking the whole trace
down.  This module is the *controlled* version of those conditions: a
``FaultPlan`` is a seeded schedule of injected faults that
``launch.scheduler.ServeScheduler`` consults at well-defined points, so a
chaos run is exactly reproducible (same seed + same trace = same faults)
and the degradation it causes can be asserted, not eyeballed.

Fault classes (each with a per-consult probability, plus an explicit
schedule form for deterministic unit tests):

  * **alloc** — a KV block allocation fails even though the pool could
    satisfy it (transient HBM pressure).  Injected *inside*
    ``BlockAllocator.alloc`` via its ``fault_hook``, so injected and
    organic pool exhaustion flow through the same scheduler code path
    (FIFO wait / preempt, never crash).
  * **nan** — one active slot's decode logits are overwritten with NaN
    for one step (a poisoned reduction / device fault).  The scheduler's
    non-finite-logit guard must fail only that request; its neighbours'
    streams stay bitwise unchanged.
  * **preempt** — one active slot is preempted: its blocks are freed and
    the request re-queued carrying its generated-so-far tokens.  On
    re-admission the scheduler replays ``prompt + generated`` through
    prefill; greedy decode is a pure function of the prefix, so the
    resumed stream must be bitwise identical to the uninterrupted run.
  * **latency** — a host-side latency spike (a short sleep) before the
    next decode step; changes only the event-stream timings, never bits.

Consult order inside one scheduler step is fixed (alloc hooks during
admission, then poison, then latency, then preempt), so a ``FaultPlan``'s
lazily-advanced RNG is deterministic per run.  ``reset()`` rewinds the
plan; the scheduler calls it at the top of every ``run`` so one plan
object can drive repeated replays (benchmark warm-up + measured pass)
identically.
"""

from __future__ import annotations

import dataclasses

import numpy as np

FAULT_KINDS = ("alloc", "nan", "preempt", "latency")


@dataclasses.dataclass
class FaultPlan:
    """A seeded fault schedule for one (replayable) serving run.

    Probabilities are per consult: ``alloc_fail`` per allocation attempt,
    ``nan`` / ``preempt`` / ``latency`` per decode step.  The ``*_at``
    forms inject deterministically — ``alloc_fail_at`` holds allocation
    call indices, ``poison_at`` / ``preempt_at`` hold ``(decode_step,
    slot_row)`` pairs — and are checked before the probabilistic draws,
    so unit tests can place a single fault exactly.
    """

    seed: int = 0
    alloc_fail: float = 0.0
    nan: float = 0.0
    preempt: float = 0.0
    latency: float = 0.0
    latency_s: float = 5e-4
    alloc_fail_at: tuple[int, ...] = ()
    poison_at: tuple[tuple[int, int], ...] = ()
    preempt_at: tuple[tuple[int, int], ...] = ()

    def __post_init__(self):
        self.reset()

    def reset(self) -> None:
        """Rewind the schedule: same seed -> same faults on the next run."""
        self._rng = np.random.default_rng(self.seed)
        self._alloc_calls = 0
        self.injected: dict[str, int] = {k: 0 for k in FAULT_KINDS}

    @property
    def total_injected(self) -> int:
        return sum(self.injected.values())

    # -- consult points (order inside a step is fixed; see module doc) ------

    def fail_alloc(self, n_blocks: int) -> bool:
        """``BlockAllocator.fault_hook``: True fails this allocation."""
        idx = self._alloc_calls
        self._alloc_calls += 1
        hit = idx in self.alloc_fail_at or (
            self.alloc_fail > 0 and self._rng.random() < self.alloc_fail)
        if hit:
            self.injected["alloc"] += 1
        return hit

    def pick_poison(self, step: int, n_slots: int) -> int | None:
        """Slot row whose logits get NaN-poisoned this decode step."""
        return self._pick("nan", self.poison_at, self.nan, step, n_slots)

    def pick_preempt(self, step: int, n_slots: int) -> int | None:
        """Slot row to preempt after this decode step."""
        return self._pick("preempt", self.preempt_at, self.preempt, step,
                          n_slots)

    def spike(self) -> float:
        """Seconds of injected host latency before the next decode step."""
        if self.latency > 0 and self._rng.random() < self.latency:
            self.injected["latency"] += 1
            return self.latency_s
        return 0.0

    def _pick(self, kind: str, explicit, rate: float, step: int,
              n_slots: int) -> int | None:
        if n_slots <= 0:
            return None
        for s, row in explicit:
            if s == step and row < n_slots:
                self.injected[kind] += 1
                return row
        if rate > 0 and self._rng.random() < rate:
            self.injected[kind] += 1
            return int(self._rng.integers(n_slots))
        return None

    # -- CLI spec -----------------------------------------------------------

    @classmethod
    def from_spec(cls, spec: str, seed: int = 0) -> "FaultPlan":
        """Parse ``'alloc=0.1,nan=0.02,preempt=0.05,latency=0.01'`` (any
        subset; optional ``seed=N`` overrides the default seed)."""
        kw: dict[str, float] = {}
        names = {"alloc": "alloc_fail", "nan": "nan", "preempt": "preempt",
                 "latency": "latency", "latency_s": "latency_s"}
        for part in filter(None, (p.strip() for p in spec.split(","))):
            key, _, val = part.partition("=")
            key = key.strip()
            if key == "seed":
                seed = int(val)
            elif key in names:
                kw[names[key]] = float(val)
            else:
                raise ValueError(
                    f"unknown fault class {key!r} in spec {spec!r} "
                    f"(known: {', '.join(names)}, seed)")
        return cls(seed=seed, **kw)

    def describe(self) -> str:
        on = [f"{k}={v:g}" for k, v in (
            ("alloc", self.alloc_fail), ("nan", self.nan),
            ("preempt", self.preempt), ("latency", self.latency)) if v > 0]
        on += [f"{k}@{len(v)}" for k, v in (
            ("alloc", self.alloc_fail_at), ("nan", self.poison_at),
            ("preempt", self.preempt_at)) if v]
        return f"FaultPlan(seed={self.seed}, {', '.join(on) or 'empty'})"
