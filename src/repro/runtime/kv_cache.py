"""Block-allocated paged KV cache for the continuous-batching serve runtime.

The dense decode cache (``models.layers.init_kv_cache``) reserves
``batch x max_seq`` rows up front — a request generating 8 tokens from a
12-token prompt holds the same HBM as one filling the whole window, and a
fixed batch can never be backfilled mid-flight.  This module replaces it
with the paged layout production servers use (vLLM's PagedAttention):

  * the cache is a pool of fixed-size **blocks** —
    ``(L, num_blocks, block_size, Hkv, hd)`` per K and V — allocated to
    requests in ``block_size``-token units by a host-side free list
    (``BlockAllocator``);
  * each request owns a **block table** (its ordered block ids); logical
    position ``p`` of a request lives at ``(table[p // bs], p % bs)``;
  * block 0 is a reserved **scratch block**: pad rows of a bucketed batch
    point their whole table at it, so their writes never touch a live
    request's cache and their reads are causally masked anyway.

The device side stays pure array math: ``write_prefill_blocks`` scatters a
prefill's per-layer K/V into the pool through a block table, and
``models.layers.attention_decode_paged`` gathers a slot's table back into a
dense per-slot view for the masked decode attention.  Admission, eviction
and the free list live on the host (``launch.scheduler``) — allocator state
never rides a traced value, so the decode step keeps its fixed shape.
"""

from __future__ import annotations

import jax.numpy as jnp

# Reserved scratch block: pad rows of a bucketed batch write (and point
# their table entries) here.  Never allocated, never read unmasked.
SCRATCH_BLOCK = 0


class BlockAllocator:
    """Host-side free-list allocator over the pool's block ids.

    ``alloc`` returns None (instead of raising) when the pool can't satisfy
    the request — the scheduler's signal to keep the request queued until
    evictions return blocks.  Double-frees and frees of never-allocated ids
    raise: a block table pointing at a re-issued block is silent cache
    corruption, the one failure mode a paged cache must never hide.

    ``fault_hook`` is the fault-injection seam (``runtime.fault_injection``):
    when set, it is consulted on every ``alloc`` and a True return fails the
    allocation even though the pool could satisfy it — so injected transient
    allocation failures flow through the exact code path organic pool
    exhaustion takes (the caller queues or preempts, never crashes).
    """

    def __init__(self, num_blocks: int, reserved: int = 1):
        self.fault_hook = None  # Callable[[int], bool] | None
        if num_blocks <= reserved:
            raise ValueError(
                f"pool of {num_blocks} blocks leaves nothing to allocate "
                f"after {reserved} reserved scratch block(s)"
            )
        self.num_blocks = num_blocks
        self.reserved = reserved
        # descending so pop() hands out low ids first (determinism only —
        # block ids never affect numerics, gathers go through the table)
        self._free = list(range(num_blocks - 1, reserved - 1, -1))
        self._live: set[int] = set()

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def live_blocks(self) -> int:
        return len(self._live)

    def alloc(self, n: int) -> list[int] | None:
        """Allocate ``n`` blocks, or None when fewer than ``n`` are free
        (or an injected fault fails the attempt — see ``fault_hook``)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if self.fault_hook is not None and self.fault_hook(n):
            return None
        if n > len(self._free):
            return None
        blocks = [self._free.pop() for _ in range(n)]
        self._live.update(blocks)
        return blocks

    def free(self, blocks: list[int]) -> None:
        for b in blocks:
            if b not in self._live:
                raise ValueError(
                    f"free of block {b} which is not live (double-free or "
                    "never allocated)"
                )
            self._live.discard(b)
            self._free.append(b)


class PagedKVCache:
    """The device pools + the host allocator, sized for one serving run.

    ``k`` / ``v`` are ``(L, num_blocks, block_size, Hkv, hd)`` bf16 — the
    serving dtype of the dense cache, block-paged.  The pools are plain
    arrays the caller threads through the jitted prefill/decode steps
    (donated, so updates are in-place); this object only tracks allocator
    state between steps.
    """

    def __init__(self, cfg, num_blocks: int, block_size: int,
                 layers: int | None = None):
        L = layers if layers is not None else cfg.num_layers
        shape = (L, num_blocks, block_size, cfg.num_kv_heads, cfg.head_dim)
        self.k = jnp.zeros(shape, jnp.bfloat16)
        self.v = jnp.zeros(shape, jnp.bfloat16)
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.allocator = BlockAllocator(num_blocks, reserved=1)

    def blocks_for(self, tokens: int) -> int:
        """Blocks needed to cache ``tokens`` positions."""
        return -(-tokens // self.block_size)

    def alloc(self, tokens: int) -> list[int] | None:
        """Allocate a request's blocks for ``tokens`` cache positions, or
        None when the pool is exhausted (caller queues the request)."""
        return self.allocator.alloc(self.blocks_for(tokens))

    def free(self, blocks: list[int]) -> None:
        self.allocator.free(blocks)


def write_prefill_blocks(pool_k, pool_v, k_all, v_all, table):
    """Scatter a prefill's per-layer K/V into the block pools.

    ``k_all`` / ``v_all``: (L, B, S, Hkv, hd) with S a multiple of the
    block size; ``table``: (B, S // bs) int32 block ids per row.  Table
    entries beyond a request's allocation point at the scratch block —
    their (pad-position) K/V lands there and is never read unmasked.
    Returns the updated pools (pure; callers jit with donation).
    """
    L, B, S = k_all.shape[:3]
    bs = pool_k.shape[2]
    nb = S // bs
    k_r = k_all.reshape(L, B, nb, bs, *k_all.shape[3:]).astype(pool_k.dtype)
    v_r = v_all.reshape(L, B, nb, bs, *v_all.shape[3:]).astype(pool_v.dtype)
    return pool_k.at[:, table].set(k_r), pool_v.at[:, table].set(v_r)
