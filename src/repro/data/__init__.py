"""Data pipeline."""

from .pipeline import DataConfig, TokenStream, device_batch, write_corpus

__all__ = ["DataConfig", "TokenStream", "device_batch", "write_corpus"]
