"""Data pipeline: deterministic synthetic LM stream + memmap token corpus.

Production posture: every batch is a pure function of (seed, step, shard), so
any host can reproduce any shard of any step — this is what makes
checkpoint/restart and elastic re-sharding exact (runtime/fault_tolerance.py):
a restarted or re-sharded job replays the same token stream with no
coordination state beyond the step counter.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Iterator

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    corpus_path: str | None = None  # memmap .bin of uint16/uint32 tokens
    num_shards: int = 1             # data-parallel shards
    shard_id: int = 0


class TokenStream:
    """Stateless batch generator: batch(step) -> {tokens, labels}."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._corpus = None
        if cfg.corpus_path and os.path.exists(cfg.corpus_path):
            dt = np.uint16 if cfg.vocab_size <= 65536 else np.uint32
            self._corpus = np.memmap(cfg.corpus_path, dtype=dt, mode="r")

    @property
    def shard_batch(self) -> int:
        assert self.cfg.global_batch % self.cfg.num_shards == 0
        return self.cfg.global_batch // self.cfg.num_shards

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        if self._corpus is not None:
            return self._corpus_batch(step)
        # synthetic: Zipf-ish marginals + a learnable bigram structure so a
        # ~100M model's loss actually decreases (examples/train_lm.py)
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.shard_id])
        )
        B, S, V = self.shard_batch, cfg.seq_len, cfg.vocab_size
        base = rng.zipf(1.5, size=(B, S + 1)).astype(np.int64)
        tokens = np.minimum(base, V - 1).astype(np.int32)
        # inject deterministic bigram structure: x[t+1] = f(x[t]) half the time
        flip = rng.random((B, S)) < 0.5
        nxt = (tokens[:, :-1] * 31 + 17) % V
        tokens[:, 1:] = np.where(flip, nxt, tokens[:, 1:])
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:].copy()}

    def _corpus_batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        B, S = self.shard_batch, cfg.seq_len
        n = len(self._corpus) - (S + 1)
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step, cfg.shard_id]))
        starts = rng.integers(0, n, size=B)
        toks = np.stack([self._corpus[s : s + S + 1] for s in starts]).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def device_batch(batch: dict[str, np.ndarray], sharding=None) -> dict[str, jax.Array]:
    """Host batch -> device arrays (optionally with a NamedSharding)."""
    if sharding is None:
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}
    return {k: jax.device_put(v, sharding) for k, v in batch.items()}


def write_corpus(path: str, tokens: np.ndarray) -> None:
    dt = np.uint16 if tokens.max() < 65536 else np.uint32
    tokens.astype(dt).tofile(path)
