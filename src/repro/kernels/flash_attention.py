"""Pallas TPU flash attention: a flex kernel family with plannable schedules.

PR 3 landed a single hard-coded online-softmax kernel (q-stationary, fixed
128x128 blocks).  This module generalizes it into the same shape the GEMM
side already has — a *family* of kernels whose schedule knobs the CMU picks
per shape and persists in the plan cache:

* ``(bq, bk)`` block sizes — tunable, not pinned to 128.
* Sweep order (``ATTN_SWEEPS``):
    - ``"q"``  (q-stationary):  grid ``(BH, nq, nkv)``.  Each q tile stays
      VMEM-resident with its f32 accumulator strip while K/V stream past.
      HBM reads K/V once *per q tile*.
    - ``"kv"`` (kv-stationary): grid ``(BH, nkv, nq)``.  Each K/V tile stays
      VMEM-resident while every q tile streams past; the accumulator /
      running-max / running-sum state for *all* rows lives in a VMEM scratch
      slab, and the output flushes once at the last kv step.  HBM reads K/V
      exactly once — the right trade for long-context prefill with GQA,
      where one resident KV head amortizes over ``group`` q heads' rows.
* A decode-shaped skinny-q variant (``paged_attention``) that reads K/V
  *in place* from the paged block pools via a scalar-prefetched block
  table — replacing the pure-jnp ``pool[table]`` gather that materialized
  a dense per-step K/V copy.
* A fused mask/softmax-scale epilogue (``_mask_scale``): scale, causal mask
  and kv-length (ragged pad) mask are applied to the score tile in VMEM,
  between the QK^T MXU op and the online-softmax update — no masked score
  tile ever round-trips to HBM.

Bitwise contract: for a fixed ``(bq, bk)`` the two sweep orders execute the
*identical* per-(i, j) update sequence for every q tile (the kv index j
ascends in both; only the interleaving across q tiles differs, and tiles
are independent), so ``sweep="q"`` and ``sweep="kv"`` agree bit-for-bit.
The property sweep in ``tests/test_flex_attention.py`` pins this.

Masking contract: prefill kernels mask additively (``-1e30``), which is
exact-zero after the softmax because every row always sees at least one
live key in its *first* kv block.  The decode kernel cannot assume that —
a sliding window can fully mask a leading block — so it zeroes masked
probabilities *multiplicatively* (see ``_paged_decode_kernel``).

Validated on CPU with interpret=True against ``ref.attention_ref``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flex_matmul import CompilerParams, _VMEM

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

#: Prefill sweep orders the CMU chooses between.
ATTN_SWEEPS = ("q", "kv")

#: Decode-attention kinds the CMU chooses between per batch bucket.
ATTN_DECODE_KINDS = ("paged", "gather")

_NEG_INF = -1e30


def _round8(d: int) -> int:
    """Round up to the fp32 sublane quantum (and at least one sublane)."""
    return max(-(-d // 8) * 8, 8)


def _mask_scale(s, i, j, bq, bk, *, scale, causal, seq, kv_len):
    """The fused mask/softmax-scale epilogue, applied to a score tile in VMEM.

    ``seq`` is the per-group logical sequence length when GQA groups are
    folded into the row axis (row r is query position ``r % seq``); None
    means rows are positions directly.  ``kv_len`` masks ragged kv padding
    (keys at ``kpos >= kv_len`` are pad).
    """
    s = s * scale
    if causal or kv_len is not None:
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        live = jnp.full((bq, bk), True)
        if causal:
            if seq is not None:
                qpos = jax.lax.rem(qpos, seq)
            live = live & (kpos <= qpos)
        if kv_len is not None:
            live = live & (kpos < kv_len)
        s = jnp.where(live, s, _NEG_INF)
    return s


def _online_update(s, v, m_prev, l_prev, acc_prev):
    """One flash online-softmax step.  Shared verbatim by both sweep orders
    so their per-tile arithmetic is literally the same op sequence (the
    bitwise q-vs-kv agreement contract)."""
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc_prev * corr + jnp.dot(p, v, preferred_element_type=jnp.float32)
    return m_new, l_new, acc_new


def _q_stationary_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                         *, scale, causal, bq, bk, seq, kv_len):
    """Grid (BH, nq, nkv): q tile resident, K/V stream (kv innermost)."""
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)          # (bq, hd)
    k = k_ref[0].astype(jnp.float32)          # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    s = _mask_scale(s, i, j, bq, bk, scale=scale, causal=causal,
                    seq=seq, kv_len=kv_len)
    m_new, l_new, acc_new = _online_update(
        s, v, m_ref[...], l_ref[...], acc_ref[...])
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype)


def _kv_stationary_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                          *, scale, causal, bq, bk, seq, kv_len, nkv):
    """Grid (BH, nkv, nq): K/V tile resident, q streams (q innermost).

    The softmax state for *all* rows lives in one VMEM slab, strip-sliced
    per q tile with ``pl.ds``; the output block is the whole row slab,
    indexed only by the batch axis, so it flushes to HBM exactly once (at
    the final kv step) — no partially-normalized tile ever leaves VMEM.
    """
    j, i = pl.program_id(1), pl.program_id(2)
    rows = pl.ds(i * bq, bq)

    @pl.when(j == 0)
    def _init():
        acc_ref[rows, :] = jnp.zeros((bq, acc_ref.shape[-1]), jnp.float32)
        m_ref[rows, :] = jnp.full((bq, 1), _NEG_INF, jnp.float32)
        l_ref[rows, :] = jnp.zeros((bq, 1), jnp.float32)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    s = _mask_scale(s, i, j, bq, bk, scale=scale, causal=causal,
                    seq=seq, kv_len=kv_len)
    m_new, l_new, acc_new = _online_update(
        s, v, m_ref[rows, :], l_ref[rows, :], acc_ref[rows, :])
    m_ref[rows, :] = m_new
    l_ref[rows, :] = l_new
    acc_ref[rows, :] = acc_new

    @pl.when(j == nkv - 1)
    def _flush():
        o_ref[0, rows, :] = (acc_new / jnp.maximum(l_new, 1e-30)).astype(
            o_ref.dtype)


def flex_attention(q, k, v, *, sweep: str = "q", causal: bool = True,
                   scale: float | None = None,
                   block_q: int = DEFAULT_BLOCK_Q,
                   block_k: int = DEFAULT_BLOCK_K,
                   seq: int | None = None, kv_len: int | None = None,
                   interpret: bool = False):
    """Schedule-parameterized flash attention on ``(BH, rows, hd)`` operands.

    The low-level family entry: ``sweep`` and ``(block_q, block_k)`` are
    the CMU's schedule knobs.  Row and kv lengths must divide their blocks
    (``mha_flash`` handles folding/padding); ``seq``/``kv_len`` feed the
    fused mask epilogue (see ``_mask_scale``).
    """
    if sweep not in ATTN_SWEEPS:
        raise ValueError(f"sweep must be one of {ATTN_SWEEPS}, got {sweep!r}")
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    if Sq % bq or Skv % bk:
        raise ValueError(f"seq lens ({Sq},{Skv}) must divide blocks ({bq},{bk})")
    nq, nkv = Sq // bq, Skv // bk
    knobs = dict(scale=scale, causal=causal, bq=bq, bk=bk, seq=seq,
                 kv_len=kv_len)
    if sweep == "q":
        grid = (BH, nq, nkv)
        kernel = functools.partial(_q_stationary_kernel, **knobs)
        in_specs = [
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ]
        out_spec = pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0))
        scratch = [_VMEM((bq, hd), jnp.float32),
                   _VMEM((bq, 1), jnp.float32),
                   _VMEM((bq, 1), jnp.float32)]
        semantics = ("parallel", "parallel", "arbitrary")
    else:
        grid = (BH, nkv, nq)
        kernel = functools.partial(_kv_stationary_kernel, **knobs, nkv=nkv)
        in_specs = [
            pl.BlockSpec((1, bq, hd), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, j, i: (b, j, 0)),
        ]
        # One whole-rows output block per batch index: never revisited, so
        # it flushes once (at j == nkv-1) instead of per (i, j) visit.
        out_spec = pl.BlockSpec((1, Sq, hd), lambda b, j, i: (b, 0, 0))
        scratch = [_VMEM((Sq, hd), jnp.float32),
                   _VMEM((Sq, 1), jnp.float32),
                   _VMEM((Sq, 1), jnp.float32)]
        semantics = ("parallel", "arbitrary", "arbitrary")
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=scratch,
        compiler_params=CompilerParams(dimension_semantics=semantics),
        interpret=interpret,
    )(q, k, v)


def flash_attention(
    q: jax.Array,   # (BH, Sq, hd)
    k: jax.Array,   # (BH, Skv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """Back-compat entry: the q-stationary member of the family."""
    return flex_attention(q, k, v, sweep="q", causal=causal, scale=scale,
                          block_q=block_q, block_k=block_k,
                          interpret=interpret)


def mha_flash(
    q: jax.Array,   # (B, S, H, hd)
    k: jax.Array,   # (B, Skv, Hkv, hd) — GQA folded, never repeated
    v: jax.Array,
    *,
    causal: bool = True,
    interpret: bool = False,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    sweep: str = "q",
) -> jax.Array:
    """Multi-head wrapper over ``flex_attention``.

    GQA contract: no repeated K/V is ever materialized.  The group axis is
    folded into the q rows of each (batch, kv-head) kernel instance —
    ``rows = group * S``, row ``r`` is query position ``r % S`` of group
    ``r // S`` — so one resident K/V tile serves every query head sharing
    it.  Ragged lengths are handled here: rows pad up to a ``bq`` multiple
    (garbage rows sliced off after), kv pads up to a ``bk`` multiple
    (masked exactly via ``kv_len``).  Both sweeps share this wrapper, so
    the padded geometry — and therefore the bits — match across sweeps.
    """
    B, S, H, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qf = (q.reshape(B, S, Hkv, g, hd).transpose(0, 2, 3, 1, 4)
           .reshape(B * Hkv, g * S, hd))
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, hd)
    rows = g * S
    bq = min(block_q, _round8(rows))
    bk = min(block_k, _round8(Skv))
    rows_p = -(-rows // bq) * bq
    kv_p = -(-Skv // bk) * bk
    if rows_p != rows:
        qf = jnp.pad(qf, ((0, 0), (0, rows_p - rows), (0, 0)))
    kv_len = None
    if kv_p != Skv:
        kf = jnp.pad(kf, ((0, 0), (0, kv_p - Skv), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, kv_p - Skv), (0, 0)))
        kv_len = Skv
    o = flex_attention(qf, kf, vf, sweep=sweep, causal=causal,
                       block_q=bq, block_k=bk,
                       seq=S if g > 1 else None, kv_len=kv_len,
                       interpret=interpret)
    o = o[:, :rows]
    return (o.reshape(B, Hkv, g, S, hd).transpose(0, 3, 1, 2, 4)
             .reshape(B, S, H, hd))


def _paged_decode_kernel(table_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, scale, window, bs, group):
    """Grid (B, nb): one decode slot's query heads resident; K/V blocks
    stream straight out of the paged pools (the scalar-prefetched block
    table picks the pool row per grid step — no dense gather copy).

    Masked probabilities are zeroed *multiplicatively*: with a sliding
    window the leading blocks of a deep sequence can be fully masked,
    which leaves the running max at the ``-1e30`` sentinel — the additive
    mask alone would then contribute ``exp(-1e30 - (-1e30)) = 1`` per
    masked key, poisoning the running sum.  ``where(live, exp(...), 0)``
    is exact zero regardless of the sentinel, and bit-identical for live
    keys.
    """
    b, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)      # (H, hd)
    k = k_ref[0].astype(jnp.float32)      # (bs, Hkv, hd)
    v = v_ref[0].astype(jnp.float32)
    hkv = k.shape[1]
    qg = q.reshape(hkv, group, q.shape[-1])
    s = jnp.einsum("hgd,khd->hgk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)[0]
    pos = pos_ref[b]
    live = kpos <= pos
    if window:
        live = live & (pos - kpos < window)
    live = live[None, None, :]
    s = jnp.where(live, s, _NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(live, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.einsum(
        "hgk,khd->hgd", p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _flush():
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = o.reshape(o_ref.shape[1], o_ref.shape[2]).astype(
            o_ref.dtype)


def paged_attention(q, pool_k, pool_v, table, positions, *,
                    scale: float | None = None, window: int = 0,
                    interpret: bool = False):
    """Decode-shaped skinny-q attention reading K/V blocks in place.

    ``q``: (B, H, hd) — one new token per slot.  ``pool_k/v``:
    (num_blocks, bs, Hkv, hd) paged pools.  ``table``: (B, nb) int32 block
    table; ``positions``: (B,) int32 current position per slot.  Each slot
    computes independently, so pad slots (all-scratch tables, position 0)
    cannot perturb live rows — the scheduler's bucket-padding contract.
    Returns (B, H, hd) in ``q.dtype``.
    """
    B, H, hd = q.shape
    bs, Hkv = pool_k.shape[1], pool_k.shape[2]
    nb = table.shape[1]
    group = H // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               window=window, bs=bs, group=group)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nb),
        in_specs=[
            pl.BlockSpec((1, H, hd), lambda b, j, tbl, ps: (b, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, hd),
                         lambda b, j, tbl, ps: (tbl[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, hd),
                         lambda b, j, tbl, ps: (tbl[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, j, tbl, ps: (b, 0, 0)),
        scratch_shapes=[
            _VMEM((Hkv, group, hd), jnp.float32),
            _VMEM((Hkv, group, 1), jnp.float32),
            _VMEM((Hkv, group, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(table, positions, q, pool_k, pool_v)


def paged_attention_reference(q, pool_k, pool_v, table, positions, *,
                              scale: float | None = None, window: int = 0):
    """The pure-jnp gather baseline: densify K/V through the block table,
    single-pass global-max softmax (``_decode_core`` math).  The "gather"
    decode kind the CMU times against the paged kernel, and the oracle the
    property sweep checks it against."""
    B, H, hd = q.shape
    Hkv = pool_k.shape[2]
    group = H // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    k = pool_k[table].reshape(B, -1, Hkv, hd).astype(jnp.float32)
    v = pool_v[table].reshape(B, -1, Hkv, hd).astype(jnp.float32)
    qg = q.reshape(B, Hkv, group, hd).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k) * scale
    kpos = jnp.arange(k.shape[1], dtype=jnp.int32)
    live = kpos[None, :] <= positions[:, None]
    if window:
        live = live & (positions[:, None] - kpos[None, :] < window)
    s = jnp.where(live[:, None, None, :], s, _NEG_INF)
    mx = jnp.max(s, axis=-1, keepdims=True)
    pr = jnp.exp(s - mx)
    num = jnp.einsum("bhgk,bkhd->bhgd", pr, v)
    den = jnp.sum(pr, axis=-1, keepdims=True)
    o = num / jnp.maximum(den, 1e-30)
    return o.reshape(B, H, hd).astype(q.dtype)
