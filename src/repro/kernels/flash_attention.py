"""Pallas TPU flash-attention kernel — output-stationary attention.

In the paper's vocabulary this is the OS dataflow applied to the attention
GEMM pair: the (bq, hd) output tile plus its running max/sum statistics stay
resident in VMEM scratch while (bk, hd) K/V tiles stream from HBM; score
tiles (bq, bk) never touch HBM.  The pure-jnp equivalent lives in
``models.layers._attention_core``; this kernel is the TPU-target hot-spot
implementation (the bounded KV grid also skips fully-masked causal tiles,
which the differentiable jnp path cannot).

Validated on CPU with interpret=True against ``ref.attention_ref``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flex_matmul import CompilerParams, _VMEM

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, bq: int, bk: int):
    """Grid (BH, nq, nkv) with the KV axis innermost (sequential)."""
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)          # (bq, hd)
    k = k_ref[0].astype(jnp.float32)          # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, -1e30)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,   # (BH, Sq, hd)
    k: jax.Array,   # (BH, Skv, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    BH, Sq, hd = q.shape
    Skv = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    if Sq % bq or Skv % bk:
        raise ValueError(f"seq lens ({Sq},{Skv}) must divide blocks ({bq},{bk})")
    grid = (BH, Sq // bq, Skv // bk)
    kern = functools.partial(_flash_kernel, scale=scale, causal=causal, bq=bq, bk=bk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            _VMEM((bq, hd), jnp.float32),
            _VMEM((bq, 1), jnp.float32),
            _VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)


def mha_flash(
    q: jax.Array,   # (B, S, H, hd)
    k: jax.Array,   # (B, Skv, Hkv, hd) — GQA broadcast internally
    v: jax.Array,
    *,
    causal: bool = True,
    interpret: bool = False,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """Multi-head wrapper: folds (B, H) into the kernel's batch-head grid."""
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, v.shape[1], hd)
    o = flash_attention(qf, kf, vf, causal=causal, interpret=interpret,
                        block_q=block_q, block_k=block_k)
    return o.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
