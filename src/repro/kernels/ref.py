"""Pure-jnp oracles for the Pallas kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """f32-accumulating matmul oracle (matches all three dataflow kernels)."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def blocked_matmul_ref(
    a: jax.Array, b: jax.Array, bm: int, bk: int, bn: int
) -> jax.Array:
    """Block-by-block oracle: proves blocking itself doesn't change the math."""
    M, K = a.shape
    _, N = b.shape
    out = jnp.zeros((M, N), jnp.float32)
    for i in range(0, M, bm):
        for j in range(0, N, bn):
            acc = jnp.zeros((min(bm, M - i), min(bn, N - j)), jnp.float32)
            for k in range(0, K, bk):
                acc += jnp.dot(
                    a[i : i + bm, k : k + bk],
                    b[k : k + bk, j : j + bn],
                    preferred_element_type=jnp.float32,
                )
            out = out.at[i : i + bm, j : j + bn].set(acc)
    return out


def linear_ref(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    activation: str | None = None,
    residual: jax.Array | None = None,
    out_dtype=None,
) -> jax.Array:
    """Unfused oracle for ``ops.flex_linear``: matmul, bias, activation and
    residual as separate f32 ops (what XLA runs when fusion is off)."""
    from repro.kernels.flex_matmul import ACTIVATIONS

    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    if activation is not None:
        y = ACTIVATIONS[activation](y)
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    return y.astype(out_dtype or jnp.promote_types(x.dtype, w.dtype))


def attention_ref(q, k, v, causal: bool = True, scale: float | None = None):
    """Plain softmax attention oracle. q (B,S,H,hd); k/v (B,Skv,Hkv,hd) GQA."""
    import math

    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, S, Hkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32)) * scale
    if causal:
        i = jnp.arange(S)[:, None]
        j = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(j <= i, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)
