"""Mesh-native flex kernels: shard_map-composed distributed GEMM schedules.

This module resolves the kernel-vs-GSPMD composition question (ROADMAP,
carried since PR 2) in favour of **explicit shard_map composition**: the
collective schedule around each layer's GEMM is chosen per layer by the
mesh-level CMU (``core.dist_dataflow``), not left to GSPMD's solver, and
the local per-shard GEMM inside the shard_map is the same fused Pallas
flex kernel the single-device path runs — with its own chip-level
(dataflow, block, strip, trans) plan tuned for the *post-collective*
shard shapes.

The three mesh dataflows are the paper's three stationarities one level up
the hierarchy (chip <-> PE, ICI <-> systolic wiring).  For a global
``C[M,N] = A[M,K] @ B[K,N]`` with tokens sharded over ``(*dp_axes, axis)``
and the weight K-sharded over ``axis`` (extent T):

  mesh-WS   the weight shards never move.  A is all-gathered over ``axis``
            (rebuilding the DP group's token block), each chip contracts
            its own K-shard — a bare local flex kernel producing an (M/dp,
            N) f32 partial — and a psum-scatter over ``axis`` both reduces
            the partials and re-shards the tokens.  The epilogue applies
            *after* the reduction (bias must be added once, the activation
            is nonlinear), as plain f32 ops on the scattered shard.
  mesh-IS   the activations never move.  The weight shard is all-gathered
            (ZeRO-3 style) and the local kernel runs the **whole** layer —
            the only mesh dataflow whose fused epilogue stays in-kernel.
  mesh-OS   nothing is gathered.  Each chip's output shard stays resident
            while the weight shard rotates around the ring
            (collective-permute), one local kernel launch per rotation
            step, f32 partials accumulating locally; A's matching k-slices
            are already local because the token shard carries full K.
            Epilogue after the last step, like WS.

All three share one I/O contract: x, residual and the output are sharded
``P((*dp_axes, axis), None)`` (tokens over the whole grid), the weight
``P(axis, None)`` (K over the tensor axis, replicated over DP — the ZeRO-3
unshard from the stored ``fsdp`` sharding is delegated to GSPMD at the
shard_map boundary), bias replicated.  Data-parallel axes never appear in
a collective: each DP group runs the schedule independently.

Everything is differentiable end-to-end: the collectives' transposes
(all-gather <-> psum-scatter, collective-permute <-> reverse permute) are
jax built-ins, and the local GEMMs carry the flex kernels' custom VJPs, so
under ``jax.grad`` the backward GEMMs run as flex kernels under the mesh
sub-plan's ``local_dx`` / ``local_dw`` geometries while the backward
collectives are exactly the forward schedule's transposes (mesh-WS
backward all-gathers the output cotangent and psum-scatters dX — the WS
schedule run in reverse).

Partial sums cross the wire in f32 (the ICI analogue of the kernels'
f32-accumulate policy); only the final epilogue casts to ``out_dtype``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.cmu import MeshPlan, mesh_local_gemm
from repro.core.dataflow import Dataflow, GemmShape, best_kernel_dataflow
from repro.core.dist_dataflow import best_mesh_dataflow
from repro.launch.mesh import dp_size, shard_map

from . import flex_matmul as fk
from . import ops


def _local_specs(plan: MeshPlan | None, lshape: GemmShape):
    """Resolve the local kernel's (dataflow, block, strip) + backward
    BwdSpecs: from the mesh sub-plan when given, else the trace-time
    roofline argmin (backward then also falls to the trace-time argmin
    inside ``ops``)."""
    if plan is not None and plan.local is not None:
        lp = plan.local
        df, blk, strip = lp.dataflow, lp.block or fk.DEFAULT_BLOCK, lp.strip
    else:
        df, _ = best_kernel_dataflow(lshape)
        blk, strip = fk.DEFAULT_BLOCK, 1

    def bwd(sub):
        if sub is None:
            return None
        return (sub.dataflow, sub.block, sub.trans, sub.strip)

    return df, blk, strip, bwd(plan.local_dx if plan else None), \
        bwd(plan.local_dw if plan else None)


def _post_epilogue(c, b, res, activation: str | None, out_dtype):
    """bias -> activation -> residual -> cast on an f32 reduced shard —
    the same op order as the kernels' in-flush ``_epilogue``, applied
    post-reduction for the mesh dataflows whose partials must be summed
    before the (nonlinear, add-once) epilogue can run."""
    z = c if b is None else c + b.astype(jnp.float32)
    y = fk.ACTIVATIONS[activation](z) if activation is not None else z
    if res is not None:
        y = y + res.astype(jnp.float32)
    return y.astype(out_dtype)


def flex_linear_sharded(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    mesh,
    axis: str,
    dp_axes: tuple[str, ...] = (),
    activation: str | None = None,
    residual: jax.Array | None = None,
    plan: MeshPlan | None = None,
    interpret: bool = False,
    out_dtype: jnp.dtype | None = None,
) -> jax.Array:
    """Distributed fused linear: ``act(x @ w + b) + residual`` as a
    shard_map-composed collective schedule around the local flex kernels.

    x (M, K) with M sharded over ``(*dp_axes, axis)``; w (K, N) K-sharded
    over ``axis``; b (N,) or None; residual (M, N) or None.  The output is
    (M, N), token-sharded like x.  Requires ``M % (dp * tp) == 0`` and
    ``K % tp == 0`` (``core.cmu.mesh_shardable`` — callers fall back to the
    single-device path otherwise, the same contract as the attention
    shard_map path).

    ``plan`` is the layer's CMU mesh sub-plan; None means trace-time
    selection: mesh dataflow from the analytical ICI model
    (``best_mesh_dataflow``), local geometry from the roofline argmin.
    Differentiable end-to-end (see module docstring).
    """
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"inner dims mismatch: {x.shape} @ {w.shape}")
    tp = int(mesh.shape[axis])
    dp = dp_size(mesh, dp_axes)
    if tp <= 1 or M % (dp * tp) or K % tp:
        raise ValueError(
            f"GEMM ({M},{K},{N}) does not divide mesh (dp={dp}, tp={tp}); "
            "callers must fall back to the single-device path"
        )
    if plan is not None and (plan.tp != tp or plan.dp != dp
                             or plan.axis != axis):
        plan = None  # stale sub-plan (other topology): trace-time fallback
    if plan is not None:
        mesh_df = plan.dataflow
    else:
        mesh_df, _ = best_mesh_dataflow(GemmShape(M // dp, K, N), tp)
    lshape = mesh_local_gemm(GemmShape(M, K, N), mesh_df, tp, dp)
    ldf, lblk, lstrip, bwd_dx, bwd_dw = _local_specs(plan, lshape)
    odt = out_dtype or jnp.promote_types(x.dtype, w.dtype)
    ksh = K // tp

    def _is_body(x_l, w_l, b_l, r_l):
        # gather the K-sharded weight; the local kernel is the whole layer,
        # epilogue fused in the flush
        w_full = jax.lax.all_gather(w_l, axis, axis=0, tiled=True)
        return ops.flex_linear(
            x_l, w_full, b_l, activation=activation, residual=r_l,
            dataflow=ldf, block=lblk, interpret=interpret, out_dtype=odt,
            bwd_dx=bwd_dx, bwd_dw=bwd_dw, strip=lstrip,
        )

    def _ws_body(x_l, w_l, b_l, r_l):
        # rebuild the DP group's token block, contract this chip's K-shard,
        # reduce + re-shard the f32 partials in one psum-scatter
        a_full = jax.lax.all_gather(x_l, axis, axis=0, tiled=True)
        j = jax.lax.axis_index(axis)
        a_sl = jax.lax.dynamic_slice_in_dim(a_full, j * ksh, ksh, axis=1)
        part = ops.flex_linear(
            a_sl, w_l, None, dataflow=ldf, block=lblk, interpret=interpret,
            out_dtype=jnp.float32, bwd_dx=bwd_dx, bwd_dw=bwd_dw, strip=lstrip,
        )
        c = jax.lax.psum_scatter(part, axis, scatter_dimension=0, tiled=True)
        return _post_epilogue(c, b_l, r_l, activation, odt)

    def _os_body(x_l, w_l, b_l, r_l):
        # SUMMA ring: the output shard stays resident, the weight shard
        # rotates; step s contracts the k-slice matching the shard currently
        # held ((j + s) mod tp).  tp - 1 rotations, none after the last MAC.
        j = jax.lax.axis_index(axis)
        acc = jnp.zeros((x_l.shape[0], N), jnp.float32)
        w_cur = w_l
        for s in range(tp):
            src = (j + s) % tp
            a_sl = jax.lax.dynamic_slice_in_dim(x_l, src * ksh, ksh, axis=1)
            acc = acc + ops.flex_linear(
                a_sl, w_cur, None, dataflow=ldf, block=lblk,
                interpret=interpret, out_dtype=jnp.float32,
                bwd_dx=bwd_dx, bwd_dw=bwd_dw, strip=lstrip,
            )
            if s != tp - 1:
                w_cur = jax.lax.ppermute(
                    w_cur, axis, perm=[(i, (i - 1) % tp) for i in range(tp)]
                )
        return _post_epilogue(acc, b_l, r_l, activation, odt)

    body = {Dataflow.IS: _is_body, Dataflow.WS: _ws_body,
            Dataflow.OS: _os_body}[mesh_df]

    tok_spec = P((*dp_axes, axis), None)
    args, in_specs = [x, w], [tok_spec, P(axis, None)]
    if b is not None:
        args.append(b)
        in_specs.append(P(None))
    if residual is not None:
        args.append(residual)
        in_specs.append(tok_spec)

    def local_fn(*a):
        it = iter(a)
        x_l, w_l = next(it), next(it)
        b_l = next(it) if b is not None else None
        r_l = next(it) if residual is not None else None
        return body(x_l, w_l, b_l, r_l)

    return shard_map(
        local_fn, mesh=mesh, in_specs=tuple(in_specs), out_specs=tok_spec,
        check_rep=False,
    )(*args)
