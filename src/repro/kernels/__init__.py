"""Pallas TPU kernels for the Flex-TPU reproduction."""

from .flash_attention import (
    ATTN_DECODE_KINDS,
    ATTN_SWEEPS,
    flash_attention,
    flex_attention,
    mha_flash,
    paged_attention,
    paged_attention_reference,
)
from .flex_scan import (
    SCAN_DECODE_KINDS,
    SCAN_SWEEPS,
    flex_recurrent_step,
    flex_scan,
)
from .flex_matmul import (
    ACTIVATIONS,
    DEFAULT_BLOCK,
    fused_matmul,
    matmul,
    matmul_is,
    matmul_os,
    matmul_ws,
)
from .mesh_ops import flex_linear_sharded
from .ops import auto_matmul, default_interpret, flex_linear, flex_matmul
from .quantize import (
    QDTYPES,
    QMAX,
    abs_max_scale,
    channel_scale,
    dequantize_channel,
    quantize_channel,
)
from .ref import attention_ref, blocked_matmul_ref, linear_ref, matmul_ref

__all__ = [
    "ACTIVATIONS",
    "ATTN_DECODE_KINDS",
    "ATTN_SWEEPS",
    "DEFAULT_BLOCK",
    "QDTYPES",
    "QMAX",
    "SCAN_DECODE_KINDS",
    "SCAN_SWEEPS",
    "abs_max_scale",
    "attention_ref",
    "auto_matmul",
    "channel_scale",
    "dequantize_channel",
    "blocked_matmul_ref",
    "default_interpret",
    "flash_attention",
    "flex_attention",
    "flex_linear",
    "flex_linear_sharded",
    "flex_matmul",
    "flex_recurrent_step",
    "flex_scan",
    "fused_matmul",
    "linear_ref",
    "matmul",
    "matmul_is",
    "matmul_os",
    "matmul_ref",
    "mha_flash",
    "matmul_ws",
    "paged_attention",
    "paged_attention_reference",
    "quantize_channel",
]
