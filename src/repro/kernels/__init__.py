"""Pallas TPU kernels for the Flex-TPU reproduction."""

from .flash_attention import flash_attention, mha_flash
from .flex_matmul import DEFAULT_BLOCK, matmul, matmul_is, matmul_os, matmul_ws
from .ops import auto_matmul, flex_matmul
from .ref import attention_ref, blocked_matmul_ref, matmul_ref

__all__ = [
    "DEFAULT_BLOCK",
    "attention_ref",
    "auto_matmul",
    "blocked_matmul_ref",
    "flash_attention",
    "flex_matmul",
    "matmul",
    "matmul_is",
    "matmul_os",
    "matmul_ref",
    "mha_flash",
    "matmul_ws",
]
