"""Flex chunked-scan kernels: the SSM analogue of ``flex_attention``.

Both Mamba2 (SSD) and RWKV-6 reduce to a diagonal-decay linear attention

    S_t = diag(w_t) S_{t-1} + k_t v_t^T ,   o_t = r_t^T S'_t

whose chunked form is exactly the GEMM family the CMU already schedules:
per chunk, an (L, L) intra-chunk score GEMM, an (L, M) output GEMM and an
(N, M) state-update GEMM.  This module exposes that scan as a *schedule
family* over folded ``(B*H, C, L, .)`` operands with two CMU knobs:

``chunk``
    The intra-chunk length L.  Bounded by exp-safety: every in-chunk
    exponent is within ``|LOG_DECAY_MIN| * chunk``, so candidates keep
    ``3 * chunk < 88`` (f32 exp range).

``sweep`` — where the running (N, M) f32 state lives across the chunk grid:

    "state" (state-stationary)
        The whole ``(B*H*N, M)`` state slab is a single never-moving output
        block: it stays VMEM-resident across the entire grid and is written
        to HBM exactly once at the end.  Maximum VMEM footprint, minimum
        state traffic — the schedule the 96 MiB budget prunes first as
        ``B*H*N*M`` grows.
    "out" (output-stationary)
        The state is a per-(b, h) ``(N, M)`` output block revisited
        *non-consecutively* across the outer chunk axis, so it streams
        through HBM (read-modify-write) once per chunk step — the same
        revisiting semantics the streamed WS/IS matmul kernels use for
        partial sums.  Minimum VMEM, ~2C x state HBM traffic.

Both sweeps run the identical grid ``(C, B*H)`` (chunks outer) and the
identical ``_chunk_update`` op sequence — the sweep changes *where* the
state lives, never the arithmetic — so the two schedules agree **bitwise**.

The fused epilogue covers both recurrence conventions: RWKV
(``post_update=False``: output reads the pre-update state, strict-lower
intra-chunk mask, plus the u-bonus diagonal) and Mamba2
(``post_update=True``: post-update state, inclusive mask, no bonus).

``flex_recurrent_step`` is the decode-shaped member: one fused O(1) step of
the same recurrence over ``(B*H, .)`` operands.

Validated on CPU with interpret=True against
``models.ssm.chunked_diag_linear_attn`` (tests/test_flex_ssm.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flex_matmul import CompilerParams

#: Chunk-grid sweep orders (where the running state lives).
SCAN_SWEEPS = ("state", "out")

#: Decode kinds: the fused Pallas step kernel vs the jnp recurrence.
#: (Chunk-length candidates live in ``core.dataflow.SCAN_CHUNK_CANDIDATES``,
#: next to the traffic model that prices them.)
SCAN_DECODE_KINDS = ("fused", "einsum")


def _chunk_update(rc, kc, vc, lw, u, S, *, post_update: bool):
    """One chunk of the diagonal-decay recurrence, all f32.

    rc/kc/lw: (L, N); vc: (L, M); u: (1, N) bonus row or None; S: (N, M).
    Returns (o (L, M), S_new (N, M)).

    Shared verbatim by both sweeps: the sweep decides where S lives (VMEM
    slab vs HBM-streamed block), never the op sequence, so the two
    schedules agree bitwise.  The factoring matches
    ``models.ssm.chunked_diag_linear_attn``: with cum = inclusive
    cumsum(log_w), r_fac = r*exp(cum or cum_prev) has exponents <= 0 and
    k_fac = k*exp(-cum) exponents <= |LOG_DECAY_MIN|*L — all f32-safe.
    """
    L = rc.shape[0]
    cum = jnp.cumsum(lw, axis=0)
    cum_prev = cum - lw
    r_fac = rc * jnp.exp(cum if post_update else cum_prev)
    k_fac = kc * jnp.exp(-cum)
    scores = jax.lax.dot_general(
        r_fac, k_fac, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    ri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    ci = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    # strict lower triangle (j<i) for RWKV; lower incl. diagonal for Mamba2
    mask = (ci <= ri) if post_update else (ci < ri)
    scores = jnp.where(mask, scores, 0.0)
    o = jax.lax.dot_general(
        scores, vc, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if u is not None:  # RWKV u-bonus diagonal (pre-update convention)
        o = o + jnp.sum(rc * u * kc, axis=1, keepdims=True) * vc
    # inter-chunk: contribution of the carried state
    o = o + jax.lax.dot_general(
        r_fac, S, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    # state update: decay the carry across the chunk, add the k v^T tail
    decay_all = jnp.exp(cum[-1:])                 # (1, N)
    k_tail = kc * jnp.exp(cum[-1:] - cum)         # exponent <= 0
    S_new = S * decay_all.T + jax.lax.dot_general(
        k_tail, vc, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return o, S_new


def _scan_kernel(*refs, sweep: str, post_update: bool, n: int):
    if post_update:
        r_ref, k_ref, v_ref, lw_ref, o_ref, s_ref = refs
        u = None
    else:
        r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref = refs
        u = u_ref[...]  # (1, N) f32
    c, bh = pl.program_id(0), pl.program_id(1)
    rc = r_ref[0, 0].astype(jnp.float32)   # (L, N)
    kc = k_ref[0, 0].astype(jnp.float32)
    vc = v_ref[0, 0].astype(jnp.float32)   # (L, M)
    lw = lw_ref[0, 0]                      # (L, N) f32
    if sweep == "state":
        # whole-slab output block, never moving: this row stays VMEM-resident
        S = s_ref[pl.ds(bh * n, n), :]
    else:
        # per-(b,h) block revisited each c: streams through HBM between chunks
        S = s_ref[...]
    S = jnp.where(c == 0, jnp.zeros_like(S), S)
    o, S_new = _chunk_update(rc, kc, vc, lw, u, S, post_update=post_update)
    o_ref[0, 0] = o.astype(o_ref.dtype)
    if sweep == "state":
        s_ref[pl.ds(bh * n, n), :] = S_new
    else:
        s_ref[...] = S_new


def flex_scan(
    r: jax.Array,       # (B, T, H, N)
    k: jax.Array,       # (B, T, H, N)
    v: jax.Array,       # (B, T, H, M)
    log_w: jax.Array,   # (B, T, H, N), in [LOG_DECAY_MIN, 0]
    diag_scale: jax.Array | None = None,  # (H, N) RWKV u bonus; None -> ones
    *,
    chunk: int = 16,
    sweep: str = "state",
    post_update: bool = False,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Schedule-parameterized chunked scan.  Returns (o (B,T,H,M) in
    ``v.dtype``, final state (B,H,N,M) f32), matching
    ``models.ssm.chunked_diag_linear_attn`` with ``state0=None``.

    ``sweep`` and ``chunk`` are the CMU's schedule knobs (see module
    docstring).  T must divide ``chunk``; the model-side dispatch pads
    ragged T with zero rows, which are exact no-ops for both outputs
    (``models.ssm._pad_chunks``).
    """
    if sweep not in SCAN_SWEEPS:
        raise ValueError(f"sweep must be one of {SCAN_SWEEPS}, got {sweep!r}")
    B, T, H, N = r.shape
    M = v.shape[-1]
    if T % chunk:
        raise ValueError(f"T={T} must divide chunk={chunk}")
    C, L = T // chunk, chunk
    BH = B * H

    def fold(a, d):
        return jnp.moveaxis(a, 2, 1).reshape(BH, C, L, d)

    inputs = [fold(r, N), fold(k, N), fold(v, M),
              fold(log_w.astype(jnp.float32), N)]
    in_specs = [
        pl.BlockSpec((1, 1, L, N), lambda c, bh: (bh, c, 0, 0)),
        pl.BlockSpec((1, 1, L, N), lambda c, bh: (bh, c, 0, 0)),
        pl.BlockSpec((1, 1, L, M), lambda c, bh: (bh, c, 0, 0)),
        pl.BlockSpec((1, 1, L, N), lambda c, bh: (bh, c, 0, 0)),
    ]
    if not post_update:
        ds = (jnp.ones((H, N), jnp.float32) if diag_scale is None
              else diag_scale.astype(jnp.float32))
        inputs.append(jnp.broadcast_to(ds[None], (B, H, N)).reshape(BH, N))
        in_specs.append(pl.BlockSpec((1, N), lambda c, bh: (bh, 0)))
    if sweep == "state":
        s_spec = pl.BlockSpec((BH * N, M), lambda c, bh: (0, 0))
    else:
        s_spec = pl.BlockSpec((N, M), lambda c, bh: (bh, 0))
    if interpret is None:
        from repro.kernels import ops

        interpret = ops.default_interpret()
    o, S = pl.pallas_call(
        functools.partial(_scan_kernel, sweep=sweep,
                          post_update=post_update, n=N),
        grid=(C, BH),  # chunks OUTER: every (b,h) advances one chunk per row
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((1, 1, L, M), lambda c, bh: (bh, c, 0, 0)),
                   s_spec],
        out_shape=[jax.ShapeDtypeStruct((BH, C, L, M), v.dtype),
                   jax.ShapeDtypeStruct((BH * N, M), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(*inputs)
    o = jnp.moveaxis(o.reshape(B, H, T, M), 1, 2)
    return o, S.reshape(B, H, N, M)


def _step_kernel(*refs, post_update: bool):
    if post_update:
        r_ref, k_ref, v_ref, lw_ref, s0_ref, o_ref, s_ref = refs
        u = None
    else:
        r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, o_ref, s_ref = refs
        u = u_ref[...]                     # (BH, N) f32
    r = r_ref[...].astype(jnp.float32)     # (BH, N)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)     # (BH, M)
    lw = lw_ref[...]                       # (BH, N) f32
    S = s0_ref[...]                        # (BH, N, M) f32
    S_new = S * jnp.exp(lw)[:, :, None] + k[:, :, None] * v[:, None, :]
    if post_update:  # Mamba2: output reads the post-update state
        o = jax.lax.dot_general(
            r, S_new, (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
    else:  # RWKV: pre-update state + u-bonus diagonal
        o = jax.lax.dot_general(
            r, S, (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        o = o + jnp.sum(r * u * k, axis=1, keepdims=True) * v
    o_ref[...] = o.astype(o_ref.dtype)
    s_ref[...] = S_new


def flex_recurrent_step(
    r: jax.Array,       # (B, H, N)
    k: jax.Array,
    v: jax.Array,       # (B, H, M)
    log_w: jax.Array,   # (B, H, N)
    S: jax.Array,       # (B, H, N, M) f32
    diag_scale: jax.Array | None = None,
    *,
    post_update: bool = False,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """One fused decode step of the recurrence — the Pallas counterpart of
    ``models.ssm.recurrent_step`` (same signature semantics).  The whole
    bucketed batch runs as a single fused kernel: state in, state out, one
    HBM round trip, no jnp intermediate for the k v^T outer product."""
    B, H, N = r.shape
    M = v.shape[-1]
    BH = B * H
    inputs = [r.reshape(BH, N), k.reshape(BH, N), v.reshape(BH, M),
              log_w.astype(jnp.float32).reshape(BH, N)]
    if not post_update:
        ds = (jnp.ones((H, N), jnp.float32) if diag_scale is None
              else diag_scale.astype(jnp.float32))
        inputs.append(jnp.broadcast_to(ds[None], (B, H, N)).reshape(BH, N))
    inputs.append(S.reshape(BH, N, M).astype(jnp.float32))
    if interpret is None:
        from repro.kernels import ops

        interpret = ops.default_interpret()
    o, S_new = pl.pallas_call(
        functools.partial(_step_kernel, post_update=post_update),
        out_shape=[jax.ShapeDtypeStruct((BH, M), v.dtype),
                   jax.ShapeDtypeStruct((BH, N, M), jnp.float32)],
        interpret=interpret,
    )(*inputs)
    return o.reshape(B, H, M), S_new.reshape(B, H, N, M)
