"""Per-channel quantization helpers shared by kernels, CMU, and runtime.

One source of truth for the abs-max scale computation: the flex kernels'
weight-only int8/fp8 path (``ops.flex_linear`` with ``qdtype=``), the CMU's
accuracy-gate calibration (``cmu.measure_quant_error``), and the gradient
compressor (``runtime.compression``) all derive their scales here, so a
plan recorded against one quantizer dispatches against the same one.

Convention: symmetric per-channel scales, ``scale = abs_max / QMAX + eps``
with ``QMAX = 127`` for int8 and ``448`` (the e4m3 max finite) for fp8.
Quantized values dequantize as ``q * scale``; with f32 accumulation in the
kernels this is exact for the stored lattice points, so dequant commutes
with k-accumulation and can run once at the flush epilogue.
"""

from __future__ import annotations

import jax.numpy as jnp

#: Quantized operand dtypes the flex kernels support.
QDTYPES = ("int8", "fp8")

#: Largest representable magnitude per qdtype (e4m3's max finite is 448).
QMAX = {"int8": 127.0, "fp8": 448.0}

_FP8 = jnp.float8_e4m3fn


def abs_max_scale(x, qdtype: str, axis, keepdims: bool = True):
    """Symmetric abs-max scale of ``x`` along ``axis``: the one per-channel
    scale formula every quantizer in the repo uses.  f32 math, with the
    classic ``+ 1e-12`` guard so all-zero channels divide cleanly."""
    if qdtype not in QMAX:
        raise ValueError(f"unknown quantized dtype {qdtype!r}")
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=keepdims)
    return amax / QMAX[qdtype] + 1e-12


def channel_scale(w, qdtype: str, axis: int = 0):
    """Per-output-channel scale for a ``(K, N)`` weight: reduce over ``axis``
    (the contraction axis), keeping dims — shape ``(1, N)`` f32."""
    return abs_max_scale(w, qdtype, axis=axis, keepdims=True)


def quantize_channel(w, qdtype: str, axis: int = 0):
    """Quantize ``w`` per channel → ``(q, scale)``.

    int8: round-to-nearest, clipped to ±127.  fp8: clip to ±448 then cast
    (the cast itself rounds to the nearest e4m3 lattice point).  Either way
    ``q.astype(f32) * scale`` is the dequantized weight.
    """
    scale = channel_scale(w, qdtype, axis=axis)
    b = w.astype(jnp.float32) / scale
    if qdtype == "int8":
        q = jnp.clip(jnp.round(b), -127, 127).astype(jnp.int8)
    else:
        q = jnp.clip(b, -448.0, 448.0).astype(_FP8)
    return q, scale


def dequantize_channel(q, scale):
    """Inverse of ``quantize_channel`` (up to rounding): f32 dequant."""
    return q.astype(jnp.float32) * scale
