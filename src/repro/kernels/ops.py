"""jit'd public wrappers around the flex dataflow kernels.

``flex_matmul`` is the op the model stack calls: it pads to block multiples,
dispatches to the CMU-selected dataflow kernel, and falls back to plain XLA
``jnp.dot`` when the kernel path is disabled (CPU dry-runs / compile-only
meshes, where XLA must see a fusible dot for cost_analysis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.dataflow import Dataflow, GemmShape, best_kernel_dataflow

from . import flex_matmul as fk


def _pad_to(x: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(
    jax.jit, static_argnames=("dataflow", "block", "interpret", "out_dtype")
)
def flex_matmul(
    a: jax.Array,
    b: jax.Array,
    dataflow: Dataflow = Dataflow.OS,
    block: tuple[int, int, int] = fk.DEFAULT_BLOCK,
    interpret: bool = False,
    out_dtype: jnp.dtype | None = None,
) -> jax.Array:
    """C = A @ B under the given dataflow; pads/unpads to block multiples."""
    M, K = a.shape
    K2, N = b.shape
    if K != K2:
        raise ValueError(f"inner dims mismatch: {a.shape} @ {b.shape}")
    bm, bk, bn = block
    bm, bk, bn = min(bm, _round_up(M)), min(bk, _round_up(K)), min(bn, _round_up(N))
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    out = fk.matmul(ap, bp, dataflow, block=(bm, bk, bn), interpret=interpret)
    out = out[:M, :N]
    return out.astype(out_dtype or jnp.promote_types(a.dtype, b.dtype))


def _round_up(d: int, mult: int = 128) -> int:
    """Smallest MXU-aligned block covering d (min 8 sublanes for tiny dims)."""
    if d >= mult:
        return mult
    r = 8
    while r < d:
        r *= 2
    return r


def auto_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    name: str = "",
    interpret: bool = False,
) -> jax.Array:
    """CMU-in-the-loop matmul: picks the dataflow from shapes at trace time.

    Shape-driven and trace-time static — the deployment model of the paper
    (offline selection, zero runtime switching cost).
    """
    shape = GemmShape(M=a.shape[0], K=a.shape[1], N=b.shape[1], name=name)
    df, _ = best_kernel_dataflow(shape)
    return flex_matmul(a, b, dataflow=df, interpret=interpret)
