"""jit'd public wrappers around the flex dataflow kernels.

``flex_linear`` is the op the model stack calls: a full linear layer —
``act(x @ w + b) + residual`` — with the epilogue fused into the Pallas
kernel's final flush, so bias/activation/residual never re-stream the matmul
output through HBM.  It pads to block multiples, dispatches to the
CMU-selected dataflow kernel, and unpads.

``flex_matmul`` is the bare-matmul variant kept for benchmarks and the
paper-claims suite; ``auto_matmul`` adds trace-time CMU dataflow selection.
The model stack falls back to plain XLA einsum when the kernel path is
disabled (CPU dry-runs / compile-only meshes, where XLA must see a fusible
dot for cost_analysis).

**Training (custom VJP).**  Both ops carry a ``jax.custom_vjp`` so
``jax.grad`` keeps the hot path on Pallas: the two backward GEMMs

  dX[M,K] = dY[M,N] @ W^T[N,K]        (cotangent wrt activations)
  dW[K,N] = X^T[K,M] @ dY[M,N]        (cotangent wrt weights)

run as flex kernels under their **own** (dataflow, block) — the backward
shapes generally prefer different stationarity than the forward (the paper's
per-layer reconfiguration argument applied to training).  ``flex_linear``
takes ``bwd_dx`` / ``bwd_dw`` overrides from a CMU train plan (None means
the trace-time roofline argmin); ``flex_matmul``'s backward always uses the
trace-time argmin.

**Transpose-free backward (default).**  The operand transposes above are
expressed through the kernels' ``trans_a`` / ``trans_b`` index-map variants:
dX streams W exactly as stored, (K, N) physical read as (N, K)-logical
(``trans_b``), and dW streams X as stored, (M, K) physical read as
(K, M)-logical (``trans_a``) — **no HBM transpose copy is ever issued**.  A
``BwdSpec`` may carry an explicit third element ``(trans_a, trans_b)``; a
CMU plan that *measured* the copy-based fallback as faster (it rarely is —
the copy round-trips the operand through HBM) can program
``(False, False)``, in which case the transpose is materialised exactly as
the pre-v3 code did.

Residual policy: **save, don't recompute**.  The forward kernel emits the
f32 pre-activation ``z = x @ w + b`` as a second output (``save_preact``) —
free for WS/IS whose staging buffer already materialises it, one extra f32
write for OS — and the VJP differentiates the epilogue as

  d_residual = dY
  dZ         = dY * act'(z)           (via jax.vjp of the activation at z)
  d_bias     = sum_M dZ

Saving z costs M*N*4 bytes of HBM versus recomputing the full forward GEMM
in the backward pass; on every shape the CMU models, the write is cheaper.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.dataflow import Dataflow, GemmShape, best_kernel_dataflow

from . import flex_matmul as fk
from .quantize import QDTYPES, quantize_channel

# Override for one backward GEMM, e.g. from a CMU plan:
#   (Dataflow.WS, (256, 256, 256))                 — block None = DEFAULT_BLOCK
#   (Dataflow.WS, (256, 256, 256), (False, True))  — explicit operand layout:
#     the third element is (trans_a, trans_b); omitted means the role's
#     zero-copy transposed-operand variant (the v3 default).
#   (Dataflow.WS, (256, 256, 256), (False, True), 4) — explicit accumulator
#     strip depth; omitted (pre-v4 specs) means 1, today's streamed WS/IS.
BwdSpec = tuple  # (Dataflow, block | None[, (trans_a, trans_b)[, strip]])


def _pad_to(x: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def default_interpret() -> bool:
    """Pallas kernels need interpret mode off-TPU (CPU CI, dry-runs)."""
    return jax.default_backend() != "tpu"


def _fit_block(M: int, K: int, N: int, block: tuple[int, int, int]):
    """Shrink each block dim to the padded extent of its GEMM dim — a block
    larger than the (128-aligned) dim just wastes VMEM — while honouring
    CMU-tuned blocks above 128."""

    def fit(d: int, bd: int) -> int:
        return min(bd, _round_up_dim(d))

    bm, bk, bn = block
    return fit(M, bm), fit(K, bk), fit(N, bn)


def _round_up_dim(d: int, mult: int = 128) -> int:
    """Smallest MXU-aligned extent covering d (min 8 sublanes for tiny dims)."""
    if d >= mult:
        return -(-d // mult) * mult
    r = 8
    while r < d:
        r *= 2
    return r


def _fit_strip(dataflow: Dataflow, strip: int, M: int, N: int,
               block: tuple[int, int, int]) -> int:
    """Clamp a requested accumulator-strip depth to what the padded geometry
    admits: the largest depth <= ``strip`` that tiles the strip axis's block
    count exactly (M blocks for WS, N blocks for IS).  OS always runs 1.
    CMU-planned strips already tile the axis they were tuned for, so this
    only engages when a plan is applied to a different (padded) geometry.
    """
    if strip <= 1 or dataflow is Dataflow.OS:
        return 1
    bm, _, bn = block
    # the padded extent is the next block multiple, so ceil is the block count
    blocks = -(-M // bm) if dataflow is Dataflow.WS else -(-N // bn)
    s = max(1, min(strip, blocks))
    while blocks % s:
        s -= 1
    return s


def _bwd_choice(spec: BwdSpec | None, M: int, K: int, N: int,
                default_trans: tuple[bool, bool] = (False, False)):
    """Resolve one backward GEMM's (dataflow, block, trans, strip): the CMU
    plan's choice when given, else the trace-time roofline argmin (shapes
    are static).  ``default_trans`` is the role's zero-copy operand layout —
    a 2-tuple spec (legacy, pre-v3) inherits it; a 3-tuple spec states its
    own (the CMU may have measured the copy-based fallback as faster).  The
    optional 4th element is the accumulator-strip depth; pre-v4 specs omit
    it and run streamed (strip=1), as does the trace-time fallback."""
    if spec is not None:
        df, blk = spec[0], spec[1]
        trans = tuple(spec[2]) if len(spec) > 2 and spec[2] is not None \
            else default_trans
        strip = int(spec[3]) if len(spec) > 3 and spec[3] else 1
        return df, tuple(blk) if blk else fk.DEFAULT_BLOCK, trans, strip
    df, _ = best_kernel_dataflow(GemmShape(M=M, K=K, N=N))
    return df, fk.DEFAULT_BLOCK, default_trans, 1


# ---------------------------------------------------------------------------
# flex_matmul — bare matmul with a flex-kernel VJP
# ---------------------------------------------------------------------------


def _matmul_run(a, b, dataflow, block, interpret, out_dtype,
                trans_a: bool = False, trans_b: bool = False, strip: int = 1,
                qdtype: str | None = None):
    """Primal blocked matmul: pad -> flex kernel -> unpad -> cast.

    With ``trans_a`` / ``trans_b`` the operands are in transposed physical
    layout ((K, M) / (N, K)); padding follows the physical axes and the
    kernel reads them through the transposed index maps — no copy.
    ``strip`` selects the WS/IS two-level schedule, clamped to what the
    padded geometry admits (``_fit_strip``).  ``qdtype`` quantizes the B
    operand per output channel (int8/fp8) and dispatches the fused-dequant
    kernel — untransposed operands only (the backward GEMMs run on the
    saved full-precision operands, so the quant path never needs trans).
    """
    M, K, N = fk._logical_dims(a, b, trans_a, trans_b)
    bm, bk, bn = _fit_block(M, K, N, block)
    strip = _fit_strip(dataflow, strip, M, N, (bm, bk, bn))
    if qdtype in QDTYPES:
        if trans_a or trans_b:
            raise ValueError(
                "quantized flex_matmul supports untransposed operands only")
        out_dtype = out_dtype or jnp.promote_types(a.dtype, b.dtype)
        qb, scale = quantize_channel(b, qdtype, axis=0)
        out = fk.fused_matmul(
            _pad_to(a, bm, bk), _pad_to(qb, bk, bn), dataflow,
            qscale=_pad_to(scale, 1, bn), block=(bm, bk, bn),
            interpret=interpret, strip=strip,
        )
        return out[:M, :N].astype(out_dtype)
    ap = _pad_to(a, bk, bm) if trans_a else _pad_to(a, bm, bk)
    bp = _pad_to(b, bn, bk) if trans_b else _pad_to(b, bk, bn)
    out = fk.matmul(ap, bp, dataflow, block=(bm, bk, bn), interpret=interpret,
                    trans_a=trans_a, trans_b=trans_b, strip=strip)
    out = out[:M, :N]
    return out.astype(out_dtype or jnp.promote_types(a.dtype, b.dtype))


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _matmul_core(cfg, a, b):
    return _matmul_run(a, b, *cfg)


def _matmul_fwd(cfg, a, b):
    return _matmul_core(cfg, a, b), (a, b)


def _matmul_bwd(cfg, residuals, g):
    # qdtype is forward-only: the cotangent GEMMs run on the saved
    # full-precision operands (straight-through estimator).
    dataflow, block, interpret, out_dtype, trans_a, trans_b, strip, _ = cfg
    a, b = residuals
    M, K, N = fk._logical_dims(a, b, trans_a, trans_b)
    # With A' = op(A), B' = op(B):  dA' = g @ B'^T  and  dB' = A'^T @ g.
    # Each cotangent is issued directly in its operand's *stored* layout —
    # the trans flags below are the algebra of op() folded into the index
    # maps, so no combination of flags ever materialises a transpose.
    if trans_a:
        # dA (stored (K, M)) = B' @ g^T — a (K,N)x(N,M) GEMM.
        df, blk, _, st = _bwd_choice(None, K, N, M)
        da = _matmul_run(b, g, df, blk, interpret, a.dtype,
                         trans_a=trans_b, trans_b=True, strip=st)
    else:
        # dA = g @ B'^T — an (M,N)x(N,K) GEMM; B'^T reads stored B directly.
        df, blk, _, st = _bwd_choice(None, M, N, K)
        da = _matmul_run(g, b, df, blk, interpret, a.dtype,
                         trans_b=not trans_b, strip=st)
    if trans_b:
        # dB (stored (N, K)) = g^T @ A' — an (N,M)x(M,K) GEMM.
        df, blk, _, st = _bwd_choice(None, N, M, K)
        db = _matmul_run(g, a, df, blk, interpret, b.dtype,
                         trans_a=True, trans_b=trans_a, strip=st)
    else:
        # dB = A'^T @ g — a (K,M)x(M,N) GEMM; A'^T reads stored A directly.
        df, blk, _, st = _bwd_choice(None, K, M, N)
        db = _matmul_run(a, g, df, blk, interpret, b.dtype,
                         trans_a=not trans_a, strip=st)
    return da, db


_matmul_core.defvjp(_matmul_fwd, _matmul_bwd)


@functools.partial(
    jax.jit, static_argnames=("dataflow", "block", "interpret", "out_dtype",
                              "trans_a", "trans_b", "strip", "qdtype")
)
def flex_matmul(
    a: jax.Array,
    b: jax.Array,
    dataflow: Dataflow = Dataflow.OS,
    block: tuple[int, int, int] = fk.DEFAULT_BLOCK,
    interpret: bool = False,
    out_dtype: jnp.dtype | None = None,
    trans_a: bool = False,
    trans_b: bool = False,
    strip: int = 1,
    qdtype: str | None = None,
) -> jax.Array:
    """C = op(A) @ op(B) under the given dataflow; pads/unpads to block
    multiples.  ``trans_a`` / ``trans_b`` read the operands in transposed
    physical layout through the kernels' index maps — zero HBM copies.
    ``strip >= 2`` runs the WS/IS two-level schedule (VMEM-resident
    accumulator strip, no partial-sum HBM traffic), clamped to the padded
    geometry; OS and ``strip = 1`` run today's streamed schedules.
    ``qdtype`` ("int8"/"fp8") quantizes B per output channel and runs the
    fused-dequant kernel — forward only; gradients flow straight-through.

    Differentiable: ``jax.grad`` routes both cotangent GEMMs back through
    the flex kernels, themselves transpose-free for every flag combination
    (see the module docstring's VJP contract).
    """
    fk._logical_dims(a, b, trans_a, trans_b)  # validates the inner dims
    return _matmul_core(
        (dataflow, block, interpret, out_dtype, trans_a, trans_b, strip,
         qdtype), a, b
    )


# ---------------------------------------------------------------------------
# flex_linear — fused linear layer with a flex-kernel VJP
# ---------------------------------------------------------------------------


class _LinearCfg(NamedTuple):
    """Hashable trace-time config for one fused linear (the nondiff arg)."""

    activation: str | None
    dataflow: Dataflow
    block: tuple[int, int, int]
    interpret: bool
    out_dtype: jnp.dtype | None
    bwd_dx: BwdSpec | None
    bwd_dw: BwdSpec | None
    strip: int = 1
    qdtype: str | None = None


def _linear_run(cfg: _LinearCfg, x, w, b, residual, save_preact: bool):
    """Primal fused linear; returns (out, z) with z=None unless save_preact."""
    M, K = x.shape
    _, N = w.shape
    bm, bk, bn = _fit_block(M, K, N, cfg.block)
    strip = _fit_strip(cfg.dataflow, cfg.strip, M, N, (bm, bk, bn))
    odt = cfg.out_dtype or jnp.promote_types(x.dtype, w.dtype)
    qscale = None
    if cfg.qdtype in QDTYPES:
        # weight-only quant: per-output-channel scale rides the bias plumbing
        # into the kernel, dequant fuses at the flush before the epilogue
        w, qscale = quantize_channel(w, cfg.qdtype, axis=0)
        qscale = _pad_to(qscale, 1, bn)
    xp = _pad_to(x, bm, bk)
    wp = _pad_to(w, bk, bn)
    bp = None if b is None else _pad_to(b.reshape(1, N), 1, bn)
    rp = None if residual is None else _pad_to(residual, bm, bn)
    out = fk.fused_matmul(
        xp, wp, cfg.dataflow,
        bias=bp, residual=rp, activation=cfg.activation, out_dtype=odt,
        block=(bm, bk, bn), interpret=cfg.interpret, save_preact=save_preact,
        strip=strip, qscale=qscale,
    )
    if save_preact:
        out, z = out
        return out[:M, :N].astype(odt), z[:M, :N]
    return out[:M, :N].astype(odt), None


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _linear_core(cfg: _LinearCfg, x, w, b, residual):
    out, _ = _linear_run(cfg, x, w, b, residual, save_preact=False)
    return out


def _linear_fwd(cfg: _LinearCfg, x, w, b, residual):
    # z is only needed to differentiate the activation; bias/residual grads
    # come straight from the cotangent.  Zero-size protos carry the epilogue
    # operands' dtypes to bwd without retaining the arrays.
    need_z = cfg.activation is not None
    out, z = _linear_run(cfg, x, w, b, residual, save_preact=need_z)
    # zero-size protos keep b/residual's shape rank and dtype for bwd (the
    # cotangent aval must match the primal: (N,) vs (1, N) bias both work)
    b_proto = None if b is None else jnp.zeros((0,) * b.ndim, b.dtype)
    r_proto = None if residual is None else jnp.zeros((0,), residual.dtype)
    return out, (x, w, b_proto, r_proto, z)


def _linear_bwd(cfg: _LinearCfg, residuals, g):
    x, w, b_proto, r_proto, z = residuals
    M, K = x.shape
    N = w.shape[1]
    g32 = g.astype(jnp.float32)
    if cfg.activation is not None:
        # exact activation derivative at the saved pre-activation
        _, act_vjp = jax.vjp(fk.ACTIVATIONS[cfg.activation], z)
        dz = act_vjp(g32)[0]
    else:
        dz = g32
    # The two backward GEMMs, each under its own CMU-planned (dataflow,
    # block, operand layout, strip).  Default layouts are the zero-copy
    # variants: dX streams W as stored via trans_b, dW streams X as stored
    # via trans_a.  A plan that measured the copy-based fallback as faster
    # programs (False, False) and the transpose is materialised explicitly.
    df_dx, blk_dx, tr_dx, st_dx = _bwd_choice(cfg.bwd_dx, M, N, K, (False, True))
    df_dw, blk_dw, tr_dw, st_dw = _bwd_choice(cfg.bwd_dw, K, M, N, (True, False))
    gd = dz.astype(jnp.promote_types(x.dtype, w.dtype))
    dx = _matmul_run(gd, w if tr_dx[1] else w.T, df_dx, blk_dx,
                     cfg.interpret, x.dtype, trans_b=tr_dx[1], strip=st_dx)
    dw = _matmul_run(x if tr_dw[0] else x.T, gd, df_dw, blk_dw,
                     cfg.interpret, w.dtype, trans_a=tr_dw[0], strip=st_dw)
    if b_proto is None:
        db = None
    else:
        db = dz.sum(axis=0, keepdims=b_proto.ndim == 2).astype(b_proto.dtype)
    dr = None if r_proto is None else g.astype(r_proto.dtype)
    return dx, dw, db, dr


_linear_core.defvjp(_linear_fwd, _linear_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "dataflow", "block", "interpret",
                     "out_dtype", "bwd_dx", "bwd_dw", "strip", "qdtype"),
)
def flex_linear(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    activation: str | None = None,
    residual: jax.Array | None = None,
    dataflow: Dataflow = Dataflow.OS,
    block: tuple[int, int, int] = fk.DEFAULT_BLOCK,
    interpret: bool = False,
    out_dtype: jnp.dtype | None = None,
    bwd_dx: BwdSpec | None = None,
    bwd_dw: BwdSpec | None = None,
    strip: int = 1,
    qdtype: str | None = None,
) -> jax.Array:
    """Fused linear layer: ``act(x @ w + b) + residual`` in one kernel pass.

    x (M, K); w (K, N); b (N,) or None; residual (M, N) or None;
    ``activation`` in {relu, gelu, silu, None}.  Bias/activation/residual and
    the output cast all run inside the kernel's final flush while the f32
    accumulator block is resident in VMEM — no extra HBM round-trips.
    Pads/unpads to block multiples (zero padding is epilogue-safe: the padded
    rows/cols are sliced off before any consumer sees them).

    Differentiable end-to-end: under ``jax.grad`` the backward GEMMs
    ``dX = dY @ W^T`` and ``dW = X^T @ dY`` run as flex kernels under
    ``bwd_dx`` / ``bwd_dw`` — ``(Dataflow, (bm, bk, bn), (trans_a,
    trans_b), strip)`` tuples, normally supplied by the CMU train plan — or
    the trace-time roofline argmin when None.  The third element is the
    operand layout: omitted (legacy 2-tuples) or the role's default means
    the zero-copy transposed-operand kernel that streams W/X as stored;
    ``(False, False)`` forces the copy-based fallback that materialises the
    transpose in HBM first.  The fourth element is the accumulator-strip
    depth (omitted = 1, streamed).  ``strip`` plays the same role for the
    forward GEMM.  The activation gradient uses the pre-activation the
    forward kernel saved (see module docstring for the save-vs-recompute
    policy).

    ``qdtype`` ("int8"/"fp8") runs the forward GEMM with the weight
    quantized per output channel, dequant fused into the flush before
    bias/activation/residual/cast.  Forward-only: the VJP saves the
    full-precision weight and both cotangent GEMMs run unquantized
    (straight-through estimator), so training against a quantized serve
    plan needs no extra plumbing.

    Examples (interpret mode, so they run anywhere):

    >>> import jax, jax.numpy as jnp
    >>> from repro.kernels import flex_linear
    >>> x = jnp.ones((8, 16)); w = jnp.full((16, 8), 0.1)
    >>> flex_linear(x, w, activation="relu", interpret=True).shape
    (8, 8)
    >>> dx = jax.grad(lambda x: flex_linear(x, w, interpret=True).sum())(x)
    >>> round(float(dx[0, 0]), 4)   # d/dx sum(x @ w) = sum_N w = 0.8
    0.8
    """
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"inner dims mismatch: {x.shape} @ {w.shape}")
    cfg = _LinearCfg(activation, dataflow, block, interpret, out_dtype,
                     bwd_dx, bwd_dw, strip, qdtype)
    return _linear_core(cfg, x, w, b, residual)


def auto_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    name: str = "",
    interpret: bool = False,
) -> jax.Array:
    """CMU-in-the-loop matmul: picks the dataflow from shapes at trace time.

    Shape-driven and trace-time static — the deployment model of the paper
    (offline selection, zero runtime switching cost).
    """
    shape = GemmShape(M=a.shape[0], K=a.shape[1], N=b.shape[1], name=name)
    df, _ = best_kernel_dataflow(shape)
    return flex_matmul(a, b, dataflow=df, interpret=interpret)
