"""jit'd public wrappers around the flex dataflow kernels.

``flex_linear`` is the op the model stack calls: a full linear layer —
``act(x @ w + b) + residual`` — with the epilogue fused into the Pallas
kernel's final flush, so bias/activation/residual never re-stream the matmul
output through HBM.  It pads to block multiples, dispatches to the
CMU-selected dataflow kernel, and unpads.

``flex_matmul`` is the bare-matmul variant kept for benchmarks and the
paper-claims suite; ``auto_matmul`` adds trace-time CMU dataflow selection.
The model stack falls back to plain XLA einsum when the kernel path is
disabled (CPU dry-runs / compile-only meshes, where XLA must see a fusible
dot for cost_analysis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.dataflow import Dataflow, GemmShape, best_kernel_dataflow

from . import flex_matmul as fk


def _pad_to(x: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def default_interpret() -> bool:
    """Pallas kernels need interpret mode off-TPU (CPU CI, dry-runs)."""
    return jax.default_backend() != "tpu"


def _fit_block(M: int, K: int, N: int, block: tuple[int, int, int]):
    """Shrink each block dim to the padded extent of its GEMM dim — a block
    larger than the (128-aligned) dim just wastes VMEM — while honouring
    CMU-tuned blocks above 128."""

    def fit(d: int, bd: int) -> int:
        return min(bd, _round_up_dim(d))

    bm, bk, bn = block
    return fit(M, bm), fit(K, bk), fit(N, bn)


@functools.partial(
    jax.jit, static_argnames=("dataflow", "block", "interpret", "out_dtype")
)
def flex_matmul(
    a: jax.Array,
    b: jax.Array,
    dataflow: Dataflow = Dataflow.OS,
    block: tuple[int, int, int] = fk.DEFAULT_BLOCK,
    interpret: bool = False,
    out_dtype: jnp.dtype | None = None,
) -> jax.Array:
    """C = A @ B under the given dataflow; pads/unpads to block multiples."""
    M, K = a.shape
    K2, N = b.shape
    if K != K2:
        raise ValueError(f"inner dims mismatch: {a.shape} @ {b.shape}")
    bm, bk, bn = _fit_block(M, K, N, block)
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    out = fk.matmul(ap, bp, dataflow, block=(bm, bk, bn), interpret=interpret)
    out = out[:M, :N]
    return out.astype(out_dtype or jnp.promote_types(a.dtype, b.dtype))


@functools.partial(
    jax.jit,
    static_argnames=("activation", "dataflow", "block", "interpret", "out_dtype"),
)
def flex_linear(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    activation: str | None = None,
    residual: jax.Array | None = None,
    dataflow: Dataflow = Dataflow.OS,
    block: tuple[int, int, int] = fk.DEFAULT_BLOCK,
    interpret: bool = False,
    out_dtype: jnp.dtype | None = None,
) -> jax.Array:
    """Fused linear layer: ``act(x @ w + b) + residual`` in one kernel pass.

    x (M, K); w (K, N); b (N,) or None; residual (M, N) or None;
    ``activation`` in {relu, gelu, silu, None}.  Bias/activation/residual and
    the output cast all run inside the kernel's final flush while the f32
    accumulator block is resident in VMEM — no extra HBM round-trips.
    Pads/unpads to block multiples (zero padding is epilogue-safe: the padded
    rows/cols are sliced off before any consumer sees them).
    """
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"inner dims mismatch: {x.shape} @ {w.shape}")
    bm, bk, bn = _fit_block(M, K, N, block)
    xp = _pad_to(x, bm, bk)
    wp = _pad_to(w, bk, bn)
    bp = None if b is None else _pad_to(b.reshape(1, N), 1, bn)
    rp = None if residual is None else _pad_to(residual, bm, bn)
    odt = out_dtype or jnp.promote_types(x.dtype, w.dtype)
    out = fk.fused_matmul(
        xp, wp, dataflow,
        bias=bp, residual=rp, activation=activation, out_dtype=odt,
        block=(bm, bk, bn), interpret=interpret,
    )
    return out[:M, :N].astype(odt)


def _round_up_dim(d: int, mult: int = 128) -> int:
    """Smallest MXU-aligned extent covering d (min 8 sublanes for tiny dims)."""
    if d >= mult:
        return -(-d // mult) * mult
    r = 8
    while r < d:
        r *= 2
    return r


def auto_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    name: str = "",
    interpret: bool = False,
) -> jax.Array:
    """CMU-in-the-loop matmul: picks the dataflow from shapes at trace time.

    Shape-driven and trace-time static — the deployment model of the paper
    (offline selection, zero runtime switching cost).
    """
    shape = GemmShape(M=a.shape[0], K=a.shape[1], N=b.shape[1], name=name)
    df, _ = best_kernel_dataflow(shape)
    return flex_matmul(a, b, dataflow=df, interpret=interpret)
