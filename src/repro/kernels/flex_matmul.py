"""Pallas TPU matmul kernels with reconfigurable dataflow (IS / OS / WS).

This is the TPU-native port of the Flex-TPU processing element (paper Fig. 3/4):
on a real TPU the programmable "stationarity" lives one level up the memory
hierarchy — which operand's VMEM block stays resident across consecutive grid
steps, determined by the grid loop order and each ``BlockSpec.index_map``:

  OS  grid (i, j, k):  the f32 accumulator block C[i,j] is pinned in VMEM
      scratch across the whole k loop and written to HBM exactly once.
  WS  grid (k, j, i):  the weight block B[k,j] is pinned across the entire
      M stream (its index_map ignores the innermost grid axis); partial sums
      stream through HBM (aliased read-modify-write) — the price WS pays when
      K exceeds one block, exactly as in `core.dataflow.hbm_traffic_bytes`.
  IS  grid (k, i, j):  symmetric — the activation block A[i,k] is pinned,
      weights stream, partials stream.

All three compute bit-identical results (f32 accumulation); they differ only
in HBM traffic and residency, which is the paper's point.  The CMU
(`core.cmu.autotune_plan`) picks per layer offline; dispatch is static at
trace time (the JAX analogue of programming the CMU mux signals).

Every kernel supports a **fused epilogue** — bias add, activation
(relu/gelu/silu), residual add, and output dtype cast — applied inside the
kernel while the f32 accumulator block is still resident in VMEM:

  OS    the epilogue runs in the final-k ``_flush`` branch, so the epilogue
        reads the scratch accumulator and the single HBM write already
        carries the finished (possibly low-precision) result.
  WS/IS the epilogue runs in a last-k-step branch: partial sums stream
        through an f32 staging buffer exactly as in the plain kernel, and at
        the last k step the finished block is written once to a separate
        output buffer in the target dtype.

Fusing the epilogue removes the extra HBM round-trips XLA would otherwise
spend re-streaming the matmul output through bias/activation/residual ops —
the on-chip-results argument of Jouppi et al. (2017) applied at VMEM level.

**Training support (fwd/bwd epilogue contract).**  With ``save_preact`` the
fused kernels additionally emit the f32 pre-activation ``z = a @ b + bias`` —
the residual ``ops.flex_linear``'s custom VJP needs to differentiate the
activation.  WS/IS get this for free: their f32 partial-sum staging buffer
already materialises ``a @ b`` in HBM, so the last-k flush just folds the
bias in and the staging buffer doubles as the saved pre-activation.  OS pays
one extra ``(M, N)`` f32 HBM write from the flush (still far cheaper than
recomputing the forward GEMM in the backward pass).  The backward GEMMs
themselves (``dX = dY @ W^T``, ``dW = X^T @ dY``) are plain flex matmuls
issued by ``ops`` under their own CMU-planned (dataflow, block).

**Transposed operands (trans_a / trans_b).**  Every kernel accepts operands
in transposed physical layout: with ``trans_a`` the first operand is stored
``(K, M)`` and read as A^T, with ``trans_b`` the second is stored ``(N, K)``
and read as B^T.  The transpose lives entirely in the BlockSpec index map
(the block of logical ``A[i, k]`` is fetched from physical ``A[k, i]``) and
the in-kernel ``dot_general`` dimension numbers — **no HBM transpose copy is
ever issued**.  This is what lets the custom-VJP backward GEMMs
``dX = dY @ W^T`` and ``dW = X^T @ dY`` stream W and X exactly as stored:
dX streams W as (N,K)-logical, dW streams X as (K,M)-logical, zero copies.
Stationarity is unchanged — the pinned operand's index map still ignores
the innermost grid axis; only which physical axis maps to which grid index
swaps.

**Block-shape constraints.**  Every kernel requires the *logical* M, K, N to
be exact multiples of (bm, bk, bn); transposed operands are blocked with the
same (bm, bk, bn) applied to their physical axes — a ``trans_a`` operand is
blocked ``(bk, bm)``.  ``ops.flex_matmul`` / ``ops.flex_linear`` pad and
unpad around this.  Blocks should be MXU-aligned (multiples of 128, min 8
sublanes); ``DEFAULT_BLOCK`` is (256, 256, 256).  ``bias`` is (1, N) and
``residual`` (M, N), blocked (1, bn) / (bm, bn).

**Dtype / accumulator policy.**  Inputs may be any float dtype; every MAC
accumulates in f32 (``preferred_element_type=jnp.float32``), partial sums
stream through HBM in f32, the epilogue runs in f32, and only the final
flush casts to ``out_dtype``.  The saved pre-activation is always f32.

Kernels are written for TPU (MXU-aligned blocks, VMEM scratch) and validated
on CPU with ``interpret=True`` against ``ref.matmul_ref`` / ``ref.linear_ref``.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.dataflow import Dataflow

DEFAULT_BLOCK = (256, 256, 256)  # (bm, bk, bn) — MXU-aligned, ~768KB working set

# jax 0.4.x names these TPUCompilerParams / VMEM; newer releases renamed them
# to CompilerParams / MemorySpace.VMEM.  Resolve whichever exists once.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
_VMEM = getattr(getattr(pltpu, "MemorySpace", None), "VMEM", None) or pltpu.VMEM


# ---------------------------------------------------------------------------
# Fused epilogue
# ---------------------------------------------------------------------------

ACTIVATIONS: dict[str, Callable[[jax.Array], jax.Array]] = {
    "relu": lambda y: jnp.maximum(y, 0.0),
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def _epilogue(acc, bias_ref, res_ref, activation: str | None):
    """bias -> activation -> residual, all on the resident f32 block.

    Returns ``(z, y)``: the pre-activation ``z = acc + bias`` (what the
    custom VJP saves to differentiate the activation) and the finished
    ``y = act(z) + residual``.
    """
    z = acc
    if bias_ref is not None:
        z = z + bias_ref[...].astype(jnp.float32)
    y = ACTIVATIONS[activation](z) if activation is not None else z
    if res_ref is not None:
        y = y + res_ref[...].astype(jnp.float32)
    return z, y


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------


def _block_dot(a, b, trans_a: bool, trans_b: bool):
    """One MAC on (possibly transposed-layout) operand blocks.

    The transpose is expressed purely in the contraction dimension numbers —
    a ``trans_a`` block is physically (bk, bm) and contracts axis 0, a
    ``trans_b`` block is (bn, bk) and contracts axis 1 — so the MXU consumes
    the block as stored and no relayout ever materialises.
    """
    dims = (((0 if trans_a else 1,), (1 if trans_b else 0,)), ((), ()))
    return jax.lax.dot_general(a, b, dims, preferred_element_type=jnp.float32)


def _os_kernel(*refs, activation: str | None, has_bias: bool, has_res: bool,
               save_preact: bool = False, trans_a: bool = False,
               trans_b: bool = False):
    """Output-stationary: accumulate in VMEM scratch across the k grid axis.

    The fused epilogue runs in the ``_flush`` branch — the accumulator block
    is still in VMEM, so bias/activation/residual cost zero extra HBM trips.
    With ``save_preact`` the flush also writes the f32 pre-activation block
    to a second output (the VJP's saved residual) — one extra HBM write.
    """
    it = iter(refs)
    a_ref, b_ref = next(it), next(it)
    bias_ref = next(it) if has_bias else None
    res_ref = next(it) if has_res else None
    o_ref = next(it)
    z_ref = next(it) if save_preact else None
    acc_ref = next(it)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _block_dot(a_ref[...], b_ref[...], trans_a, trans_b)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        z, y = _epilogue(acc_ref[...], bias_ref, res_ref, activation)
        if save_preact:
            z_ref[...] = z
        o_ref[...] = y.astype(o_ref.dtype)


def _stream_accum_kernel(*refs, activation: str | None, has_bias: bool,
                         has_res: bool, fused: bool, save_preact: bool = False,
                         trans_a: bool = False, trans_b: bool = False):
    """WS/IS shared body: one MAC into the HBM-streamed partial-sum block.

    The output block is revisited non-consecutively across the outer k axis,
    so partial sums stream through HBM (read-modify-write) — the structural
    price WS/IS pay when K exceeds one block, matching
    ``core.dataflow.hbm_traffic_bytes``.  The stationarity difference between
    WS and IS is entirely in the grid order and index_maps of the surrounding
    pallas_call (whose pinned operand ignores the innermost axis), not in the
    MAC itself — mirroring the paper's PE, where the same MAC hardware serves
    all three dataflows and only the mux selection changes.

    With ``fused`` the last-k-step branch applies the epilogue to the fully
    accumulated f32 partial block and writes the finished result once to a
    separate output buffer in the target dtype (partials must stay f32, so
    the low-precision final cast needs its own buffer).

    With ``save_preact`` the flush also folds the bias into the staging
    buffer, so after the kernel it holds the f32 pre-activation ``z`` — the
    VJP's saved residual at zero extra HBM cost (the buffer was being
    written every k step anyway).
    """
    it = iter(refs)
    a_ref, b_ref = next(it), next(it)
    bias_ref = next(it) if has_bias else None
    res_ref = next(it) if has_res else None
    part_ref = next(it)
    out_ref = next(it) if fused else None
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        part_ref[...] = jnp.zeros_like(part_ref)

    part_ref[...] += _block_dot(
        a_ref[...], b_ref[...], trans_a, trans_b
    ).astype(part_ref.dtype)

    if fused:

        @pl.when(k == pl.num_programs(0) - 1)
        def _flush():
            z, y = _epilogue(part_ref[...], bias_ref, res_ref, activation)
            if save_preact:
                part_ref[...] = z
            out_ref[...] = y.astype(out_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call builders (one per dataflow)
# ---------------------------------------------------------------------------


def _check(M: int, K: int, N: int, bm: int, bk: int, bn: int) -> None:
    if M % bm or K % bk or N % bn:
        raise ValueError(
            f"matmul dims ({M},{K},{N}) must divide blocks ({bm},{bk},{bn}); "
            "use ops.flex_matmul / ops.flex_linear which pad"
        )


def _logical_dims(a, b, trans_a: bool, trans_b: bool) -> tuple[int, int, int]:
    """(M, K, N) of ``op(a) @ op(b)`` given the physical operand layouts."""
    M, K = a.shape[::-1] if trans_a else a.shape
    K2, N = b.shape[::-1] if trans_b else b.shape
    if K != K2:
        raise ValueError(
            f"inner dims mismatch: {a.shape} @ {b.shape} "
            f"(trans_a={trans_a}, trans_b={trans_b})"
        )
    return M, K, N


def _operand_specs(bm, bk, bn, a_map, b_map, trans_a: bool, trans_b: bool):
    """BlockSpecs for A and B given *logical* index maps ``a_map`` (grid ids
    -> (i, k) block coords) and ``b_map`` (-> (k, j)).  A transposed operand
    gets the same logical map with its output pair swapped — the transpose
    lives in the index map, never in HBM."""
    if trans_a:
        a_spec = pl.BlockSpec((bk, bm), lambda *ids: a_map(*ids)[::-1])
    else:
        a_spec = pl.BlockSpec((bm, bk), a_map)
    if trans_b:
        b_spec = pl.BlockSpec((bn, bk), lambda *ids: b_map(*ids)[::-1])
    else:
        b_spec = pl.BlockSpec((bk, bn), b_map)
    return a_spec, b_spec


def _epilogue_inputs(bias, res, bias_map, out_map, bm, bn):
    """Extra (arrays, specs) for whichever epilogue operands are present."""
    arrays, specs = [], []
    if bias is not None:
        arrays.append(bias)
        specs.append(pl.BlockSpec((1, bn), bias_map))
    if res is not None:
        arrays.append(res)
        specs.append(pl.BlockSpec((bm, bn), out_map))
    return arrays, specs


def matmul_os(
    a: jax.Array,
    b: jax.Array,
    *,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    activation: str | None = None,
    out_dtype: jnp.dtype | None = None,
    block: tuple[int, int, int] = DEFAULT_BLOCK,
    interpret: bool = False,
    save_preact: bool = False,
    trans_a: bool = False,
    trans_b: bool = False,
) -> jax.Array | tuple[jax.Array, jax.Array]:
    M, K, N = _logical_dims(a, b, trans_a, trans_b)
    bm, bk, bn = block
    _check(M, K, N, bm, bk, bn)
    grid = (M // bm, N // bn, K // bk)
    out_map = lambda i, j, k: (i, j)
    extra, extra_specs = _epilogue_inputs(
        bias, residual, lambda i, j, k: (0, j), out_map, bm, bn
    )
    a_spec, b_spec = _operand_specs(
        bm, bk, bn, lambda i, j, k: (i, k), lambda i, j, k: (k, j),
        trans_a, trans_b,
    )
    kern = functools.partial(
        _os_kernel, activation=activation,
        has_bias=bias is not None, has_res=residual is not None,
        save_preact=save_preact, trans_a=trans_a, trans_b=trans_b,
    )
    out_specs = pl.BlockSpec((bm, bn), out_map)
    out_shape = jax.ShapeDtypeStruct((M, N), out_dtype or jnp.float32)
    if save_preact:
        out_specs = [out_specs, pl.BlockSpec((bm, bn), out_map)]
        out_shape = [out_shape, jax.ShapeDtypeStruct((M, N), jnp.float32)]
    result = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[a_spec, b_spec, *extra_specs],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[_VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a, b, *extra)
    return (result[0], result[1]) if save_preact else result


def _matmul_stream(
    a: jax.Array,
    b: jax.Array,
    *,
    stationary: str,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    activation: str | None = None,
    out_dtype: jnp.dtype | None = None,
    block: tuple[int, int, int],
    interpret: bool,
    save_preact: bool = False,
    trans_a: bool = False,
    trans_b: bool = False,
) -> jax.Array | tuple[jax.Array, jax.Array]:
    """Shared WS/IS driver: aliased partial-sum accumulation over outer k."""
    M, K, N = _logical_dims(a, b, trans_a, trans_b)
    bm, bk, bn = block
    _check(M, K, N, bm, bk, bn)
    if stationary == "weight":
        # WS: grid (k, j, i) — B[k,j] constant across innermost i (pinned;
        # with trans_b the pinned physical block is B[j,k], still ignoring i).
        grid = (K // bk, N // bn, M // bm)
        a_map = lambda k, j, i: (i, k)
        b_map = lambda k, j, i: (k, j)
        c_map = lambda k, j, i: (i, j)
        bias_map = lambda k, j, i: (0, j)
    elif stationary == "input":
        # IS: grid (k, i, j) — A[i,k] constant across innermost j (pinned).
        grid = (K // bk, M // bm, N // bn)
        a_map = lambda k, i, j: (i, k)
        b_map = lambda k, i, j: (k, j)
        c_map = lambda k, i, j: (i, j)
        bias_map = lambda k, i, j: (0, j)
    else:  # pragma: no cover
        raise ValueError(stationary)
    a_spec, b_spec = _operand_specs(bm, bk, bn, a_map, b_map, trans_a, trans_b)
    fused = (
        save_preact
        or bias is not None or residual is not None or activation is not None
        or (out_dtype is not None and jnp.dtype(out_dtype) != jnp.float32)
    )
    # The residual is only read in the last-k flush, but its natural (i, j)
    # index map changes every inner step while k is outermost — that would
    # re-stream the whole residual K//bk times.  Pin it to block (0, 0)
    # until the final k step so it is fetched exactly once overall.
    nk = K // bk
    last = nk - 1

    def res_map(*ids):
        bi, bj = c_map(*ids)
        on_last = ids[0] == last
        return (jax.lax.select(on_last, bi, 0), jax.lax.select(on_last, bj, 0))

    extra, extra_specs = _epilogue_inputs(bias, residual, bias_map, res_map, bm, bn)
    kern = functools.partial(
        _stream_accum_kernel, activation=activation,
        has_bias=bias is not None, has_res=residual is not None, fused=fused,
        save_preact=save_preact, trans_a=trans_a, trans_b=trans_b,
    )
    out_specs = pl.BlockSpec((bm, bn), c_map)
    out_shape = jax.ShapeDtypeStruct((M, N), jnp.float32)
    if fused:
        # f32 partial staging buffer + finished output in the target dtype
        out_specs = [out_specs, pl.BlockSpec((bm, bn), c_map)]
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((M, N), out_dtype or jnp.float32)]
    result = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[a_spec, b_spec, *extra_specs],
        out_specs=out_specs,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")
        ),
        interpret=interpret,
    )(a, b, *extra)
    if save_preact:
        return result[1], result[0]  # (finished out, staged pre-activation)
    return result[1] if fused else result


def matmul_ws(a, b, *, block=DEFAULT_BLOCK, interpret=False, **epilogue):
    return _matmul_stream(a, b, stationary="weight", block=block,
                          interpret=interpret, **epilogue)


def matmul_is(a, b, *, block=DEFAULT_BLOCK, interpret=False, **epilogue):
    return _matmul_stream(a, b, stationary="input", block=block,
                          interpret=interpret, **epilogue)


KERNELS = {
    Dataflow.OS: matmul_os,
    Dataflow.WS: matmul_ws,
    Dataflow.IS: matmul_is,
}


def matmul(
    a: jax.Array,
    b: jax.Array,
    dataflow: Dataflow = Dataflow.OS,
    *,
    block: tuple[int, int, int] = DEFAULT_BLOCK,
    interpret: bool = False,
    trans_a: bool = False,
    trans_b: bool = False,
) -> jax.Array:
    """Flex matmul: same math, dataflow-selected block schedule.

    ``trans_a`` / ``trans_b`` read the operands in transposed physical
    layout via the index maps — ``op(a) @ op(b)`` with zero HBM copies.
    """
    return KERNELS[dataflow](a, b, block=block, interpret=interpret,
                             trans_a=trans_a, trans_b=trans_b)


def fused_matmul(
    a: jax.Array,
    b: jax.Array,
    dataflow: Dataflow = Dataflow.OS,
    *,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    activation: str | None = None,
    out_dtype: jnp.dtype | None = None,
    block: tuple[int, int, int] = DEFAULT_BLOCK,
    interpret: bool = False,
    save_preact: bool = False,
    trans_a: bool = False,
    trans_b: bool = False,
) -> jax.Array | tuple[jax.Array, jax.Array]:
    """Matmul with the epilogue fused into the kernel's final flush.

    ``bias`` must be (1, N); ``residual`` (M, N); all dims block multiples
    (ops.flex_linear pads).  ``activation`` in {relu, gelu, silu, None}.
    With ``save_preact`` returns ``(out, z)`` where ``z`` is the f32
    pre-activation ``a @ b + bias`` — what the custom VJP saves.
    ``trans_a`` / ``trans_b`` read transposed-layout operands in place.
    """
    if activation is not None and activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    return KERNELS[dataflow](
        a, b, bias=bias, residual=residual, activation=activation,
        out_dtype=out_dtype, block=block, interpret=interpret,
        save_preact=save_preact, trans_a=trans_a, trans_b=trans_b,
    )
