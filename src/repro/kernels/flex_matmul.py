"""Pallas TPU matmul kernels with reconfigurable dataflow (IS / OS / WS).

This is the TPU-native port of the Flex-TPU processing element (paper Fig. 3/4):
on a real TPU the programmable "stationarity" lives one level up the memory
hierarchy — which operand's VMEM block stays resident across consecutive grid
steps, determined by the grid loop order and each ``BlockSpec.index_map``:

  OS  grid (i, j, k):  the f32 accumulator block C[i,j] is pinned in VMEM
      scratch across the whole k loop and written to HBM exactly once.
  WS  grid (k, j, i):  the weight block B[k,j] is pinned across the entire
      M stream (its index_map ignores the innermost grid axis); partial sums
      stream through HBM (aliased read-modify-write) — the price WS pays when
      K exceeds one block, exactly as in `core.dataflow.hbm_traffic_bytes`.
  IS  grid (k, i, j):  symmetric — the activation block A[i,k] is pinned,
      weights stream, partials stream.

**Two-level stationarity (``strip`` >= 2).**  The streamed WS/IS schedules
above pay a cost the paper's hardware never would: every k step round-trips
the f32 output block through HBM.  With ``strip=ns`` the WS/IS kernels
instead pin a *strip* of ``ns`` output accumulator blocks in VMEM and
reorder the grid so each strip's k-revisits are consecutive:

  WS  grid (s, j, k, u), i = s*ns + u:  level 1 — the weight block B[k,j]
      stays pinned across the strip's inner M sweep (its index map ignores
      ``u``, exactly as the streamed schedule ignores ``i``); level 2 — the
      f32 accumulator strip stays pinned in VMEM across the whole k loop.
      Partial sums never touch HBM; each output block is written exactly
      once, like OS.  The price: B is re-fetched once per strip
      (``ceil(Mb/ns)`` times) instead of once.
  IS  grid (s, i, k, u), j = s*ns + u:  symmetric — the activation block
      A[i,k] is level-1 pinned across the strip's inner N sweep, the strip
      tiles N, and A is re-fetched once per strip.

``strip=1`` is exactly the streamed schedule.  OS takes no strip: its
accumulator is already VMEM-resident, and widening it to ``ns`` blocks
*is* the IS strip schedule (the search space already contains it).  The
strip grids' ``(s, j)`` / ``(s, i)`` axes are single-writer, so they are
declared ``"parallel"`` in ``dimension_semantics`` and megacore
partitioning can engage; the streamed grids stay all-``"arbitrary"``
(their output blocks are multi-writer across k).

All schedules compute bit-identical results (f32 accumulation in the same
k order); they differ only in HBM traffic and residency, which is the
paper's point.  The CMU (`core.cmu.autotune_plan`) picks the per-layer
``(dataflow, block, strip)`` offline; dispatch is static at trace time
(the JAX analogue of programming the CMU mux signals).
``schedule_cost_bytes`` walks the exact grids and index maps the builders
emit and counts HBM bytes under Pallas revisiting semantics — the guard
that keeps `core.dataflow.hbm_traffic_bytes` honest about what the
kernels actually do.

Every kernel supports a **fused epilogue** — bias add, activation
(relu/gelu/silu), residual add, and output dtype cast — applied inside the
kernel while the f32 accumulator block is still resident in VMEM:

  OS    the epilogue runs in the final-k ``_flush`` branch, so the epilogue
        reads the scratch accumulator and the single HBM write already
        carries the finished (possibly low-precision) result.
  WS/IS **strip >= 2**: the full epilogue (including the residual, fetched
        honestly once per strip — its index map ignores the k and u axes)
        runs off the VMEM-resident accumulator strip at flush.
  WS/IS **strip = 1** (streamed): bias/activation/cast run in a last-k-step
        branch off the f32 staging buffer; the *residual* add runs as one
        XLA op on the kernel's f32 output (same f32 op order, so results
        are bit-identical to the fused form).  An in-kernel residual fetch
        under the streamed grid would either re-stream the whole residual
        ``K/bk`` times or need an index-map workaround — the strip schedule
        is the honest fix, so the streamed path no longer fuses it.

Fusing the epilogue removes the extra HBM round-trips XLA would otherwise
spend re-streaming the matmul output through bias/activation/residual ops —
the on-chip-results argument of Jouppi et al. (2017) applied at VMEM level.

**Training support (fwd/bwd epilogue contract).**  With ``save_preact`` the
fused kernels additionally emit the f32 pre-activation ``z = a @ b + bias`` —
the residual ``ops.flex_linear``'s custom VJP needs to differentiate the
activation.  Streamed WS/IS get this for free: their f32 partial-sum staging
buffer already materialises ``a @ b`` in HBM, so the last-k flush just folds
the bias in and the staging buffer doubles as the saved pre-activation.
Strip WS/IS and OS pay one extra ``(M, N)`` f32 write from the flush — a
single clean write off the VMEM-resident accumulator, still far cheaper
than recomputing the forward GEMM in the backward pass.  The backward GEMMs
themselves (``dX = dY @ W^T``, ``dW = X^T @ dY``) are plain flex matmuls
issued by ``ops`` under their own CMU-planned (dataflow, block).

**Transposed operands (trans_a / trans_b).**  Every kernel accepts operands
in transposed physical layout: with ``trans_a`` the first operand is stored
``(K, M)`` and read as A^T, with ``trans_b`` the second is stored ``(N, K)``
and read as B^T.  The transpose lives entirely in the BlockSpec index map
(the block of logical ``A[i, k]`` is fetched from physical ``A[k, i]``) and
the in-kernel ``dot_general`` dimension numbers — **no HBM transpose copy is
ever issued**.  This is what lets the custom-VJP backward GEMMs
``dX = dY @ W^T`` and ``dW = X^T @ dY`` stream W and X exactly as stored:
dX streams W as (N,K)-logical, dW streams X as (K,M)-logical, zero copies.
Stationarity is unchanged — the pinned operand's index map still ignores
the innermost grid axis; only which physical axis maps to which grid index
swaps.

**Block-shape constraints.**  Every kernel requires the *logical* M, K, N to
be exact multiples of (bm, bk, bn); transposed operands are blocked with the
same (bm, bk, bn) applied to their physical axes — a ``trans_a`` operand is
blocked ``(bk, bm)``.  ``ops.flex_matmul`` / ``ops.flex_linear`` pad and
unpad around this.  Blocks should be MXU-aligned (multiples of 128, min 8
sublanes); ``DEFAULT_BLOCK`` is (256, 256, 256).  ``bias`` is (1, N) and
``residual`` (M, N), blocked (1, bn) / (bm, bn).

**Dtype / accumulator policy.**  Inputs may be any float dtype; every MAC
accumulates in f32 (``preferred_element_type=jnp.float32``), partial sums
stream through HBM in f32, the epilogue runs in f32, and only the final
flush casts to ``out_dtype``.  The saved pre-activation is always f32.

**Quantized operands (``qscale``).**  ``fused_matmul`` accepts a B operand
stored int8 or fp8(e4m3) with a per-output-channel f32 scale row
``qscale`` of shape (1, N), streamed alongside B with the bias's block
spec (one ``(1, bn)`` row per resident B block — epilogue-operand traffic,
like bias).  The MAC upcasts the quantized block to f32 (exact for int8
and e4m3 lattice points) and accumulates in f32 as always; because the
per-output-channel scale is constant across k, dequantization commutes
with the k-accumulation and runs **once at the flush epilogue**, before
everything else:

    dequant -> bias -> activation -> residual -> cast

so the existing epilogue contract — and the bit-exactness tests pinned on
it — compose unchanged.  The streamed WS/IS schedules force the fused
flush when ``qscale`` is present (the raw f32 staging buffer holds
*scaled-lattice* partial sums, which must not escape undequantized); the
saved pre-activation ``z`` is the dequantized ``a @ dequant(b) + bias``.

Kernels are written for TPU (MXU-aligned blocks, VMEM scratch) and validated
on CPU with ``interpret=True`` against ``ref.matmul_ref`` / ``ref.linear_ref``.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.dataflow import Dataflow

DEFAULT_BLOCK = (256, 256, 256)  # (bm, bk, bn) — MXU-aligned, ~768KB working set

# jax 0.4.x names these TPUCompilerParams / VMEM; newer releases renamed them
# to CompilerParams / MemorySpace.VMEM.  Resolve whichever exists once.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
_VMEM = getattr(getattr(pltpu, "MemorySpace", None), "VMEM", None) or pltpu.VMEM


# ---------------------------------------------------------------------------
# Fused epilogue
# ---------------------------------------------------------------------------

ACTIVATIONS: dict[str, Callable[[jax.Array], jax.Array]] = {
    "relu": lambda y: jnp.maximum(y, 0.0),
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def _epilogue(acc, bias, res, activation: str | None):
    """bias -> activation -> residual, all on the resident f32 block.

    Takes *values* (already-sliced blocks), not refs, so the strip kernels
    can feed per-``u`` slices of their strip-wide bias/residual buffers.
    Returns ``(z, y)``: the pre-activation ``z = acc + bias`` (what the
    custom VJP saves to differentiate the activation) and the finished
    ``y = act(z) + residual``.
    """
    z = acc
    if bias is not None:
        z = z + bias.astype(jnp.float32)
    y = ACTIVATIONS[activation](z) if activation is not None else z
    if res is not None:
        y = y + res.astype(jnp.float32)
    return z, y


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------


def _block_dot(a, b, trans_a: bool, trans_b: bool):
    """One MAC on (possibly transposed-layout) operand blocks.

    The transpose is expressed purely in the contraction dimension numbers —
    a ``trans_a`` block is physically (bk, bm) and contracts axis 0, a
    ``trans_b`` block is (bn, bk) and contracts axis 1 — so the MXU consumes
    the block as stored and no relayout ever materialises.
    """
    dims = (((0 if trans_a else 1,), (1 if trans_b else 0,)), ((), ()))
    if a.dtype != b.dtype:
        # quantized path: B arrives int8/fp8 while A is a float dtype.
        # dot_general requires matching operand dtypes, so upcast both to the
        # f32 the MAC accumulates in anyway — exact for int8/e4m3 values.
        a, b = a.astype(jnp.float32), b.astype(jnp.float32)
    return jax.lax.dot_general(a, b, dims, preferred_element_type=jnp.float32)


def _os_kernel(*refs, activation: str | None, has_scale: bool = False,
               has_bias: bool, has_res: bool,
               save_preact: bool = False, trans_a: bool = False,
               trans_b: bool = False):
    """Output-stationary: accumulate in VMEM scratch across the k grid axis.

    The fused epilogue runs in the ``_flush`` branch — the accumulator block
    is still in VMEM, so bias/activation/residual cost zero extra HBM trips.
    With ``has_scale`` the flush first dequantizes the resident accumulator
    (``acc * qscale``, per output channel — exact, since the scale is
    constant across k) before the rest of the epilogue.
    With ``save_preact`` the flush also writes the f32 pre-activation block
    to a second output (the VJP's saved residual) — one extra HBM write.
    """
    it = iter(refs)
    a_ref, b_ref = next(it), next(it)
    scale_ref = next(it) if has_scale else None
    bias_ref = next(it) if has_bias else None
    res_ref = next(it) if has_res else None
    o_ref = next(it)
    z_ref = next(it) if save_preact else None
    acc_ref = next(it)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += _block_dot(a_ref[...], b_ref[...], trans_a, trans_b)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        acc = acc_ref[...]
        if scale_ref is not None:
            acc = acc * scale_ref[...].astype(jnp.float32)
        z, y = _epilogue(
            acc,
            None if bias_ref is None else bias_ref[...],
            None if res_ref is None else res_ref[...],
            activation,
        )
        if save_preact:
            z_ref[...] = z
        o_ref[...] = y.astype(o_ref.dtype)


def _stream_accum_kernel(*refs, activation: str | None,
                         has_scale: bool = False, has_bias: bool,
                         fused: bool, save_preact: bool = False,
                         trans_a: bool = False, trans_b: bool = False):
    """WS/IS streamed (strip=1) body: one MAC into the HBM-streamed
    partial-sum block.

    The output block is revisited non-consecutively across the outer k axis,
    so partial sums stream through HBM (read-modify-write) — the structural
    price WS/IS pay when K exceeds one block, matching
    ``core.dataflow.hbm_traffic_bytes``.  The stationarity difference between
    WS and IS is entirely in the grid order and index_maps of the surrounding
    pallas_call (whose pinned operand ignores the innermost axis), not in the
    MAC itself — mirroring the paper's PE, where the same MAC hardware serves
    all three dataflows and only the mux selection changes.

    With ``fused`` the last-k-step branch applies bias/activation to the
    fully accumulated f32 partial block and writes the finished result once
    to a separate output buffer in the target dtype (partials must stay f32,
    so the low-precision final cast needs its own buffer).  The residual is
    *not* fused here — under the streamed grid its honest fetch would
    re-stream it every k plane, so ``_matmul_stream`` adds it outside the
    kernel in the same f32 op order; the strip kernels fuse it honestly.

    With ``save_preact`` the flush also folds the bias into the staging
    buffer, so after the kernel it holds the f32 pre-activation ``z`` — the
    VJP's saved residual at zero extra HBM cost (the buffer was being
    written every k step anyway).

    With ``has_scale`` the staging buffer accumulates scaled-lattice
    partial sums and the flush dequantizes before the epilogue — the driver
    forces ``fused`` on so the raw buffer never escapes undequantized.
    """
    it = iter(refs)
    a_ref, b_ref = next(it), next(it)
    scale_ref = next(it) if has_scale else None
    bias_ref = next(it) if has_bias else None
    part_ref = next(it)
    out_ref = next(it) if fused else None
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        part_ref[...] = jnp.zeros_like(part_ref)

    part_ref[...] += _block_dot(
        a_ref[...], b_ref[...], trans_a, trans_b
    ).astype(part_ref.dtype)

    if fused:

        @pl.when(k == pl.num_programs(0) - 1)
        def _flush():
            acc = part_ref[...]
            if scale_ref is not None:
                acc = acc * scale_ref[...].astype(jnp.float32)
            z, y = _epilogue(
                acc,
                None if bias_ref is None else bias_ref[...],
                None,
                activation,
            )
            if save_preact:
                part_ref[...] = z
            out_ref[...] = y.astype(out_ref.dtype)


def _strip_kernel(*refs, activation: str | None, has_scale: bool = False,
                  has_bias: bool, has_res: bool,
                  fused: bool, save_preact: bool, trans_a: bool, trans_b: bool,
                  ns: int, row_strip: bool):
    """WS/IS two-level body: one MAC into the VMEM-resident accumulator strip.

    The strip holds ``ns`` f32 output blocks — ``(ns*bm, bn)`` when the
    strip tiles M (WS), ``(bm, ns*bn)`` when it tiles N (IS).  Grid step
    ``(s, ·, k, u)`` MACs into the strip's ``u``-th slice; because the
    surrounding grid makes each strip's k-revisits consecutive, the strip
    buffer persists in VMEM across the whole k loop and partial sums never
    touch HBM.  The level-1 stationary operand (B for WS, A for IS) is
    pinned across the inner ``u`` sweep exactly as the streamed kernel pins
    it across its innermost axis.

    The flush at the last k step runs the **full** epilogue — including the
    residual, whose strip-wide block was fetched once per strip — and
    writes each finished block exactly once.  With ``save_preact`` the
    accumulator strip *is* the ``z`` output buffer (the bias folds in at
    flush), so the saved pre-activation costs one clean f32 write, never a
    partial-sum stream.
    """
    it = iter(refs)
    a_ref, b_ref = next(it), next(it)
    scale_ref = next(it) if has_scale else None
    bias_ref = next(it) if has_bias else None
    res_ref = next(it) if has_res else None
    o_ref = next(it)
    z_ref = next(it) if save_preact else None
    scratch_ref = next(it) if fused and not save_preact else None
    # accumulate into the z output when saving the pre-activation (it is the
    # staging buffer), else scratch (fused cast needs f32), else o_ref (f32)
    acc = z_ref if save_preact else (scratch_ref if fused else o_ref)
    k = pl.program_id(2)
    u = pl.program_id(3)
    if row_strip:  # strip tiles M: slice rows of the (ns*bm, bn) buffers
        bm = a_ref.shape[1] if trans_a else a_ref.shape[0]
        sl = (pl.ds(u * bm, bm), slice(None))
        blk_shape = (bm, acc.shape[1])
    else:  # strip tiles N: slice cols of the (bm, ns*bn) buffers
        bn = b_ref.shape[0] if trans_b else b_ref.shape[1]
        sl = (slice(None), pl.ds(u * bn, bn))
        blk_shape = (acc.shape[0], bn)

    @pl.when(k == 0)
    def _init():
        acc[sl] = jnp.zeros(blk_shape, acc.dtype)

    acc[sl] += _block_dot(a_ref[...], b_ref[...], trans_a, trans_b)

    if fused:

        @pl.when(k == pl.num_programs(2) - 1)
        def _flush():
            if bias_ref is None:
                bias = None
            else:  # WS bias block is (1, bn); IS carries (1, ns*bn), sliced
                bias = bias_ref[...] if row_strip else bias_ref[sl]
            blk = acc[sl]
            if scale_ref is not None:  # same layout as bias: dequant first
                scale = scale_ref[...] if row_strip else scale_ref[sl]
                blk = blk * scale.astype(jnp.float32)
            z, y = _epilogue(
                blk, bias,
                None if res_ref is None else res_ref[sl], activation,
            )
            if save_preact:
                z_ref[sl] = z
            o_ref[sl] = y.astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call builders (one per dataflow)
# ---------------------------------------------------------------------------


def _check(M: int, K: int, N: int, bm: int, bk: int, bn: int) -> None:
    if M % bm or K % bk or N % bn:
        raise ValueError(
            f"matmul dims ({M},{K},{N}) must divide blocks ({bm},{bk},{bn}); "
            "use ops.flex_matmul / ops.flex_linear which pad"
        )


def _logical_dims(a, b, trans_a: bool, trans_b: bool) -> tuple[int, int, int]:
    """(M, K, N) of ``op(a) @ op(b)`` given the physical operand layouts."""
    M, K = a.shape[::-1] if trans_a else a.shape
    K2, N = b.shape[::-1] if trans_b else b.shape
    if K != K2:
        raise ValueError(
            f"inner dims mismatch: {a.shape} @ {b.shape} "
            f"(trans_a={trans_a}, trans_b={trans_b})"
        )
    return M, K, N


def _operand_specs(bm, bk, bn, a_map, b_map, trans_a: bool, trans_b: bool):
    """BlockSpecs for A and B given *logical* index maps ``a_map`` (grid ids
    -> (i, k) block coords) and ``b_map`` (-> (k, j)).  A transposed operand
    gets the same logical map with its output pair swapped — the transpose
    lives in the index map, never in HBM."""
    if trans_a:
        a_spec = pl.BlockSpec((bk, bm), lambda *ids: a_map(*ids)[::-1])
    else:
        a_spec = pl.BlockSpec((bm, bk), a_map)
    if trans_b:
        b_spec = pl.BlockSpec((bn, bk), lambda *ids: b_map(*ids)[::-1])
    else:
        b_spec = pl.BlockSpec((bk, bn), b_map)
    return a_spec, b_spec


def _epilogue_inputs(qscale, bias, res, bias_map, out_map, bm, bn):
    """Extra (arrays, specs) for whichever epilogue operands are present.
    The quant scale row shares the bias's (1, bn) layout and index map."""
    arrays, specs = [], []
    if qscale is not None:
        arrays.append(qscale)
        specs.append(pl.BlockSpec((1, bn), bias_map))
    if bias is not None:
        arrays.append(bias)
        specs.append(pl.BlockSpec((1, bn), bias_map))
    if res is not None:
        arrays.append(res)
        specs.append(pl.BlockSpec((bm, bn), out_map))
    return arrays, specs


# ---------------------------------------------------------------------------
# Schedules: the (grid, index-map) tuples that *are* the dataflows.  Shared
# by the pallas_call builders and by ``schedule_cost_bytes``, so the traffic
# the cost model claims is counted off the very maps the kernels run.
# ---------------------------------------------------------------------------


def _os_schedule(mb: int, kb: int, nb: int):
    """OS grid (i, j, k): accumulator block pinned across the inner k loop."""
    grid = (mb, nb, kb)
    a_map = lambda i, j, k: (i, k)
    b_map = lambda i, j, k: (k, j)
    out_map = lambda i, j, k: (i, j)
    bias_map = lambda i, j, k: (0, j)
    return grid, a_map, b_map, out_map, bias_map


def _stream_schedule(stationary: str, mb: int, kb: int, nb: int):
    """Streamed (strip=1) WS/IS grids: k outermost, partials through HBM.
    The pinned operand's index map ignores the innermost grid axis."""
    if stationary == "weight":
        grid = (kb, nb, mb)  # WS: B[k,j] pinned across the inner M stream
        a_map = lambda k, j, i: (i, k)
        b_map = lambda k, j, i: (k, j)
        out_map = lambda k, j, i: (i, j)
        bias_map = lambda k, j, i: (0, j)
    elif stationary == "input":
        grid = (kb, mb, nb)  # IS: A[i,k] pinned across the inner N stream
        a_map = lambda k, i, j: (i, k)
        b_map = lambda k, i, j: (k, j)
        out_map = lambda k, i, j: (i, j)
        bias_map = lambda k, i, j: (0, j)
    else:  # pragma: no cover
        raise ValueError(stationary)
    return grid, a_map, b_map, out_map, bias_map


def _strip_schedule(stationary: str, mb: int, kb: int, nb: int, ns: int):
    """Two-level WS/IS grids (s, ·, k, u): the accumulator strip's k-revisits
    are consecutive (strip pinned in VMEM, level 2) while the stationary
    operand's map ignores the innermost u axis (pinned across the strip's
    inner sweep, level 1).  ``out_map`` is in strip-block coordinates —
    the output block is ``(ns*bm, bn)`` for WS, ``(bm, ns*bn)`` for IS —
    and ignores both k and u, so each strip is copied out exactly once."""
    if stationary == "weight":
        grid = (mb // ns, nb, kb, ns)  # i = s*ns + u
        a_map = lambda s, j, k, u: (s * ns + u, k)
        b_map = lambda s, j, k, u: (k, j)
        out_map = lambda s, j, k, u: (s, j)
        bias_map = lambda s, j, k, u: (0, j)  # block (1, bn)
    elif stationary == "input":
        grid = (nb // ns, mb, kb, ns)  # j = s*ns + u
        a_map = lambda s, i, k, u: (i, k)
        b_map = lambda s, i, k, u: (k, s * ns + u)
        out_map = lambda s, i, k, u: (i, s)
        bias_map = lambda s, i, k, u: (0, s)  # block (1, ns*bn)
    else:  # pragma: no cover
        raise ValueError(stationary)
    return grid, a_map, b_map, out_map, bias_map


def schedule_cost_bytes(
    dataflow: Dataflow,
    M: int,
    K: int,
    N: int,
    block: tuple[int, int, int],
    strip: int = 1,
    in_bytes: int = 4,
    out_bytes: int = 4,
    *,
    a_bytes: int | None = None,
    b_bytes: int | None = None,
) -> int:
    """HBM bytes the kernel's schedule actually moves, counted by walking
    the same grid and index maps the pallas_call builders emit.

    Pallas revisiting semantics: an input block is (re)fetched whenever its
    index-map output changes between consecutive grid steps; an output
    block is written once per run of constant index and read back on every
    revisit after its first (the read-modify-write partial-sum stream).
    ``core.dataflow.hbm_traffic_bytes`` must agree with this walk — the CI
    perf smoke (`benchmarks/train_step.py --verify-traffic`) asserts exact
    equality whenever every GEMM dimension spans >= 2 blocks, and
    walk <= model on degenerate single-block axes (there an idle grid axis
    leaves an index map constant, Pallas coalesces the refetch, and the
    closed form deliberately stays conservative rather than growing
    special cases — it never undercounts, so pruning stays safe).
    Epilogue operands (bias/residual/qscale) are outside both models.

    ``a_bytes`` / ``b_bytes`` give each operand its own element width
    (default ``in_bytes`` for both) — the quantized schedules stream a
    1-byte B against a 2/4-byte A, and the walk must count what the kernel
    actually moves.
    """
    import itertools

    bm, bk, bn = block
    mb, kb, nb = -(-M // bm), -(-K // bk), -(-N // bn)
    if dataflow is Dataflow.OS:
        grid, a_map, b_map, out_map, _ = _os_schedule(mb, kb, nb)
        out_blk = bm * bn
    else:
        stationary = "weight" if dataflow is Dataflow.WS else "input"
        if strip > 1:
            axis_blocks = mb if dataflow is Dataflow.WS else nb
            if axis_blocks % strip:
                raise ValueError(
                    f"strip {strip} does not tile the "
                    f"{'M' if dataflow is Dataflow.WS else 'N'} axis "
                    f"({axis_blocks} blocks) — the kernel would reject this "
                    "schedule, so there is no traffic to count"
                )
            grid, a_map, b_map, out_map, _ = _strip_schedule(
                stationary, mb, kb, nb, strip
            )
            out_blk = strip * bm * bn
        else:
            grid, a_map, b_map, out_map, _ = _stream_schedule(stationary, mb, kb, nb)
            out_blk = bm * bn
    a_blk = bm * bk * (in_bytes if a_bytes is None else a_bytes)
    b_blk = bk * bn * (in_bytes if b_bytes is None else b_bytes)
    total = 0
    prev_a = prev_b = prev_o = None
    seen_out: set[tuple[int, int]] = set()
    for ids in itertools.product(*(range(g) for g in grid)):
        ia, ib, io = a_map(*ids), b_map(*ids), out_map(*ids)
        if ia != prev_a:
            total += a_blk
            prev_a = ia
        if ib != prev_b:
            total += b_blk
            prev_b = ib
        if io != prev_o:  # new output run: one write, plus a read on revisit
            total += out_blk * out_bytes
            if io in seen_out:
                total += out_blk * out_bytes
            seen_out.add(io)
            prev_o = io
    return total


def matmul_os(
    a: jax.Array,
    b: jax.Array,
    *,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    activation: str | None = None,
    out_dtype: jnp.dtype | None = None,
    block: tuple[int, int, int] = DEFAULT_BLOCK,
    interpret: bool = False,
    save_preact: bool = False,
    trans_a: bool = False,
    trans_b: bool = False,
    strip: int = 1,
    qscale: jax.Array | None = None,
) -> jax.Array | tuple[jax.Array, jax.Array]:
    if strip != 1:
        raise ValueError(
            "OS runs strip=1 only: its accumulator is already VMEM-resident, "
            "and the strip generalisation of OS is the IS strip schedule"
        )
    M, K, N = _logical_dims(a, b, trans_a, trans_b)
    bm, bk, bn = block
    _check(M, K, N, bm, bk, bn)
    grid, a_map, b_map, out_map, bias_map = _os_schedule(M // bm, K // bk, N // bn)
    extra, extra_specs = _epilogue_inputs(
        qscale, bias, residual, bias_map, out_map, bm, bn)
    a_spec, b_spec = _operand_specs(bm, bk, bn, a_map, b_map, trans_a, trans_b)
    kern = functools.partial(
        _os_kernel, activation=activation, has_scale=qscale is not None,
        has_bias=bias is not None, has_res=residual is not None,
        save_preact=save_preact, trans_a=trans_a, trans_b=trans_b,
    )
    out_specs = pl.BlockSpec((bm, bn), out_map)
    out_shape = jax.ShapeDtypeStruct((M, N), out_dtype or jnp.float32)
    if save_preact:
        out_specs = [out_specs, pl.BlockSpec((bm, bn), out_map)]
        out_shape = [out_shape, jax.ShapeDtypeStruct((M, N), jnp.float32)]
    result = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[a_spec, b_spec, *extra_specs],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[_VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a, b, *extra)
    return (result[0], result[1]) if save_preact else result


def _matmul_stream(
    a: jax.Array,
    b: jax.Array,
    *,
    stationary: str,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    activation: str | None = None,
    out_dtype: jnp.dtype | None = None,
    block: tuple[int, int, int],
    interpret: bool,
    save_preact: bool = False,
    trans_a: bool = False,
    trans_b: bool = False,
    strip: int = 1,
    qscale: jax.Array | None = None,
) -> jax.Array | tuple[jax.Array, jax.Array]:
    """Shared WS/IS driver.

    ``strip >= 2`` runs the two-level schedule (`_matmul_strip`): partial
    sums accumulate in a VMEM-resident strip, the full epilogue fuses at
    flush.  ``strip = 1`` is the streamed legacy schedule: aliased
    partial-sum accumulation over the outer k axis, bias/activation/cast
    fused in the last-k branch — and the residual added *outside* the
    kernel on the f32 result (same op order, bit-identical; an in-kernel
    fetch under this grid would re-stream the residual every k plane).
    A ``qscale`` forces the fused flush: the staging buffer accumulates
    scaled-lattice partials that must dequantize before leaving the kernel.
    """
    M, K, N = _logical_dims(a, b, trans_a, trans_b)
    bm, bk, bn = block
    _check(M, K, N, bm, bk, bn)
    if strip > 1:
        return _matmul_strip(
            a, b, stationary=stationary, bias=bias, residual=residual,
            activation=activation, out_dtype=out_dtype, block=block,
            interpret=interpret, save_preact=save_preact,
            trans_a=trans_a, trans_b=trans_b, strip=strip, qscale=qscale,
        )
    grid, a_map, b_map, c_map, bias_map = _stream_schedule(
        stationary, M // bm, K // bk, N // bn
    )
    a_spec, b_spec = _operand_specs(bm, bk, bn, a_map, b_map, trans_a, trans_b)
    # the kernel casts only when no residual follows: with one, the finished
    # f32 block still needs the (f32) residual added before the final cast
    fused = (
        save_preact or bias is not None or activation is not None
        or qscale is not None
        or (residual is None and out_dtype is not None
            and jnp.dtype(out_dtype) != jnp.float32)
    )
    extra, extra_specs = _epilogue_inputs(
        qscale, bias, None, bias_map, c_map, bm, bn)
    kern = functools.partial(
        _stream_accum_kernel, activation=activation,
        has_scale=qscale is not None, has_bias=bias is not None, fused=fused,
        save_preact=save_preact, trans_a=trans_a, trans_b=trans_b,
    )
    out_specs = pl.BlockSpec((bm, bn), c_map)
    out_shape = jax.ShapeDtypeStruct((M, N), jnp.float32)
    if fused:
        # f32 partial staging buffer + finished output in the target dtype
        kern_dtype = jnp.float32 if residual is not None else (
            out_dtype or jnp.float32)
        out_specs = [out_specs, pl.BlockSpec((bm, bn), c_map)]
        out_shape = [out_shape, jax.ShapeDtypeStruct((M, N), kern_dtype)]
    result = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[a_spec, b_spec, *extra_specs],
        out_specs=out_specs,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")
        ),
        interpret=interpret,
    )(a, b, *extra)
    out = result[1] if fused else result
    z = result[0] if save_preact else None
    if residual is not None:
        out = (out + residual.astype(jnp.float32)).astype(
            out_dtype or jnp.float32)
    return (out, z) if save_preact else out


def _matmul_strip(
    a: jax.Array,
    b: jax.Array,
    *,
    stationary: str,
    bias: jax.Array | None,
    residual: jax.Array | None,
    activation: str | None,
    out_dtype: jnp.dtype | None,
    block: tuple[int, int, int],
    interpret: bool,
    save_preact: bool,
    trans_a: bool,
    trans_b: bool,
    strip: int,
    qscale: jax.Array | None = None,
) -> jax.Array | tuple[jax.Array, jax.Array]:
    """Two-level WS/IS driver: VMEM-resident accumulator strip over the
    streamed output axis, one HBM write per output block."""
    M, K, N = _logical_dims(a, b, trans_a, trans_b)
    bm, bk, bn = block
    _check(M, K, N, bm, bk, bn)
    row_strip = stationary == "weight"
    axis_blocks = M // bm if row_strip else N // bn
    if axis_blocks % strip:
        raise ValueError(
            f"strip {strip} must tile the {'M' if row_strip else 'N'} axis "
            f"({axis_blocks} blocks of {bm if row_strip else bn}); "
            "ops.flex_matmul / ops.flex_linear clamp to a feasible strip"
        )
    grid, a_map, b_map, out_map, bias_map = _strip_schedule(
        stationary, M // bm, K // bk, N // bn, strip
    )
    a_spec, b_spec = _operand_specs(bm, bk, bn, a_map, b_map, trans_a, trans_b)
    sblock = (strip * bm, bn) if row_strip else (bm, strip * bn)
    bias_block = (1, bn) if row_strip else (1, strip * bn)
    fused = (
        save_preact
        or bias is not None or residual is not None or activation is not None
        or qscale is not None
        or (out_dtype is not None and jnp.dtype(out_dtype) != jnp.float32)
    )
    extra, extra_specs = [], []
    if qscale is not None:  # rides the bias layout: (1, bn) / (1, ns*bn)
        extra.append(qscale)
        extra_specs.append(pl.BlockSpec(bias_block, bias_map))
    if bias is not None:
        extra.append(bias)
        extra_specs.append(pl.BlockSpec(bias_block, bias_map))
    if residual is not None:  # honest per-strip fetch: map ignores k and u
        extra.append(residual)
        extra_specs.append(pl.BlockSpec(sblock, out_map))
    kern = functools.partial(
        _strip_kernel, activation=activation, has_scale=qscale is not None,
        has_bias=bias is not None, has_res=residual is not None, fused=fused,
        save_preact=save_preact, trans_a=trans_a, trans_b=trans_b,
        ns=strip, row_strip=row_strip,
    )
    out_specs = [pl.BlockSpec(sblock, out_map)]
    out_shape = [jax.ShapeDtypeStruct(
        (M, N), (out_dtype or jnp.float32) if fused else jnp.float32)]
    if save_preact:
        out_specs.append(pl.BlockSpec(sblock, out_map))
        out_shape.append(jax.ShapeDtypeStruct((M, N), jnp.float32))
    scratch = []
    if fused and not save_preact:
        scratch.append(_VMEM(sblock, jnp.float32))
    result = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[a_spec, b_spec, *extra_specs],
        out_specs=out_specs if len(out_specs) > 1 else out_specs[0],
        out_shape=out_shape if len(out_shape) > 1 else out_shape[0],
        scratch_shapes=scratch,
        compiler_params=CompilerParams(
            # (s, j/i) own disjoint output strips — single-writer, so
            # megacore partitioning can engage; k and u stay sequential
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")
        ),
        interpret=interpret,
    )(a, b, *extra)
    if save_preact:
        return result[0], result[1]
    return result


def matmul_ws(a, b, *, block=DEFAULT_BLOCK, interpret=False, strip=1,
              **epilogue):
    return _matmul_stream(a, b, stationary="weight", block=block,
                          interpret=interpret, strip=strip, **epilogue)


def matmul_is(a, b, *, block=DEFAULT_BLOCK, interpret=False, strip=1,
              **epilogue):
    return _matmul_stream(a, b, stationary="input", block=block,
                          interpret=interpret, strip=strip, **epilogue)


KERNELS = {
    Dataflow.OS: matmul_os,
    Dataflow.WS: matmul_ws,
    Dataflow.IS: matmul_is,
}


def matmul(
    a: jax.Array,
    b: jax.Array,
    dataflow: Dataflow = Dataflow.OS,
    *,
    block: tuple[int, int, int] = DEFAULT_BLOCK,
    interpret: bool = False,
    trans_a: bool = False,
    trans_b: bool = False,
    strip: int = 1,
) -> jax.Array:
    """Flex matmul: same math, dataflow-selected block schedule.

    ``trans_a`` / ``trans_b`` read the operands in transposed physical
    layout via the index maps — ``op(a) @ op(b)`` with zero HBM copies.
    ``strip >= 2`` selects the two-level WS/IS schedule (VMEM-resident
    accumulator strip; OS rejects it — see module docstring).
    """
    return KERNELS[dataflow](a, b, block=block, interpret=interpret,
                             trans_a=trans_a, trans_b=trans_b, strip=strip)


def fused_matmul(
    a: jax.Array,
    b: jax.Array,
    dataflow: Dataflow = Dataflow.OS,
    *,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    activation: str | None = None,
    out_dtype: jnp.dtype | None = None,
    block: tuple[int, int, int] = DEFAULT_BLOCK,
    interpret: bool = False,
    save_preact: bool = False,
    trans_a: bool = False,
    trans_b: bool = False,
    strip: int = 1,
    qscale: jax.Array | None = None,
) -> jax.Array | tuple[jax.Array, jax.Array]:
    """Matmul with the epilogue fused into the kernel's final flush.

    ``bias`` must be (1, N); ``residual`` (M, N); all dims block multiples
    (ops.flex_linear pads).  ``activation`` in {relu, gelu, silu, None}.
    With ``save_preact`` returns ``(out, z)`` where ``z`` is the f32
    pre-activation ``a @ b + bias`` — what the custom VJP saves.
    ``trans_a`` / ``trans_b`` read transposed-layout operands in place.
    ``strip >= 2`` runs the two-level WS/IS schedule: the whole epilogue
    (residual included) fuses at the strip flush; with ``strip = 1`` the
    streamed WS/IS kernels fuse bias/activation/cast and the residual is
    added outside the kernel in the same f32 op order (bit-identical).
    ``qscale`` (1, N) f32 marks B as a quantized (int8/fp8) operand with
    per-output-channel scales: the flush dequantizes the f32 accumulator
    before the rest of the epilogue (dequant -> bias -> act -> residual ->
    cast), so quantized and unquantized calls share the epilogue contract.
    """
    if activation is not None and activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")
    return KERNELS[dataflow](
        a, b, bias=bias, residual=residual, activation=activation,
        out_dtype=out_dtype, block=block, interpret=interpret,
        save_preact=save_preact, trans_a=trans_a, trans_b=trans_b,
        strip=strip, qscale=qscale,
    )
