"""Pallas TPU matmul kernels with reconfigurable dataflow (IS / OS / WS).

This is the TPU-native port of the Flex-TPU processing element (paper Fig. 3/4):
on a real TPU the programmable "stationarity" lives one level up the memory
hierarchy — which operand's VMEM block stays resident across consecutive grid
steps, determined by the grid loop order and each ``BlockSpec.index_map``:

  OS  grid (i, j, k):  the f32 accumulator block C[i,j] is pinned in VMEM
      scratch across the whole k loop and written to HBM exactly once.
  WS  grid (k, j, i):  the weight block B[k,j] is pinned across the entire
      M stream (its index_map ignores the innermost grid axis); partial sums
      stream through HBM (aliased read-modify-write) — the price WS pays when
      K exceeds one block, exactly as in `core.dataflow.hbm_traffic_bytes`.
  IS  grid (k, i, j):  symmetric — the activation block A[i,k] is pinned,
      weights stream, partials stream.

All three compute bit-identical results (f32 accumulation); they differ only
in HBM traffic and residency, which is the paper's point.  The CMU
(`core.cmu.plan_kernels`) picks per layer offline; dispatch is static at
trace time (the JAX analogue of programming the CMU mux signals).

Kernels are written for TPU (MXU-aligned blocks, VMEM scratch) and validated
on CPU with ``interpret=True`` against ``ref.matmul_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.dataflow import Dataflow

DEFAULT_BLOCK = (256, 256, 256)  # (bm, bk, bn) — MXU-aligned, ~768KB working set


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------


def _os_kernel(a_ref, b_ref, o_ref, acc_ref):
    """Output-stationary: accumulate in VMEM scratch across the k grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _stream_accum_kernel(a_ref, b_ref, o_ref):
    """WS/IS shared body: one MAC into the HBM-streamed partial-sum block.

    The output block is revisited non-consecutively across the outer k axis,
    so partial sums stream through HBM (read-modify-write) — the structural
    price WS/IS pay when K exceeds one block, matching
    ``core.dataflow.hbm_traffic_bytes``.  The stationarity difference between
    WS and IS is entirely in the grid order and index_maps of the surrounding
    pallas_call (whose pinned operand ignores the innermost axis), not in the
    MAC itself — mirroring the paper's PE, where the same MAC hardware serves
    all three dataflows and only the mux selection changes.
    """
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call builders (one per dataflow)
# ---------------------------------------------------------------------------


def _check(M: int, K: int, N: int, bm: int, bk: int, bn: int) -> None:
    if M % bm or K % bk or N % bn:
        raise ValueError(
            f"matmul dims ({M},{K},{N}) must divide blocks ({bm},{bk},{bn}); "
            "use ops.flex_matmul which pads"
        )


def matmul_os(
    a: jax.Array,
    b: jax.Array,
    *,
    block: tuple[int, int, int] = DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bk, bn = block
    _check(M, K, N, bm, bk, bn)
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _os_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.MemorySpace.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a, b)


def _matmul_stream(
    a: jax.Array,
    b: jax.Array,
    *,
    stationary: str,
    block: tuple[int, int, int],
    interpret: bool,
) -> jax.Array:
    """Shared WS/IS driver: aliased partial-sum accumulation over outer k."""
    M, K = a.shape
    _, N = b.shape
    bm, bk, bn = block
    _check(M, K, N, bm, bk, bn)
    if stationary == "weight":
        # WS: grid (k, j, i) — B[k,j] constant across innermost i (pinned).
        grid = (K // bk, N // bn, M // bm)
        a_spec = pl.BlockSpec((bm, bk), lambda k, j, i: (i, k))
        b_spec = pl.BlockSpec((bk, bn), lambda k, j, i: (k, j))
        c_spec = pl.BlockSpec((bm, bn), lambda k, j, i: (i, j))
    elif stationary == "input":
        # IS: grid (k, i, j) — A[i,k] constant across innermost j (pinned).
        grid = (K // bk, M // bm, N // bn)
        a_spec = pl.BlockSpec((bm, bk), lambda k, i, j: (i, k))
        b_spec = pl.BlockSpec((bk, bn), lambda k, i, j: (k, j))
        c_spec = pl.BlockSpec((bm, bn), lambda k, i, j: (i, j))
    else:  # pragma: no cover
        raise ValueError(stationary)
    return pl.pallas_call(
        _stream_accum_kernel,
        grid=grid,
        in_specs=[a_spec, b_spec],
        out_specs=c_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")
        ),
        interpret=interpret,
    )(a, b)


def matmul_ws(a, b, *, block=DEFAULT_BLOCK, interpret=False):
    return _matmul_stream(a, b, stationary="weight", block=block, interpret=interpret)


def matmul_is(a, b, *, block=DEFAULT_BLOCK, interpret=False):
    return _matmul_stream(a, b, stationary="input", block=block, interpret=interpret)


KERNELS = {
    Dataflow.OS: matmul_os,
    Dataflow.WS: matmul_ws,
    Dataflow.IS: matmul_is,
}


def matmul(
    a: jax.Array,
    b: jax.Array,
    dataflow: Dataflow = Dataflow.OS,
    *,
    block: tuple[int, int, int] = DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Flex matmul: same math, dataflow-selected block schedule."""
    return KERNELS[dataflow](a, b, block=block, interpret=interpret)
