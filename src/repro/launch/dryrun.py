import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, with no device allocation (ShapeDtypeStruct inputs).

For each cell this prints/records:
  - compiled.memory_analysis()  (per-device bytes: proves the config fits)
  - compiled.cost_analysis()    (HLO FLOPs / bytes; scan bodies counted once —
                                 the roofline harness corrects via unrolled
                                 depth probes, benchmarks/roofline.py)
  - the collective schedule     (op type -> count, bytes) parsed from HLO

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""

import argparse
import json
import re
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, all_cells, applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    batch_shardings,
    batch_specs,
    cache_shardings,
    model_for_cell,
    rules_for,
)
from repro.launch.steps import (
    make_decode_step,
    make_prefill_step,
    make_train_step,
    microbatches_for,
    use_quantized_opt,
)
from repro.models.sharding import param_shardings, use_rules
from repro.optim import adamw_init

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt[:4].rstrip("_"), 4)
    return total


def collective_stats(hlo_text: str) -> dict[str, dict[str, float]]:
    """Sum bytes moved per collective type from compiled (SPMD) HLO."""
    stats: dict[str, dict[str, float]] = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+)$", s)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.search(r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)(?:-start)?\(", rhs)
        if not opm or "-done" in rhs:
            continue
        op = opm.group(1)
        result_part = rhs[: opm.start()]
        operand_part = rhs[opm.end():]
        b = max(_shape_bytes(result_part), _shape_bytes(operand_part))
        stats[op]["count"] += 1
        stats[op]["bytes"] += b
    return stats


def opt_shardings(opt_sds, p_shardings, mesh):
    """fp32 moments follow param shardings; int8 blocks flat-shard dim 0."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.optim.adamw import AdamWState

    flat_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)

    # int8 moments keep the param's shape -> same sharding; per-channel
    # scales (last dim 1) keep the leading spec with the last entry dropped.
    def scale_sh(p_sh):
        spec = list(p_sh.spec)
        if spec:
            spec[-1] = None
        return NamedSharding(mesh, P(*spec))

    m_sh, v_sh = jax.tree.map(lambda p: p, p_shardings), jax.tree.map(lambda p: p, p_shardings)
    sc_sh = None
    if opt_sds.scales is not None:
        sc_sh = (jax.tree.map(scale_sh, p_shardings), None)
    return AdamWState(step=NamedSharding(mesh, P()), m=m_sh, v=v_sh, scales=sc_sh)


def lower_cell(
    arch: str,
    shape: str,
    *,
    multi_pod: bool = False,
    rules: dict | None = None,
    overrides: dict | None = None,
    compile_only_lower: bool = False,
    unroll: bool = False,
    microbatches: int | None = None,
):
    """Lower + compile one cell. Returns the result record."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    model, cell = model_for_cell(arch, shape, unroll=unroll, overrides=overrides)
    cfg = model.cfg
    rec: dict[str, Any] = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "step": cell.step,
    }
    t0 = time.time()
    if rules is None:
        rules = rules_for(arch, shape)
    with use_rules(mesh, rules):
        p_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        p_sh = param_shardings(p_sds)
        b_sds = batch_specs(cfg, cell)
        b_sh = batch_shardings(mesh, b_sds)

        if cell.step == "train":
            o_sds = jax.eval_shape(
                lambda p: adamw_init(p, quantize=use_quantized_opt(arch)), p_sds
            )
            o_sh = opt_shardings(o_sds, p_sh, mesh)
            mb = microbatches if microbatches is not None else microbatches_for(arch)
            step = make_train_step(model, microbatches=mb)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(p_sds, o_sds, b_sds)
        elif cell.step == "prefill":
            step = make_prefill_step(model, cache_len=cell.seq_len)
            c_sds = jax.eval_shape(step, p_sds, b_sds)[0]
            c_sh = cache_shardings(mesh, c_sds)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh), out_shardings=(c_sh, None))
            lowered = jitted.lower(p_sds, b_sds)
        else:  # decode
            prefill = make_prefill_step(model, cache_len=cell.seq_len)
            pre_b = batch_specs(cfg, SHAPES["prefill_32k"])
            # cache structure from eval_shape at this cell's B x S
            pre_b = {
                k: jax.ShapeDtypeStruct((cell.global_batch,) + v.shape[1:], v.dtype)
                for k, v in pre_b.items()
                if k != "labels"
            }
            # prompt length irrelevant for cache struct; use a short prompt
            prompt = min(128, cell.seq_len)
            pre_b["tokens"] = jax.ShapeDtypeStruct((cell.global_batch, prompt), jnp.int32)
            if cfg.family == "vlm":
                pre_b["vision_embeds"] = jax.ShapeDtypeStruct(
                    (cell.global_batch, cfg.vision_tokens, cfg.vision_embed_dim or cfg.d_model),
                    jnp.float32,
                )
            c_sds = jax.eval_shape(prefill, p_sds, pre_b)[0]
            c_sh = cache_shardings(mesh, c_sds)
            step = make_decode_step(model)
            tok = jax.ShapeDtypeStruct((cell.global_batch,), jnp.int32)
            from jax.sharding import NamedSharding, PartitionSpec as P
            tok_sh = batch_shardings(mesh, {"tokens": tok})["tokens"]
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, c_sh, tok_sh),
                out_shardings=(None, c_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(p_sds, c_sds, tok)

        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        if mem is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    rec[f"mem_{k}"] = int(v)
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # jax < 0.5: one dict per device
            cost = cost[0] if cost else None
        if cost:
            rec["hlo_flops"] = float(cost.get("flops", 0.0))
            rec["hlo_bytes"] = float(cost.get("bytes accessed", 0.0))
        hlo = compiled.as_text()
        rec["collectives"] = collective_stats(hlo)
        rec["hlo_lines"] = hlo.count("\n")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    cells = all_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in cells:
        if not applicable(arch, shape):
            print(f"SKIP {arch} x {shape} (inapplicable)")
            continue
        for mp in meshes:
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"CACHED {tag}")
                continue
            try:
                rec = lower_cell(arch, shape, multi_pod=mp)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                coll = {k: v for k, v in rec["collectives"].items() if v["count"]}
                print(
                    f"OK {tag}: lower {rec['lower_s']}s compile {rec['compile_s']}s "
                    f"flops {rec.get('hlo_flops', 0):.3g} "
                    f"mem_temp {rec.get('mem_temp_size_in_bytes', -1):,} "
                    f"collectives {list(coll)}"
                )
            except Exception as e:  # noqa: BLE001 - report and continue
                failures.append((tag, str(e)[:200]))
                print(f"FAIL {tag}: {e}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nAll dry-run cells compiled.")


if __name__ == "__main__":
    main()
