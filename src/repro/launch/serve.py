"""Batched serving driver: continuous prefill + decode over a request queue.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3_12b --smoke \
      --requests 8 --prompt-len 24 --gen 16

Multi-device (the mesh-native flex kernel path; on CPU give jax virtual
devices first):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --arch qwen3_4b --smoke --pallas \
      --mesh 2x4 --requests 8 --prompt-len 32 --gen 4
"""

from __future__ import annotations

import argparse
import contextlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import make_decode_step, make_prefill_step, setup_plan_cache
from repro.models import Model, get_config


def parse_mesh(spec: str):
    """'DxM' -> a ('data', 'model') mesh, e.g. '2x4'; '' -> None."""
    if not spec:
        return None
    from repro.launch.mesh import make_mesh

    d, m = (int(v) for v in spec.lower().split("x"))
    return make_mesh((d, m), ("data", "model"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3_12b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--plan-cache", default="",
                    help="CMU plan JSON: reload if present, else autotune + save")
    ap.add_argument("--pallas", action="store_true",
                    help="dispatch projections to the fused flex kernels")
    ap.add_argument("--mesh", default="",
                    help="'DxM' data x model mesh (e.g. 2x4): serve "
                         "multi-device — projections run the shard_map-"
                         "composed mesh-native kernel path when --pallas")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.pallas:
        cfg = cfg.replace(use_pallas=True)
    mesh = parse_mesh(args.mesh)
    if mesh is not None:
        from repro.models.sharding import use_rules

        rules_ctx = use_rules(mesh)
    else:
        rules_ctx = contextlib.nullcontext()
    with rules_ctx:
        _serve(args, cfg, mesh)


def _serve(args, cfg, mesh) -> None:
    setup_plan_cache(args.plan_cache, cfg, args.requests * args.prompt_len,
                     mesh=mesh)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if mesh is not None:
        from repro.models.sharding import param_shardings

        params = jax.device_put(params, param_shardings(params))
    prefill = jax.jit(make_prefill_step(model, cache_len=args.cache_len))
    decode = jax.jit(make_decode_step(model), donate_argnums=(1,))

    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (args.requests, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["audio_embeds"] = jax.random.normal(key, (args.requests, cfg.enc_seq_len, cfg.d_model))
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (args.requests, cfg.vision_tokens, cfg.vision_embed_dim or cfg.d_model)
        )

    t0 = time.time()
    cache, last = prefill(params, batch)
    last.block_until_ready()
    t_prefill = time.time() - t0
    tok = jnp.argmax(last, -1).astype(jnp.int32)

    outs = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / args.temperature).astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_dec = time.time() - t0

    gen = np.stack(outs, 1)
    print(f"prefill: {args.requests}x{args.prompt_len} tokens in {t_prefill*1e3:.0f} ms "
          f"({args.requests*args.prompt_len/t_prefill:,.0f} tok/s)")
    print(f"decode:  {args.gen-1} steps x {args.requests} reqs in {t_dec*1e3:.0f} ms "
          f"({args.requests*(args.gen-1)/max(t_dec,1e-9):,.0f} tok/s)")
    print("sample generations (token ids):")
    for r in range(min(3, args.requests)):
        print(f"  req{r}: {gen[r, :12].tolist()}")


if __name__ == "__main__":
    main()
