"""Serving driver: continuous batching over the paged KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_4b --smoke \
      --requests 10 --prompt-len 12 --gen 6 --arrival-rate 0.5 --verify

Default mode is the continuous-batching scheduler (``launch.scheduler``):
Poisson-staggered requests are admitted into free slots as they arrive,
finished ones evicted per step, decode batches quantized to the tuned CMU
batch buckets.  ``--verify`` replays every request through classic
per-request ``prefill``/``decode_step`` serving and asserts the token
streams are identical.  ``--fixed-batch`` runs the old fixed-batch loop
instead (the benchmark baseline).

Multi-device (the mesh-native flex kernel path; on CPU give jax virtual
devices first):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --arch qwen3_4b --smoke --pallas \
      --mesh 2x4 --requests 8 --prompt-len 12 --gen 4
"""

from __future__ import annotations

import argparse
import contextlib
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.scheduler import (
    RequestStatus,
    ServeScheduler,
    poisson_trace,
    run_fixed_batch,
    serve_buckets,
)
from repro.launch.steps import make_decode_step, make_prefill_step, setup_plan_cache
from repro.models import Model, get_config
from repro.runtime.fault_injection import FaultPlan


def parse_mesh(spec: str):
    """'DxM' -> a ('data', 'model') mesh, e.g. '2x4'; '' -> None."""
    if not spec:
        return None
    from repro.launch.mesh import make_mesh

    d, m = (int(v) for v in spec.lower().split("x"))
    return make_mesh((d, m), ("data", "model"))


def sequential_reference(model, params, requests, cache_len: int):
    """Classic per-request serving: exact-length prefill, batch-1 decode.
    The correctness oracle for the continuous-batching path."""
    prefill = jax.jit(make_prefill_step(model, cache_len))
    decode = jax.jit(make_decode_step(model))
    out = {}
    for r in requests:
        cache, last = prefill(params, {"tokens": jnp.asarray(r.prompt[None])})
        tok = jnp.argmax(last, -1).astype(jnp.int32)
        toks = [tok]
        for _ in range(r.max_new - 1):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            toks.append(tok)
        out[r.rid] = np.asarray([int(t[0]) for t in jax.device_get(toks)], np.int32)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24,
                    help="max prompt length (trace mixes [4, max])")
    ap.add_argument("--gen", type=int, default=16,
                    help="max generated tokens (trace mixes [2, max])")
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="Poisson arrivals per decode step; 0 = all at once")
    ap.add_argument("--slots", type=int, default=8,
                    help="slot-table capacity (= max decode batch bucket)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV cache block size in tokens")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="assert token streams == classic per-request decode "
                         "(under --faults: every *completed* stream must "
                         "still match, and every request must end in a "
                         "terminal status)")
    ap.add_argument("--deadline", type=int, default=0,
                    help="queue-wait TTL in decode steps: a request still "
                         "waiting past it times out (0 = no deadline)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bound on the waiting queue; the newest arrival is "
                         "load-shed when it would overflow (0 = unbounded)")
    ap.add_argument("--faults", default="",
                    help="deterministic fault injection, e.g. "
                         "'alloc=0.1,nan=0.02,preempt=0.05,latency=0.01"
                         "[,seed=N]' (see runtime/fault_injection.py)")
    ap.add_argument("--fixed-batch", action="store_true",
                    help="run the legacy fixed-batch loop instead")
    ap.add_argument("--cache-len", type=int, default=128,
                    help="dense cache length for --fixed-batch")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (--fixed-batch only; the "
                         "scheduler is greedy for determinism)")
    ap.add_argument("--plan-cache", default="",
                    help="CMU plan JSON: reload if present, else autotune + "
                         "save (bucketed decode sub-plans included)")
    ap.add_argument("--pallas", action="store_true",
                    help="dispatch projections to the fused flex kernels")
    ap.add_argument("--quant", nargs="?", const="int8,fp8", default="",
                    help="tune weight-quantized decode/prefill GEMMs: a "
                         "comma list of dtypes from {int8, fp8} (bare flag "
                         "= 'int8,fp8').  Each layer is accuracy-gated and "
                         "either dispatches the quantized kernel with its "
                         "fused dequant epilogue or records a bf16 "
                         "fallback in the plan; requires --pallas to "
                         "change dispatch")
    ap.add_argument("--quant-budget", type=float, default=None,
                    help="accuracy gate bound: max relative RMS calibration "
                         "error a quantized layer may add (default "
                         "cmu.QUANT_ERROR_BUDGET)")
    ap.add_argument("--attn-pallas", action="store_true",
                    help="dispatch attention to the planned flex flash/"
                         "paged kernel family (prefill flash + per-bucket "
                         "Pallas paged decode)")
    ap.add_argument("--ssm-pallas", action="store_true",
                    help="dispatch the ssm/hybrid mixer scan to the planned "
                         "flex chunked-scan kernel family (prefill chunked "
                         "scan + per-bucket fused decode step); no-op on "
                         "attention-only archs")
    ap.add_argument("--mesh", default="",
                    help="'DxM' data x model mesh (e.g. 2x4): serve "
                         "multi-device — projections run the shard_map-"
                         "composed mesh-native kernel path when --pallas")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.pallas:
        cfg = cfg.replace(use_pallas=True)
    if args.attn_pallas:
        cfg = cfg.replace(attn_pallas=True)
    if args.ssm_pallas:
        cfg = cfg.replace(ssm_pallas=True)
    mesh = parse_mesh(args.mesh)
    if mesh is not None:
        from repro.models.sharding import use_rules

        rules_ctx = use_rules(mesh)
    else:
        rules_ctx = contextlib.nullcontext()
    with rules_ctx:
        _serve(args, cfg, mesh)


def _serve(args, cfg, mesh) -> None:
    buckets = None if args.fixed_batch else serve_buckets(args.slots)
    quant = tuple(q for q in args.quant.split(",") if q) or None
    setup_plan_cache(args.plan_cache, cfg, args.requests * args.prompt_len,
                     mesh=mesh, decode_buckets=buckets, quant=quant,
                     quant_budget=args.quant_budget)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if mesh is not None:
        from repro.models.sharding import param_shardings

        params = jax.device_put(params, param_shardings(params))

    if args.fixed_batch:
        _serve_fixed(args, cfg, model, params)
        return

    trace = poisson_trace(
        args.requests, vocab=cfg.vocab_size, max_prompt=args.prompt_len,
        max_gen=args.gen, rate=args.arrival_rate, seed=args.seed)
    faults = (FaultPlan.from_spec(args.faults, seed=args.seed)
              if args.faults else None)
    sched = ServeScheduler(
        model, params, capacity=args.slots, block_size=args.block_size,
        max_total_len=args.prompt_len + args.gen,
        deadline=args.deadline or None, max_queue=args.max_queue or None,
        faults=faults)
    t0 = time.perf_counter()
    results, stats = sched.run(trace)
    wall = time.perf_counter() - t0

    print(f"continuous batching: {args.requests} reqs, {stats.tokens} tokens "
          f"in {wall*1e3:.0f} ms ({stats.tokens/max(wall, 1e-9):,.0f} tok/s)")
    print(f"  {stats.steps} decode steps, {stats.prefills} prefills, "
          f"slot utilization {stats.slot_utilization:.2f}, "
          f"bucket histogram {stats.bucket_histogram()}")
    if faults is not None or stats.rejections or stats.timeouts:
        statuses: dict[str, int] = {}
        for res in results.values():
            statuses[res.status.value] = statuses.get(res.status.value, 0) + 1
        print(f"  statuses {statuses} | preemptions {stats.preemptions}, "
              f"replays {stats.replays}, injected {stats.faults_injected}")
    for r in trace[:3]:
        res = results[r.rid]
        toks = res.tokens[:12].tolist() if res.tokens is not None else None
        print(f"  req{r.rid} [{res.status.value}]: {toks}")

    if args.verify:
        cache_len = sched.max_blocks * sched.block_size
        completed = [r for r in trace if results[r.rid].status.completed]
        ref = sequential_reference(model, params, completed, cache_len)
        bad = [r.rid for r in completed
               if not np.array_equal(results[r.rid].tokens, ref[r.rid])]
        if bad:
            for rid in bad[:3]:
                print(f"  MISMATCH req{rid}: scheduler "
                      f"{results[rid].tokens.tolist()} != sequential "
                      f"{ref[rid].tolist()}")
            raise SystemExit(
                f"verify FAILED: {len(bad)}/{len(completed)} completed "
                "streams diverge from per-request sequential decode")
        if faults is not None:
            terminal = all(isinstance(res.status, RequestStatus)
                           for res in results.values())
            assert terminal and len(results) == len(trace)
            print(f"verify: {len(completed)}/{len(trace)} completed under "
                  f"{faults.describe()}; every completed stream identical "
                  "to per-request sequential decode, every request in a "
                  "terminal status")
        else:
            print(f"verify: {len(completed)}/{len(trace)} token streams "
                  "identical to per-request sequential decode")


def _serve_fixed(args, cfg, model, params) -> None:
    rng = np.random.default_rng(args.seed)
    reqs = []
    from repro.launch.scheduler import Request

    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=args.prompt_len).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new=args.gen))
    if args.temperature > 0:
        # keep the legacy sampling path exercisable
        print("note: --temperature samples only in the legacy loop; results "
              "are not comparable across runs")
    results, st = run_fixed_batch(model, params, reqs, cache_len=args.cache_len)
    print(f"fixed batch: {args.requests}x{args.gen} tokens in "
          f"{st['walltime_s']*1e3:.0f} ms "
          f"({st['useful_tokens']/max(st['walltime_s'], 1e-9):,.0f} tok/s, "
          f"{st['row_steps']} row-steps for {st['useful_tokens']} useful)")
    for i in range(min(3, args.requests)):
        print(f"  req{i}: {results[i][:12].tolist()}")


if __name__ == "__main__":
    main()
