"""Production mesh builders.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first jax use.
"""

from __future__ import annotations

import jax

# jax promoted shard_map out of experimental at 0.5; the pinned 0.4.x only
# has the experimental spelling.  Every caller (models, runtime, tests)
# imports this compat name instead of touching jax.shard_map directly.
try:
    shard_map = jax.shard_map
except AttributeError:  # jax < 0.5
    from jax.experimental.shard_map import shard_map

__all__ = ["dp_axes", "dp_size", "make_mesh", "make_production_mesh", "shard_map"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; the multi-pod mesh adds a leading DCN 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (elastic resizes, CI-scale meshes)."""
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh, axes: tuple[str, ...] | None = None) -> int:
    """Data-parallel extent of ``mesh`` — the **canonical** definition.

    ``axes`` names the mesh axes that play the DP role; None means the
    production convention (``dp_axes``: whichever of 'pod'/'data' exist).
    ``models.sharding.dp_size`` is the rules-context wrapper around this —
    it resolves the active rules table's ``act_batch`` mapping and
    delegates here, so the two can never drift (pinned by
    tests/test_mesh_flex.py::test_dp_size_single_definition).
    """
    import math

    if axes is None:
        axes = dp_axes(mesh)
    return math.prod(mesh.shape[a] for a in axes if a in mesh.axis_names)
