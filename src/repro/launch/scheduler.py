"""Continuous-batching serve scheduler over the paged KV cache.

The previous serving loop was fixed-batch: all requests prefill together,
decode runs ``max(gen)`` steps for everyone, and a request finishing early
keeps burning its row until the slowest one is done.  This module replaces
it with the production shape:

  * a **request queue** with per-step admission — finished requests are
    evicted the step they complete and freed slots are refilled from the
    queue, so the decode batch tracks the live load;
  * a **slot table** of fixed capacity: slot state (block table, position,
    last token) lives in compacted host arrays sliced to the active bucket
    each step, so jit only ever sees one shape per bucket;
  * **bucket-quantized decode**: the live batch is padded up to the
    smallest tuned batch-size bucket (``core.cmu.DECODE_BUCKETS`` capped at
    the slot capacity) and each bucket dispatches its own pre-tuned CMU
    decode sub-plan — the PR-4 skinny-bm geometries — via
    ``LayerPlan.decode_plan``;
  * **prefill/decode disaggregation**: prefill runs one request at a time
    at a pow2-of-block-size padded prompt length (one jit signature per
    length bucket), scattering K/V straight into the paged block pools;
    decode never sees a prompt.  Cross-request prefill batching is left
    out deliberately: rows of a batched GEMM under a *different* bucket
    plan are a different reduction geometry, which would break the
    batch-composition-independence guarantee the tests pin down.

Determinism contract: greedy decode here is bitwise identical to classic
per-request ``prefill``/``decode_step`` serving, independent of arrival
order, co-scheduled batch composition, and bucket padding — pad slots
write only the reserved scratch block and masked attention scores underflow
to exact zeros, so a request's stream never depends on its neighbours.
The contract holds on the Pallas decode-attention path too
(``cfg.attn_pallas``): the paged flash kernel zeroes masked probabilities
*multiplicatively* (``p = where(live, exp(s - m), 0)``) rather than relying
on additive ``-1e30`` bias underflow alone, so pad rows — whose every key
is masked — contribute exact-zero attention instead of a uniform
distribution over garbage.  ``tests/test_serving.py`` pins stream-vs-
sequential token equality per bucket with the Pallas path enabled.

Host/device sync discipline: tokens live in a device-resident slot array
and are folded back with lazy ``.at[].set``; the loop never calls
``np.asarray`` per step (the old loop's per-step host sync).  The only
blocking syncs are at admission/eviction events — where the host must
inspect schedule state anyway — and each one timestamps the event stream
that ``benchmarks/serve_bench.py`` turns into per-token latencies.
"""

from __future__ import annotations

import logging
import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cmu import DECODE_BUCKETS
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.runtime.kv_cache import PagedKVCache

log = logging.getLogger(__name__)


@dataclass
class Request:
    """One serving request: ``max_new`` greedy tokens from ``prompt``.

    ``arrival`` is a virtual timestamp in decode-step units — the scheduler
    admits a request only once its arrival step has passed, which is how
    the benchmark replays a Poisson trace without wall-clock sleeps."""

    rid: int
    prompt: np.ndarray
    max_new: int
    arrival: int = 0


@dataclass
class RequestResult:
    rid: int
    tokens: np.ndarray | None  # filled by the end-of-run drain
    admitted_step: int
    finished_step: int


@dataclass
class ServeStats:
    capacity: int
    steps: int = 0
    prefills: int = 0
    tokens: int = 0
    active_per_step: list[int] = field(default_factory=list)
    bucket_per_step: list[int] = field(default_factory=list)
    # (decode steps so far, tokens so far, perf_counter) at every sync event
    events: list[tuple[int, int, float]] = field(default_factory=list)

    @property
    def slot_utilization(self) -> float:
        if not self.steps:
            return 0.0
        return sum(self.active_per_step) / (self.steps * self.capacity)

    def bucket_histogram(self) -> dict[int, int]:
        h: dict[int, int] = {}
        for b in self.bucket_per_step:
            h[b] = h.get(b, 0) + 1
        return dict(sorted(h.items()))


@dataclass
class _Slot:
    rid: int
    pos: int        # next cache write position = tokens already cached
    remaining: int  # decode steps left
    blocks: list[int]
    admitted_step: int


def _pow2_at_least(n: int, lo: int) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def _jit_steps(model):
    """Jitted (greedy prefill, greedy decode) paged steps, cached on the
    model: every ``ServeScheduler`` for the same model shares one jit cache,
    so a fresh scheduler (the benchmark builds several) never recompiles
    already-traced (prompt-bucket, batch-bucket) signatures."""
    cached = getattr(model, "_paged_jit_steps", None)
    if cached is not None:
        return cached
    pf = make_prefill_step(model, paged=True)
    dc = make_decode_step(model, paged=True)

    def prefill_fn(params, tokens, lens, table, pool_k, pool_v):
        last, pk, pv = pf(params, {"tokens": tokens}, lens, table, pool_k, pool_v)
        return jnp.argmax(last, -1).astype(jnp.int32), pk, pv

    def decode_fn(params, pool_k, pool_v, table, positions, token):
        logits, pk, pv = dc(params, pool_k, pool_v, table, positions, token)
        return jnp.argmax(logits, -1).astype(jnp.int32), pk, pv

    steps = (jax.jit(prefill_fn, donate_argnums=(4, 5)),
             jax.jit(decode_fn, donate_argnums=(1, 2)))
    model._paged_jit_steps = steps
    return steps


def serve_buckets(capacity: int) -> tuple[int, ...]:
    """The decode batch buckets for a slot capacity: every tuned bucket
    below it, plus the capacity itself."""
    return tuple(sorted({b for b in DECODE_BUCKETS if b < capacity} | {capacity}))


def poisson_trace(n: int, *, vocab: int, max_prompt: int, max_gen: int,
                  rate: float = 0.0, seed: int = 0, min_prompt: int = 4,
                  min_gen: int = 2) -> list[Request]:
    """Synthetic request trace: Poisson arrivals (exponential interarrivals
    in decode-step units; ``rate <= 0`` lands everything at step 0) with
    uniformly mixed prompt/generation lengths."""
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for i in range(n):
        if rate > 0:
            t += rng.exponential(1.0 / rate)
        p = int(rng.integers(min_prompt, max_prompt + 1))
        g = int(rng.integers(min_gen, max_gen + 1))
        prompt = rng.integers(0, vocab, size=p).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new=g, arrival=int(t)))
    return reqs


class ServeScheduler:
    """Continuous-batching greedy decoder over a paged KV cache.

    ``capacity`` slots; each admitted request gets its blocks for
    ``prompt + max_new - 1`` cache positions up front (no mid-flight OOM),
    a queue position otherwise.  ``run(requests)`` replays a trace and
    returns ``({rid: RequestResult}, ServeStats)``.
    """

    def __init__(self, model, params, *, capacity: int = 8,
                 block_size: int = 16, max_total_len: int,
                 num_blocks: int | None = None):
        cfg = model.cfg
        if cfg.family not in ("dense", "moe", "vlm"):
            raise NotImplementedError(
                f"continuous batching covers dense/moe/vlm, not {cfg.family}")
        self.model = model
        self.params = params
        self.capacity = capacity
        self.block_size = block_size
        self.buckets = serve_buckets(capacity)
        # table width: blocks for the longest admissible request
        self.max_blocks = -(-max_total_len // block_size)
        if num_blocks is None:
            num_blocks = capacity * self.max_blocks + 1  # +1 scratch
        self.kv = PagedKVCache(cfg, num_blocks, block_size)

        self._prefill, self._decode = _jit_steps(model)

    # -- sizing ------------------------------------------------------------

    def total_len(self, r: Request) -> int:
        """Cache positions a request needs: prompt + all but the last
        generated token (the last one is sampled but never cached)."""
        return len(r.prompt) + r.max_new - 1

    def prompt_bucket(self, p: int) -> int:
        return _pow2_at_least(max(p, self.block_size), self.block_size)

    def bucket(self, active: int) -> int:
        for b in self.buckets:
            if active <= b:
                return b
        raise AssertionError(f"{active} active > capacity {self.capacity}")

    # -- the loop ----------------------------------------------------------

    def run(self, requests: list[Request]) -> tuple[dict[int, RequestResult], ServeStats]:
        for r in requests:
            need = self.total_len(r)
            if self.kv.blocks_for(need) > min(self.max_blocks,
                                              self.kv.num_blocks - 1):
                raise ValueError(
                    f"request {r.rid} needs {need} cache positions; pool is "
                    f"{self.max_blocks} blocks x {self.block_size}")
        pending = deque(sorted(requests, key=lambda r: (r.arrival, r.rid)))
        waiting: deque[Request] = deque()
        slots: list[_Slot] = []
        C, nb = self.capacity, self.max_blocks
        tables = np.zeros((C, nb), np.int32)      # pad rows -> scratch block
        positions = np.zeros((C,), np.int32)
        tok = jnp.zeros((C,), jnp.int32)          # device-resident slot tokens
        pool_k, pool_v = self.kv.k, self.kv.v
        step = 0
        tokens_out = 0
        # per decode step: (token array (bucket,), rids of active slots);
        # prefill first-tokens ride the same list — everything is fetched
        # from device in ONE transfer after the loop (`drain`), never per step
        emitted: list[tuple[jax.Array, tuple[int, ...]]] = []
        results: dict[int, RequestResult] = {}
        stats = ServeStats(capacity=C)

        def note_event():
            jax.block_until_ready(tok)
            stats.events.append((stats.steps, tokens_out, time.perf_counter()))

        def evict_finished():
            nonlocal tok
            done = [i for i, s in enumerate(slots) if s.remaining == 0]
            for i in reversed(done):  # compact from the back: swap-with-last
                s = slots[i]
                results[s.rid] = RequestResult(
                    rid=s.rid, tokens=None, admitted_step=s.admitted_step,
                    finished_step=step)
                self.kv.free(s.blocks)
                last = len(slots) - 1
                if i != last:
                    slots[i] = slots[last]
                    tables[i] = tables[last]
                    positions[i] = positions[last]
                    tok = tok.at[i].set(tok[last])
                slots.pop()
                tables[len(slots)] = 0
                positions[len(slots)] = 0
            return bool(done)

        note_event()
        while pending or waiting or slots:
            while pending and pending[0].arrival <= step:
                waiting.append(pending.popleft())
            synced = False
            while waiting and len(slots) < C:
                r = waiting[0]
                blocks = self.kv.alloc(self.total_len(r))
                if blocks is None:
                    break  # pool exhausted: FIFO-wait for evictions
                waiting.popleft()
                tok, pool_k, pool_v, first = self._admit(
                    r, len(slots), blocks, slots, tables, positions, tok,
                    pool_k, pool_v, step)
                emitted.append((first, (r.rid,)))
                tokens_out += 1
                stats.prefills += 1
                synced |= evict_finished()  # max_new == 1: done at prefill
                synced = True
            if synced:
                note_event()
            if not slots:
                if pending:
                    step = max(step, pending[0].arrival)  # idle: skip ahead
                    continue
                if waiting:
                    raise AssertionError(
                        "empty slot table but queued requests: pool cannot "
                        "satisfy an admissible request")
                break
            b = self.bucket(len(slots))
            tok_b, pool_k, pool_v = self._decode(
                self.params, pool_k, pool_v,
                jnp.asarray(tables[:b]), jnp.asarray(positions[:b]), tok[:b])
            tok = tok.at[:b].set(tok_b)
            step += 1
            stats.steps += 1
            stats.active_per_step.append(len(slots))
            stats.bucket_per_step.append(b)
            emitted.append((tok_b, tuple(s.rid for s in slots)))
            tokens_out += len(slots)
            for s in slots:
                s.pos += 1
                s.remaining -= 1
            positions[:len(slots)] += 1
            if evict_finished():
                note_event()
        note_event()
        self.kv.k, self.kv.v = pool_k, pool_v
        stats.tokens = tokens_out
        self._drain(emitted, results)
        return results, stats

    def _admit(self, r: Request, row: int, blocks: list[int], slots, tables,
               positions, tok, pool_k, pool_v, step: int):
        """Prefill one request into ``row``: pad the prompt to its length
        bucket, scatter K/V through a prefill block table (entries past the
        allocation -> scratch), and seed the slot with the first sampled
        token."""
        p = len(r.prompt)
        sb = self.prompt_bucket(p)
        prompt = np.zeros((1, sb), np.int32)
        prompt[0, :p] = r.prompt
        nb_p = sb // self.block_size
        ptable = np.zeros((1, nb_p), np.int32)
        for j in range(min(nb_p, len(blocks))):
            ptable[0, j] = blocks[j]
        first, pool_k, pool_v = self._prefill(
            self.params, jnp.asarray(prompt),
            jnp.asarray(np.array([p], np.int32)), jnp.asarray(ptable),
            pool_k, pool_v)
        tables[row] = 0
        tables[row, :len(blocks)] = blocks
        positions[row] = p
        tok = tok.at[row].set(first[0])
        slots.append(_Slot(rid=r.rid, pos=p, remaining=r.max_new - 1,
                           blocks=blocks, admitted_step=step))
        return tok, pool_k, pool_v, first

    def _drain(self, emitted, results) -> None:
        """One device->host transfer for every token of the run, then
        scatter them back into per-request streams."""
        host = jax.device_get([t for t, _ in emitted])
        streams: dict[int, list[int]] = {}
        for arr, (_, rids) in zip(host, emitted):
            for i, rid in enumerate(rids):
                streams.setdefault(rid, []).append(int(arr[i]))
        for rid, toks in streams.items():
            results[rid].tokens = np.asarray(toks, np.int32)


def run_fixed_batch(model, params, requests: list[Request], *,
                    cache_len: int | None = None):
    """The pre-scheduler fixed-batch serving loop, kept as the benchmark
    baseline: every prompt right-padded to the longest, one joint prefill,
    then ``max(max_new)`` decode steps for the whole batch — early
    finishers burn their row until the last request completes.  Tokens stay
    on device until one final transfer (the old loop's per-step
    ``np.asarray`` host sync is gone here too).

    Note the classic semantics: with mixed prompt lengths the joint prefill
    samples every row at the padded last column, so this is a throughput
    baseline, not a correctness reference — the sequential reference for
    that is per-request classic decode (see ``launch.serve``).
    """
    B = len(requests)
    pmax = max(len(r.prompt) for r in requests)
    gmax = max(r.max_new for r in requests)
    if cache_len is None:
        cache_len = _pow2_at_least(pmax + gmax, 16)
    prompt = np.zeros((B, pmax), np.int32)
    for i, r in enumerate(requests):
        prompt[i, :len(r.prompt)] = r.prompt
    # same per-model jit caching as the scheduler path, so repeat baseline
    # runs (warm-up + measured) don't recompile and the comparison is honest
    cached = getattr(model, "_classic_jit_steps", None)
    if cached is None or cached[0] != cache_len:
        prefill = jax.jit(make_prefill_step(model, cache_len))
        decode = jax.jit(make_decode_step(model), donate_argnums=(1,))
        model._classic_jit_steps = cached = (cache_len, prefill, decode)
    _, prefill, decode = cached

    t0 = time.perf_counter()
    cache, last = prefill(params, {"tokens": jnp.asarray(prompt)})
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    outs = [tok]
    for _ in range(gmax - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(tok)
    wall = time.perf_counter() - t0

    host = np.stack(jax.device_get(outs), axis=1)  # (B, gmax)
    results = {r.rid: host[i, :r.max_new] for i, r in enumerate(requests)}
    useful = sum(r.max_new for r in requests)
    return results, {"walltime_s": wall, "useful_tokens": useful,
                     "row_steps": B * gmax, "decode_steps": gmax - 1}
