"""Continuous-batching serve scheduler over the paged KV cache.

The previous serving loop was fixed-batch: all requests prefill together,
decode runs ``max(gen)`` steps for everyone, and a request finishing early
keeps burning its row until the slowest one is done.  This module replaces
it with the production shape:

  * a **request queue** with per-step admission — finished requests are
    evicted the step they complete and freed slots are refilled from the
    queue, so the decode batch tracks the live load;
  * a **slot table** of fixed capacity: slot state (block table, position,
    last token) lives in compacted host arrays sliced to the active bucket
    each step, so jit only ever sees one shape per bucket;
  * **bucket-quantized decode**: the live batch is padded up to the
    smallest tuned batch-size bucket (``core.cmu.DECODE_BUCKETS`` capped at
    the slot capacity) and each bucket dispatches its own pre-tuned CMU
    decode sub-plan — the PR-4 skinny-bm geometries — via
    ``LayerPlan.decode_plan``;
  * **prefill/decode disaggregation**: prefill runs one request at a time
    at a pow2-of-block-size padded prompt length (one jit signature per
    length bucket), scattering K/V straight into the paged block pools;
    decode never sees a prompt.  Cross-request prefill batching is left
    out deliberately: rows of a batched GEMM under a *different* bucket
    plan are a different reduction geometry, which would break the
    batch-composition-independence guarantee the tests pin down.

Determinism contract: greedy decode here is bitwise identical to classic
per-request ``prefill``/``decode_step`` serving, independent of arrival
order, co-scheduled batch composition, and bucket padding — pad slots
write only the reserved scratch block and masked attention scores underflow
to exact zeros, so a request's stream never depends on its neighbours.
The contract holds on the Pallas decode-attention path too
(``cfg.attn_pallas``): the paged flash kernel zeroes masked probabilities
*multiplicatively* (``p = where(live, exp(s - m), 0)``) rather than relying
on additive ``-1e30`` bias underflow alone, so pad rows — whose every key
is masked — contribute exact-zero attention instead of a uniform
distribution over garbage.  ``tests/test_serving.py`` pins stream-vs-
sequential token equality per bucket with the Pallas path enabled.

Fault model (the robustness layer; see docs/serving.md): every request
ends in a terminal ``RequestStatus`` and no failure mode crashes the
trace.  An inadmissible request is **rejected** per-request; queue
overflow (``max_queue``) load-sheds the newest arrival; a request still
queued past its TTL (``deadline``) **times out**; a slot whose decode
logits go non-finite **fails** alone — its stream is truncated at the
poisoned step, its neighbours' streams stay bitwise unchanged.  Pool
starvation (organic or injected) **preempts-and-replays**: the victim's
blocks are freed and the request re-queued carrying its generated-so-far
tokens; on re-admission ``prompt + generated`` replays through prefill,
and because greedy decode is a pure function of the prefix the resumed
stream is bitwise identical to the uninterrupted run
(``RequestStatus.PREEMPTED_RESUMED``).  ``runtime.fault_injection`` makes
every one of those paths deterministically schedulable;
``tests/test_fault_serving.py`` sweeps randomized fault schedules and
pins the replay-determinism property.

Host/device sync discipline: tokens live in a device-resident slot array
and are folded back with lazy ``.at[].set``; the loop never calls
``np.asarray`` per step (the old loop's per-step host sync).  The only
blocking syncs are at admission/eviction/preemption events — where the
host must inspect schedule state anyway — and each one timestamps the
event stream that ``benchmarks/serve_bench.py`` turns into per-token
latencies.  The non-finite-logit guard rides the same discipline: decode
emits a per-row finiteness flag that accumulates device-side next to the
tokens and is inspected only at the end-of-run drain (injected poison is
additionally evicted eagerly, since the host scheduled it and needs no
readback to know).
"""

from __future__ import annotations

import enum
import logging
import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cmu import DECODE_BUCKETS
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.runtime.fault_injection import FaultPlan
from repro.runtime.kv_cache import PagedKVCache

log = logging.getLogger(__name__)

# Consecutive empty-slot-table admission retries under injected allocation
# faults before the scheduler sheds the head request instead of spinning.
STARVATION_RETRY_LIMIT = 1024


class RequestStatus(enum.Enum):
    """Terminal state of a served request.  Every request a trace hands to
    ``ServeScheduler.run`` ends in exactly one of these — the scheduler
    never raises for a per-request condition."""

    OK = "ok"                              # completed, never disturbed
    REJECTED = "rejected"                  # inadmissible or load-shed
    TIMEOUT = "timeout"                    # queue-wait TTL exceeded
    PREEMPTED_RESUMED = "preempted_resumed"  # completed after >=1 replay
    FAILED = "failed"                      # non-finite logits / no progress

    @property
    def completed(self) -> bool:
        """True when the request finished with its full token stream."""
        return self in (RequestStatus.OK, RequestStatus.PREEMPTED_RESUMED)


@dataclass
class Request:
    """One serving request: ``max_new`` greedy tokens from ``prompt``.

    ``arrival`` is a virtual timestamp in decode-step units — the scheduler
    admits a request only once its arrival step has passed, which is how
    the benchmark replays a Poisson trace without wall-clock sleeps.
    ``deadline`` (steps, from arrival) bounds the queue wait for this
    request alone; None defers to the scheduler-wide TTL."""

    rid: int
    prompt: np.ndarray
    max_new: int
    arrival: int = 0
    deadline: int | None = None


@dataclass
class RequestResult:
    rid: int
    tokens: np.ndarray | None  # filled by the end-of-run drain
    admitted_step: int         # first admission (-1 if never admitted)
    finished_step: int         # terminal step (-1 if rejected up front)
    status: RequestStatus = RequestStatus.OK
    preemptions: int = 0


@dataclass
class ServeStats:
    capacity: int
    steps: int = 0
    prefills: int = 0
    tokens: int = 0
    preemptions: int = 0
    replays: int = 0
    rejections: int = 0
    timeouts: int = 0
    failures: int = 0
    faults_injected: dict[str, int] = field(default_factory=dict)
    active_per_step: list[int] = field(default_factory=list)
    bucket_per_step: list[int] = field(default_factory=list)
    # (decode steps so far, tokens so far, perf_counter) at every sync event
    events: list[tuple[int, int, float]] = field(default_factory=list)

    @property
    def slot_utilization(self) -> float:
        if not self.steps:
            return 0.0
        return sum(self.active_per_step) / (self.steps * self.capacity)

    def bucket_histogram(self) -> dict[int, int]:
        h: dict[int, int] = {}
        for b in self.bucket_per_step:
            h[b] = h.get(b, 0) + 1
        return dict(sorted(h.items()))


@dataclass
class _Slot:
    rid: int
    pos: int        # next cache write position = tokens already cached
    remaining: int  # decode steps left (this incarnation)
    blocks: list[int]
    admitted_step: int


def _pow2_at_least(n: int, lo: int) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def _jit_steps(model):
    """Jitted (greedy prefill, greedy decode) paged steps, cached on the
    model: every ``ServeScheduler`` for the same model shares one jit cache,
    so a fresh scheduler (the benchmark builds several) never recompiles
    already-traced (prompt-bucket, batch-bucket) signatures.

    Both steps emit a per-row **finiteness flag** next to the sampled token
    (the non-finite-logit guard's observable), and decode takes a per-row
    ``poison`` mask — the fault-injection seam that overwrites a row's
    logits with NaN *inside* the step.  With the mask all-False the logits
    pass through ``where`` untouched, so the determinism contract is
    bitwise intact on the clean path."""
    cached = getattr(model, "_paged_jit_steps", None)
    if cached is not None:
        return cached
    pf = make_prefill_step(model, paged=True)
    dc = make_decode_step(model, paged=True)

    def prefill_fn(params, tokens, lens, table, pool_k, pool_v):
        last, pk, pv = pf(params, {"tokens": tokens}, lens, table, pool_k, pool_v)
        ok = jnp.isfinite(last.astype(jnp.float32)).all(-1)
        return jnp.argmax(last, -1).astype(jnp.int32), ok, pk, pv

    def decode_fn(params, pool_k, pool_v, table, positions, token, poison):
        logits, pk, pv = dc(params, pool_k, pool_v, table, positions, token)
        logits = jnp.where(poison[:, None], jnp.nan, logits)
        ok = jnp.isfinite(logits.astype(jnp.float32)).all(-1)
        return jnp.argmax(logits, -1).astype(jnp.int32), ok, pk, pv

    steps = (jax.jit(prefill_fn, donate_argnums=(4, 5)),
             jax.jit(decode_fn, donate_argnums=(1, 2)))
    model._paged_jit_steps = steps
    return steps


def serve_buckets(capacity: int) -> tuple[int, ...]:
    """The decode batch buckets for a slot capacity: every tuned bucket
    below it, plus the capacity itself."""
    return tuple(sorted({b for b in DECODE_BUCKETS if b < capacity} | {capacity}))


def poisson_trace(n: int, *, vocab: int, max_prompt: int, max_gen: int,
                  rate: float = 0.0, seed: int = 0, min_prompt: int = 4,
                  min_gen: int = 2) -> list[Request]:
    """Synthetic request trace: Poisson arrivals (exponential interarrivals
    in decode-step units; ``rate <= 0`` lands everything at step 0) with
    uniformly mixed prompt/generation lengths."""
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for i in range(n):
        if rate > 0:
            t += rng.exponential(1.0 / rate)
        p = int(rng.integers(min_prompt, max_prompt + 1))
        g = int(rng.integers(min_gen, max_gen + 1))
        prompt = rng.integers(0, vocab, size=p).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new=g, arrival=int(t)))
    return reqs


class ServeScheduler:
    """Continuous-batching greedy decoder over a paged KV cache.

    ``capacity`` slots; each admitted request gets its blocks for
    ``prompt + max_new - 1`` cache positions up front (no mid-flight OOM),
    a queue position otherwise.  ``run(requests)`` replays a trace and
    returns ``({rid: RequestResult}, ServeStats)`` with every request in a
    terminal ``RequestStatus`` — per-request failures degrade, they never
    crash the trace.

    Robustness knobs: ``deadline`` is the queue-wait TTL in decode steps
    (a request still waiting ``deadline`` steps after arrival times out;
    preempted requests re-enter the queue with a fresh arrival),
    ``max_queue`` bounds the waiting queue (the newest arrival is load-shed
    when it would overflow), and ``faults`` threads a deterministic
    ``runtime.fault_injection.FaultPlan`` through the scheduler's fault
    seams (allocation, decode logits, preemption, latency).
    """

    def __init__(self, model, params, *, capacity: int = 8,
                 block_size: int = 16, max_total_len: int,
                 num_blocks: int | None = None,
                 deadline: int | None = None,
                 max_queue: int | None = None,
                 faults: FaultPlan | None = None):
        cfg = model.cfg
        if cfg.family not in ("dense", "moe", "vlm"):
            raise NotImplementedError(
                f"continuous batching covers dense/moe/vlm, not {cfg.family}")
        self.model = model
        self.params = params
        self.capacity = capacity
        self.block_size = block_size
        self.deadline = deadline
        self.max_queue = max_queue
        self.faults = faults
        self.buckets = serve_buckets(capacity)
        # table width: blocks for the longest admissible request
        self.max_blocks = -(-max_total_len // block_size)
        if num_blocks is None:
            num_blocks = capacity * self.max_blocks + 1  # +1 scratch
        self.kv = PagedKVCache(cfg, num_blocks, block_size)
        if faults is not None:
            self.kv.allocator.fault_hook = faults.fail_alloc

        self._prefill, self._decode = _jit_steps(model)

    # -- sizing ------------------------------------------------------------

    def total_len(self, r: Request) -> int:
        """Cache positions a request needs: prompt + all but the last
        generated token (the last one is sampled but never cached)."""
        return len(r.prompt) + r.max_new - 1

    def prompt_bucket(self, p: int) -> int:
        return _pow2_at_least(max(p, self.block_size), self.block_size)

    def bucket(self, active: int) -> int:
        for b in self.buckets:
            if active <= b:
                return b
        raise AssertionError(f"{active} active > capacity {self.capacity}")

    def admissible(self, r: Request) -> bool:
        """Whether the pool could ever hold this request: its block need
        fits the table width and the (empty) pool."""
        return self.kv.blocks_for(self.total_len(r)) <= min(
            self.max_blocks, self.kv.num_blocks - 1)

    # -- the loop ----------------------------------------------------------

    def run(self, requests: list[Request]) -> tuple[dict[int, RequestResult], ServeStats]:
        results: dict[int, RequestResult] = {}
        stats = ServeStats(capacity=self.capacity)
        faults = self.faults
        if faults is not None:
            faults.reset()

        # per-request admissibility: reject the oversized request, keep the
        # trace alive (the pre-robustness scheduler raised for everyone)
        admissible: list[Request] = []
        for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            if self.admissible(r):
                admissible.append(r)
                continue
            log.warning(
                "request %d needs %d cache positions; pool is %d blocks x %d"
                " — rejected", r.rid, self.total_len(r), self.max_blocks,
                self.block_size)
            results[r.rid] = RequestResult(
                rid=r.rid, tokens=None, admitted_step=-1, finished_step=-1,
                status=RequestStatus.REJECTED)
            stats.rejections += 1

        pending = deque(admissible)
        waiting: deque[Request] = deque()
        slots: list[_Slot] = []
        origin = {r.rid: r for r in requests}   # pre-preemption identity
        first_admit: dict[int, int] = {}
        preempts: dict[int, int] = {}
        C, nb = self.capacity, self.max_blocks
        tables = np.zeros((C, nb), np.int32)      # pad rows -> scratch block
        positions = np.zeros((C,), np.int32)
        tok = jnp.zeros((C,), jnp.int32)          # device-resident slot tokens
        pool_k, pool_v = self.kv.k, self.kv.v
        step = 0
        starved = 0
        tokens_out = 0
        # per decode step: (token array (bucket,), finite flags, rids of
        # active slots); prefill first-tokens ride the same list —
        # everything is fetched from device in ONE transfer after the loop
        # (`drain`), never per step
        emitted: list[tuple[jax.Array, jax.Array, tuple[int, ...]]] = []

        def note_event():
            jax.block_until_ready(tok)
            stats.events.append((stats.steps, tokens_out, time.perf_counter()))

        def remove_slot(i: int, status: RequestStatus | None):
            """Free slot ``i`` with swap-with-last compaction.  ``status``
            None means preemption: blocks return but no result is final."""
            nonlocal tok
            s = slots[i]
            if status is not None:
                results[s.rid] = RequestResult(
                    rid=s.rid, tokens=None,
                    admitted_step=first_admit.get(s.rid, s.admitted_step),
                    finished_step=step, status=status,
                    preemptions=preempts.get(s.rid, 0))
            self.kv.free(s.blocks)
            last = len(slots) - 1
            if i != last:
                slots[i] = slots[last]
                tables[i] = tables[last]
                positions[i] = positions[last]
                tok = tok.at[i].set(tok[last])
            slots.pop()
            tables[len(slots)] = 0
            positions[len(slots)] = 0
            return s

        def evict_finished():
            done = [i for i, s in enumerate(slots) if s.remaining == 0]
            for i in reversed(done):  # compact from the back: swap-with-last
                rid = slots[i].rid
                remove_slot(i, RequestStatus.PREEMPTED_RESUMED
                            if preempts.get(rid) else RequestStatus.OK)
            return bool(done)

        def preempt(i: int):
            """Free the victim's blocks and re-queue it carrying its
            generated-so-far tokens; re-admission replays the prefix."""
            s = remove_slot(i, None)
            gen = self._generated(emitted, s.rid)
            r0 = origin[s.rid]
            resumed = Request(
                rid=s.rid, prompt=np.concatenate([r0.prompt, gen]),
                max_new=r0.max_new - len(gen), arrival=step,
                deadline=r0.deadline)
            waiting.appendleft(resumed)  # it held a slot: front of the line
            preempts[s.rid] = preempts.get(s.rid, 0) + 1
            stats.preemptions += 1

        def shed_expired():
            if self.deadline is None and all(
                    r.deadline is None for r in waiting):
                return
            kept: deque[Request] = deque()
            while waiting:
                r = waiting.popleft()
                ttl = r.deadline if r.deadline is not None else self.deadline
                if ttl is not None and step - r.arrival > ttl:
                    results[r.rid] = RequestResult(
                        rid=r.rid, tokens=None,
                        admitted_step=first_admit.get(r.rid, -1),
                        finished_step=step, status=RequestStatus.TIMEOUT,
                        preemptions=preempts.get(r.rid, 0))
                    stats.timeouts += 1
                else:
                    kept.append(r)
            waiting.extend(kept)

        note_event()
        while pending or waiting or slots:
            while pending and pending[0].arrival <= step:
                waiting.append(pending.popleft())
            shed_expired()
            synced = False
            while waiting and len(slots) < C:
                r = waiting[0]
                blocks = self.kv.alloc(self.total_len(r))
                if blocks is None:
                    break  # pool exhausted: FIFO-wait for evictions
                waiting.popleft()
                starved = 0
                first_admit.setdefault(r.rid, step)
                if preempts.get(r.rid):
                    stats.replays += 1
                tok, pool_k, pool_v, first, ok = self._admit(
                    r, len(slots), blocks, slots, tables, positions, tok,
                    pool_k, pool_v, step)
                emitted.append((first, ok, (r.rid,)))
                tokens_out += 1
                stats.prefills += 1
                synced |= evict_finished()  # max_new == 1: done at prefill
                synced = True
            # bounded admission: the queue never grows past max_queue —
            # the newest arrival is load-shed (the head keeps its FIFO turn)
            while self.max_queue is not None and len(waiting) > self.max_queue:
                r = waiting.pop()
                results[r.rid] = RequestResult(
                    rid=r.rid, tokens=None,
                    admitted_step=first_admit.get(r.rid, -1),
                    finished_step=step, status=RequestStatus.REJECTED,
                    preemptions=preempts.get(r.rid, 0))
                stats.rejections += 1
            if synced:
                note_event()
            if not slots:
                if pending and not waiting:
                    step = max(step, pending[0].arrival)  # idle: skip ahead
                    continue
                if waiting:
                    # empty slot table + a queued admissible request: only
                    # injected allocation faults (transient) or a leak can
                    # cause this.  Retry; past the retry budget, shed the
                    # head — degrade, never crash.
                    starved += 1
                    if (faults is not None and starved <= STARVATION_RETRY_LIMIT):
                        step += 1
                        continue
                    r = waiting.popleft()
                    log.error(
                        "pool cannot satisfy admissible request %d with an "
                        "empty slot table — shedding it as FAILED", r.rid)
                    results[r.rid] = RequestResult(
                        rid=r.rid, tokens=None,
                        admitted_step=first_admit.get(r.rid, -1),
                        finished_step=step, status=RequestStatus.FAILED,
                        preemptions=preempts.get(r.rid, 0))
                    continue
                break
            b = self.bucket(len(slots))
            poison = np.zeros((b,), bool)
            poisoned = None
            if faults is not None:
                dt = faults.spike()
                if dt:
                    time.sleep(dt)
                poisoned = faults.pick_poison(step, len(slots))
                if poisoned is not None:
                    poison[poisoned] = True
            tok_b, ok_b, pool_k, pool_v = self._decode(
                self.params, pool_k, pool_v,
                jnp.asarray(tables[:b]), jnp.asarray(positions[:b]), tok[:b],
                jnp.asarray(poison))
            tok = tok.at[:b].set(tok_b)
            step += 1
            stats.steps += 1
            stats.active_per_step.append(len(slots))
            stats.bucket_per_step.append(b)
            emitted.append((tok_b, ok_b, tuple(s.rid for s in slots)))
            tokens_out += len(slots)
            for s in slots:
                s.pos += 1
                s.remaining -= 1
            positions[:len(slots)] += 1
            if poisoned is not None:
                # the host scheduled this poison: evict the failed slot
                # eagerly (no readback needed); the drain truncates its
                # stream at the poisoned token via the finiteness flags
                remove_slot(poisoned, RequestStatus.FAILED)
                synced = True
            else:
                synced = False
            synced |= evict_finished()
            if faults is not None and slots:
                victim = faults.pick_preempt(step, len(slots))
                if victim is not None:
                    note_event()  # the replay prefix needs a token readback
                    preempt(victim)
                    synced = True
            if synced:
                note_event()
        note_event()
        self.kv.k, self.kv.v = pool_k, pool_v
        stats.tokens = tokens_out
        self._drain(emitted, results)
        stats.failures = sum(
            1 for res in results.values()
            if res.status is RequestStatus.FAILED)
        if faults is not None:
            stats.faults_injected = dict(faults.injected)
        missing = {r.rid for r in requests} - set(results)
        assert not missing, f"requests {missing} ended without a status"
        return results, stats

    def _admit(self, r: Request, row: int, blocks: list[int], slots, tables,
               positions, tok, pool_k, pool_v, step: int):
        """Prefill one request into ``row``: pad the prompt to its length
        bucket, scatter K/V through a prefill block table (entries past the
        allocation -> scratch), and seed the slot with the first sampled
        token."""
        p = len(r.prompt)
        sb = self.prompt_bucket(p)
        prompt = np.zeros((1, sb), np.int32)
        prompt[0, :p] = r.prompt
        nb_p = sb // self.block_size
        ptable = np.zeros((1, nb_p), np.int32)
        for j in range(min(nb_p, len(blocks))):
            ptable[0, j] = blocks[j]
        first, ok, pool_k, pool_v = self._prefill(
            self.params, jnp.asarray(prompt),
            jnp.asarray(np.array([p], np.int32)), jnp.asarray(ptable),
            pool_k, pool_v)
        tables[row] = 0
        tables[row, :len(blocks)] = blocks
        positions[row] = p
        tok = tok.at[row].set(first[0])
        slots.append(_Slot(rid=r.rid, pos=p, remaining=r.max_new - 1,
                           blocks=blocks, admitted_step=step))
        return tok, pool_k, pool_v, first, ok

    def _generated(self, emitted, rid: int) -> np.ndarray:
        """This request's generated-so-far tokens (all incarnations), read
        back from the emitted stream — the replay prefix for preemption."""
        picks = [(j, rids.index(rid)) for j, (_, _, rids) in enumerate(emitted)
                 if rid in rids]
        host = jax.device_get([emitted[j][0] for j, _ in picks])
        return np.asarray([int(a[col]) for a, (_, col) in zip(host, picks)],
                          np.int32)

    def _drain(self, emitted, results) -> None:
        """One device->host transfer for every token of the run, then
        scatter them back into per-request streams.  The non-finite-logit
        guard lands here: a stream whose finiteness flag dropped is
        truncated at the first poisoned token and its request marked
        FAILED — neighbours' streams are untouched."""
        host_tok = jax.device_get([t for t, _, _ in emitted])
        host_ok = jax.device_get([o for _, o, _ in emitted])
        streams: dict[int, list[int]] = {}
        fine: dict[int, list[bool]] = {}
        for arr, oks, (_, _, rids) in zip(host_tok, host_ok, emitted):
            for i, rid in enumerate(rids):
                streams.setdefault(rid, []).append(int(arr[i]))
                fine.setdefault(rid, []).append(bool(oks[i]))
        for rid, toks in streams.items():
            flags = fine[rid]
            if all(flags):
                results[rid].tokens = np.asarray(toks, np.int32)
            else:
                bad = flags.index(False)
                results[rid].tokens = np.asarray(toks[:bad], np.int32)
                results[rid].status = RequestStatus.FAILED


def run_fixed_batch(model, params, requests: list[Request], *,
                    cache_len: int | None = None):
    """The pre-scheduler fixed-batch serving loop, kept as the benchmark
    baseline: every prompt right-padded to the longest, one joint prefill,
    then ``max(max_new)`` decode steps for the whole batch — early
    finishers burn their row until the last request completes.  Tokens stay
    on device until one final transfer (the old loop's per-step
    ``np.asarray`` host sync is gone here too).

    Note the classic semantics: with mixed prompt lengths the joint prefill
    samples every row at the padded last column, so this is a throughput
    baseline, not a correctness reference — the sequential reference for
    that is per-request classic decode (see ``launch.serve``).
    """
    B = len(requests)
    pmax = max(len(r.prompt) for r in requests)
    gmax = max(r.max_new for r in requests)
    if cache_len is None:
        cache_len = _pow2_at_least(pmax + gmax, 16)
    prompt = np.zeros((B, pmax), np.int32)
    for i, r in enumerate(requests):
        prompt[i, :len(r.prompt)] = r.prompt
    # same per-model jit caching as the scheduler path, so repeat baseline
    # runs (warm-up + measured) don't recompile and the comparison is honest
    cached = getattr(model, "_classic_jit_steps", None)
    if cached is None or cached[0] != cache_len:
        prefill = jax.jit(make_prefill_step(model, cache_len))
        decode = jax.jit(make_decode_step(model), donate_argnums=(1,))
        model._classic_jit_steps = cached = (cache_len, prefill, decode)
    _, prefill, decode = cached

    t0 = time.perf_counter()
    cache, last = prefill(params, {"tokens": jnp.asarray(prompt)})
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    outs = [tok]
    for _ in range(gmax - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(tok)
    wall = time.perf_counter() - t0

    host = np.stack(jax.device_get(outs), axis=1)  # (B, gmax)
    results = {r.rid: host[i, :r.max_new] for i, r in enumerate(requests)}
    useful = sum(r.max_new for r in requests)
    return results, {"walltime_s": wall, "useful_tokens": useful,
                     "row_steps": B * gmax, "decode_steps": gmax - 1}
