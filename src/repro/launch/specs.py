"""ShapeDtypeStruct input specs + shardings for every (arch x shape) cell.

``input_specs`` returns weak-type-correct, shardable stand-ins for every
model input — no device allocation, the shannon/kernels pattern.  Cache
specs for decode cells are derived with ``jax.eval_shape`` over the prefill
function, so they always match the model's real cache structure.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, ShapeCell
from repro.launch.mesh import dp_axes, dp_size
from repro.models.config import ModelConfig
from repro.models.registry import get_config
from repro.models.transformer import Model

SDS = jax.ShapeDtypeStruct


def batch_specs(cfg: ModelConfig, cell: ShapeCell) -> dict[str, SDS]:
    B, S = cell.global_batch, cell.seq_len
    specs: dict[str, SDS] = {}
    if cfg.family == "vlm" and cell.step != "decode":
        text = S - cfg.vision_tokens
        specs["tokens"] = SDS((B, text), jnp.int32)
        specs["vision_embeds"] = SDS(
            (B, cfg.vision_tokens, cfg.vision_embed_dim or cfg.d_model), jnp.float32
        )
        if cell.step == "train":
            specs["labels"] = SDS((B, text), jnp.int32)
        return specs
    specs["tokens"] = SDS((B, S), jnp.int32)
    if cfg.family == "encdec":
        specs["audio_embeds"] = SDS((B, cfg.enc_seq_len, cfg.d_model), jnp.float32)
    if cell.step == "train":
        specs["labels"] = SDS((B, S), jnp.int32)
    return specs


def batch_shardings(mesh, specs: dict[str, SDS]) -> dict[str, NamedSharding]:
    dp = dp_axes(mesh)
    out = {}
    for k, v in specs.items():
        spec = [dp] + [None] * (len(v.shape) - 1)
        if v.shape[0] % dp_size(mesh):
            spec[0] = None
        out[k] = NamedSharding(mesh, P(*spec))
    return out


# ---------------------------------------------------------------------------
# cache shardings (decode cells)
# ---------------------------------------------------------------------------

_CACHE_RULES: list[tuple[str, tuple[Any, ...]]] = [
    # (path regex, dims tags: 'B' batch, 'S' seq (model-fallback), 'H' heads)
    (r"kv/(k|v)$", (None, "B", "S", "H", None)),
    (r"cross_(k|v)$", (None, "B", "S", "H", None)),
    (r"mamba/conv$", (None, "B", None, "H")),
    (r"mamba/ssm$", (None, "B", "H", None, None)),
    (r"states/wkv$", (None, "B", "H", None, None)),
    (r"states/shift_(t|c)$", (None, "B", "H")),
    (r"pos$", ()),
]


def cache_shardings(mesh, cache_sds: Any) -> Any:
    """Path-rule shardings for a decode cache tree.

    Batch shards over DP when divisible (else long_500k's B=1 falls back to
    sharding the KV sequence over 'data').  The 'model' axis goes on the
    kv-head dim when head count divides it, else on the sequence dim — GQA
    archs with 1-8 kv heads can't split 16 ways, but their 32k-token caches
    can (the attention then runs with a sharded-KV softmax).
    """
    dp = dp_axes(mesh)
    tp = mesh.shape["model"] if "model" in mesh.axis_names else 1

    def leaf(path, x):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "name", ""))) for k in path)
        for pat, dims in _CACHE_RULES:
            if re.search(pat, pstr):
                spec: list[Any] = [None] * len(dims)
                b_ok = False
                h_ok = False
                for i, d in enumerate(dims):
                    if d == "B" and x.shape[i] % dp_size(mesh) == 0:
                        spec[i] = dp
                        b_ok = True
                    elif d == "H" and x.shape[i] % tp == 0:
                        spec[i] = "model"
                        h_ok = True
                for i, d in enumerate(dims):
                    if d != "S":
                        continue
                    axes = []
                    if not h_ok:
                        axes.append("model")  # model axis falls back to seq
                    if not b_ok and "data" in mesh.axis_names:
                        axes.append("data")   # B=1 long-context: seq takes data too
                    import math as _m

                    ext = _m.prod(mesh.shape[a] for a in axes) if axes else 1
                    if axes and x.shape[i] % ext == 0:
                        spec[i] = tuple(axes) if len(axes) > 1 else axes[0]
                return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(leaf, cache_sds)


def rules_for(arch: str, shape: str) -> dict | None:
    """Per-(arch, shape) sharding-rule overrides — the mesh-level CMU output.

    zamba2's training step is SSM-dominated (sequence-serial token mixing):
    the §Perf hillclimb showed the IS mesh-dataflow (activations stationary,
    batch over data x model, weights gathered ZeRO-3 style) cuts the
    collective term 8.7x and memory 1.9x vs the default WS/SP rules
    (EXPERIMENTS.md §Perf A1-A3). None -> DEFAULT_RULES.
    """
    from repro.models.sharding import DEFAULT_RULES

    if arch == "zamba2_7b" and shape == "train_4k":
        return dict(
            DEFAULT_RULES,
            act_batch=("data", "model"), act_seq=None, act_seq_np=None,
            act_heads=None, act_expert=None, act_vocab=None,
        )
    return None


def model_for_cell(arch: str, shape: str, *, remat: str = "full", unroll: bool = False,
                   overrides: dict | None = None) -> tuple[Model, ShapeCell]:
    cell = SHAPES[shape]
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    return Model(cfg, remat=remat if cell.step == "train" else "none", unroll=unroll), cell


def token_count(cfg: ModelConfig, cell: ShapeCell) -> int:
    if cfg.family == "vlm" and cell.step != "decode":
        return cell.global_batch * cell.seq_len  # vision prefix + text
    return cell.global_batch * cell.seq_len
