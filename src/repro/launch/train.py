"""End-to-end training driver.

CPU-friendly by default (--smoke); the same flags drive a real pod:

  PYTHONPATH=src python -m repro.launch.train --arch qwen3_4b --smoke \
      --steps 200 --global-batch 8 --seq 128 --ckpt-dir /tmp/run1

Fault tolerance: the loop runs under runtime.TrainRunner — kill/restart the
process and it resumes from the last committed checkpoint; --fail-at N
injects a SimulatedNodeFailure to exercise that path in one invocation.
"""

from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp

from repro.data import DataConfig, TokenStream
from repro.launch.steps import (
    init_train_state,
    make_train_step,
    microbatches_for,
    setup_plan_cache,
    use_quantized_opt,
)
from repro.models import Model, get_config
from repro.runtime import RunnerConfig, SimulatedNodeFailure, TrainRunner


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=0, help="0 = per-arch default")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=-1, help="inject a node failure")
    ap.add_argument("--d-model", type=int, default=0, help="override width")
    ap.add_argument("--layers", type=int, default=0, help="override depth")
    ap.add_argument("--plan-cache", default="",
                    help="CMU plan JSON: reload if present, else autotune + save")
    ap.add_argument("--pallas", action="store_true",
                    help="dispatch projections — forward AND backward GEMMs "
                         "— to the fused flex kernels via the custom VJP; "
                         "the plan cache then carries per-layer fwd/dX/dW "
                         "sub-plans")
    ap.add_argument("--mesh", default="",
                    help="'DxM' data x model mesh (e.g. 2x4): train "
                         "multi-device — with --pallas the projections run "
                         "the shard_map-composed mesh-native kernel path")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    cfg = get_config(args.arch, smoke=args.smoke)
    if args.d_model:
        cfg = cfg.replace(d_model=args.d_model)
    if args.layers:
        cfg = cfg.replace(num_layers=args.layers)
    if args.pallas:
        cfg = cfg.replace(use_pallas=True)

    import contextlib

    from repro.launch.serve import parse_mesh

    mesh = parse_mesh(args.mesh)
    if mesh is not None:
        from repro.models.sharding import use_rules

        rules_ctx = use_rules(mesh)
    else:
        rules_ctx = contextlib.nullcontext()
    with rules_ctx:
        _train(args, cfg, mesh)


def _train(args, cfg, mesh) -> None:
    mb = args.microbatches or microbatches_for(args.arch)
    mb = mb if args.global_batch % max(mb, 1) == 0 else 1
    # training plans group each layer's three GEMMs (fwd + dX + dW) so the
    # backward pass reconfigures per layer too; under grad accumulation each
    # GEMM runs per microbatch, so that is the geometry to tune for
    setup_plan_cache(args.plan_cache, cfg,
                     args.global_batch // max(mb, 1) * args.seq,
                     train=args.pallas, mesh=mesh)
    model = Model(cfg)
    total, active = cfg.param_count()
    print(f"arch={cfg.name} params={total/1e6:.1f}M (active {active/1e6:.1f}M)")

    stream = TokenStream(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.global_batch)
    )
    jit_step = jax.jit(
        make_train_step(
            model, peak_lr=args.lr, warmup=args.warmup,
            total_steps=args.steps, microbatches=mb,
        ),
        donate_argnums=(0, 1),
    )

    def init():
        params, opt = init_train_state(
            model, jax.random.PRNGKey(0), quantize_opt=use_quantized_opt(args.arch)
        )
        if mesh is not None:
            from repro.models.sharding import param_shardings

            params = jax.device_put(params, param_shardings(params))
        return {"params": params, "opt": opt}

    times = []

    def step_fn(state, i):
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in stream.batch(i).items()}
        params, opt, metrics = jit_step(state["params"], state["opt"], batch)
        loss = float(metrics["loss"])
        times.append(time.time() - t0)
        if i % 10 == 0 or i == args.steps - 1:
            tps = args.global_batch * args.seq / max(times[-1], 1e-9)
            print(f"step {i:5d} loss {loss:.4f} lr {float(metrics['lr']):.2e} "
                  f"{times[-1]*1e3:.0f} ms/step {tps:,.0f} tok/s")
        return {"params": params, "opt": opt}, {"loss": loss}

    hook = None
    if args.fail_at >= 0:
        fired = []

        def hook(step):  # noqa: ANN001
            if step == args.fail_at and not fired:
                fired.append(1)
                raise SimulatedNodeFailure(f"injected at step {step}")

    runner = TrainRunner(
        step_fn, init,
        RunnerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     max_steps=args.steps),
        failure_hook=hook,
    )
    state, step = runner.run()
    losses = [m["loss"] for m in runner.metrics_log]
    trajectory = (f"loss {losses[0]:.4f} -> {losses[-1]:.4f}" if losses
                  else "no new steps (checkpoint already at --steps)")
    print(f"done: {step} steps, restarts={runner.restarts}, {trajectory}")


if __name__ == "__main__":
    main()
