"""Step-function builders (train / prefill / decode) shared by the dry-run,
the real training driver, and the serving driver."""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from repro.optim import adamw_init, adamw_update
from repro.optim.schedules import cosine, wsd


def make_train_step(
    model: Model,
    *,
    schedule: Callable | None = None,
    peak_lr: float = 3e-4,
    total_steps: int = 10_000,
    warmup: int = 100,
    weight_decay: float = 0.1,
    microbatches: int = 1,
    compute_dtype: str | None = "bfloat16",
) -> Callable:
    """Build the jittable train step.

    ``microbatches > 1`` runs gradient accumulation over a lax.scan: the
    global batch is split along dim 0, grads accumulate in f32 — this is what
    fits the 100B+ MoE configs' activations in per-chip HBM (DESIGN.md §5).

    ``compute_dtype='bfloat16'`` casts f32 master params once at step entry,
    so FSDP weight all-gathers move bf16 (half the ICI bytes) while the
    optimizer still updates f32 masters (§Perf C2).
    """
    sched = schedule or (
        (lambda s: wsd(s, total_steps, peak_lr, warmup))
        if model.cfg.name.startswith("minicpm")  # minicpm's WSD schedule
        else (lambda s: cosine(s, total_steps, peak_lr, warmup))
    )

    from repro.models.sharding import constrain

    cdt = jnp.dtype(compute_dtype) if compute_dtype else None

    def loss_fn(params, batch):
        if cdt is not None:
            params = jax.tree.map(
                lambda p: p.astype(cdt)
                if p.dtype == jnp.float32 and p.ndim >= 2 else p,
                params,
            )
        return model.loss(params, batch)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            mb = jax.tree.map(
                lambda a: a.reshape(microbatches, a.shape[0] // microbatches, *a.shape[1:]),
                batch,
            )
            mb = jax.tree.map(
                lambda a: constrain(a, None, "act_batch", *([None] * (a.ndim - 2))), mb
            )

            def body(acc, one):
                g_acc, l_acc = acc
                one = jax.tree.map(
                    lambda a: constrain(a, "act_batch", *([None] * (a.ndim - 1))), one
                )
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, one)
                g_acc = jax.tree.map(lambda A, G: A + G.astype(A.dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g_sum, l_sum), _ = jax.lax.scan(body, (zero_g, jnp.zeros(())), mb)
            grads = jax.tree.map(lambda g: g / microbatches, g_sum)
            loss, metrics = l_sum / microbatches, {}
        lr = sched(opt_state.step)
        params, opt_state = adamw_update(
            params, grads, opt_state, lr, weight_decay=weight_decay
        )
        return params, opt_state, {"loss": loss, "lr": lr, **metrics}

    return train_step


def microbatches_for(arch_name: str, step: str = "train") -> int:
    """Grad-accumulation depth per arch (memory fit on 16GB/chip v5e)."""
    if step != "train":
        return 1
    return {"arctic_480b": 8, "qwen3_moe_235b": 4, "gemma3_12b": 2}.get(arch_name, 1)


def make_prefill_step(model: Model, cache_len: int | None = None, *,
                      paged: bool = False) -> Callable:
    """Prefill step builder.

    Classic form (``cache_len``): (params, batch) -> (dense cache, last
    logits).  With ``paged=True`` the step is the disaggregated-serving
    prefill instead: (params, batch, lens, table, pool_k, pool_v) ->
    (per-row last real logits, updated pools) — the prompt is forwarded at
    its padded bucket length, K/V scattered into the KV block pools through
    ``table``, and the logits row picked at each request's true last token
    (``lens - 1``), so prompt-length bucketing never changes the sampled
    token.
    """
    if paged:
        from repro.runtime.kv_cache import write_prefill_blocks

        def prefill_paged(params, batch, lens, table, pool_k, pool_v):
            logits, k_all, v_all = model.prefill_kv(params, batch)
            pool_k, pool_v = write_prefill_blocks(pool_k, pool_v, k_all, v_all, table)
            B = k_all.shape[1]
            last = logits[jnp.arange(B), lens - 1]
            return last, pool_k, pool_v

        return prefill_paged

    def prefill(params, batch):
        return model.prefill(params, batch, cache_len)

    return prefill


def make_decode_step(model: Model, *, paged: bool = False) -> Callable:
    """Decode step builder.  Classic form: (params, cache, token) ->
    (logits, cache).  With ``paged=True``: (params, pool_k, pool_v, table,
    positions, token) -> (logits, pool_k, pool_v) — the continuous-batching
    step over the paged KV block pools (per-slot block tables + positions,
    one jit signature per batch bucket)."""
    if paged:
        def decode_paged(params, pool_k, pool_v, table, positions, token):
            logits, pools = model.decode_step_paged(
                params, {"k": pool_k, "v": pool_v}, table, positions, token)
            return logits, pools["k"], pools["v"]

        return decode_paged

    def decode(params, cache, token):
        return model.decode_step(params, cache, token)

    return decode


def setup_plan_cache(path: str | None, cfg, tokens: int, *, measure: bool = True,
                     train: bool = False, mesh=None,
                     decode_buckets: tuple[int, ...] | None = None,
                     quant: tuple[str, ...] | None = None,
                     quant_budget: float | None = None):
    """Program the CMU for a serve/train run.

    Loads the persisted ``DataflowPlan`` from ``path`` when it exists;
    otherwise runs the measured autotune over the config's GEMMs and saves
    the winner to ``path`` so the next launch skips tuning.  The activated
    plan drives every ``models.layers.linear`` dispatch when the config runs
    with ``use_pallas``.  With ``train=True`` the plan must carry per-layer
    backward sub-plans (the fwd + dX + dW group) — a fwd-only cache is
    re-tuned, so ``--pallas`` training never runs unplanned backward GEMMs.
    Forward candidates are measured with each layer's actual fused-epilogue
    signature (``model_epilogues``), so the tuner times the op the model
    issues rather than the bare matmul.

    With ``mesh`` (a ``jax.sharding.Mesh`` the run executes under) the plan
    additionally carries per-layer **mesh sub-plans** — the second CMU
    level: mesh dataflow + local per-shard kernel geometry — keyed by the
    mesh fingerprint (``MeshSpec``).  A cache tuned for another topology
    (or a migrated single-device v1–v4 file) is upgraded incrementally:
    the single-device decisions are kept verbatim and only the mesh level
    is tuned.  Returns the plan (or None when no path given).

    With ``decode_buckets`` (a serving run's batch-size buckets) the plan
    additionally carries per-layer **decode sub-plans** — skinny-M kernel
    geometries tuned at M = bucket for every bucket, all up front, so the
    scheduler's bucket-quantized decode steps never hit an unplanned
    geometry at runtime.  A cache lacking some buckets (e.g. a v5 file, or
    a run widening its slot count) is likewise upgraded incrementally.

    When the config runs attention through the flex kernel family
    (``attn_pallas``) the plan also carries an **attention schedule** on the
    ``attn.wq`` anchor row: prefill sweep order + ``(bq, bk)`` block sizes,
    plus per-bucket decode sub-plans (Pallas paged kernel vs jnp gather)
    mirroring the GEMM decode dict.  v1–v6 caches load with the attention
    row absent and are upgraded incrementally — every existing GEMM, mesh
    and decode decision survives verbatim.

    When the config runs its recurrent mixer through the flex scan family
    (``ssm_pallas``, for the ssm/hybrid families) the plan also carries a
    **chunked-scan schedule** on the ``lm_head`` anchor row: state-residency
    sweep + chunk length for prefill, plus per-bucket decode sub-plans
    (fused Pallas step kernel vs jnp recurrence).  v1–v7 caches load with
    the scan row absent and are upgraded the same incremental way.

    With ``quant`` (``serve --quant``'s dtype tuple) the forward rows and
    decode sub-plans additionally carry **quant verdicts**: each layer is
    accuracy-gated (``measure_quant_error`` under ``quant_budget``) and
    either dispatches a weight-quantized kernel ("int8"/"fp8") or records
    the "bf16" fallback.  v1–v8 caches load with the verdicts absent and
    gain only the annotations — every schedule decision stays verbatim.
    """
    if not path:
        return None
    import logging

    from repro.core import (
        MeshSpec,
        activate_plan,
        load_or_autotune,
        model_epilogues,
        model_gemms,
    )

    mesh_spec = None
    if mesh is not None:
        from repro.launch.mesh import dp_axes

        mesh_spec = MeshSpec.from_mesh(mesh, dp_axes=dp_axes(mesh))
        if mesh_spec.tp <= 1:
            mesh_spec = None  # no tensor axis to compose over
    gemms = model_gemms(cfg, tokens)
    attn = None
    if getattr(cfg, "attn_pallas", False):
        from repro.core import model_attn_shape

        attn = model_attn_shape(cfg, tokens)
    scan = None
    if getattr(cfg, "ssm_pallas", False):
        from repro.core import model_scan_shape

        scan = model_scan_shape(cfg, tokens)  # None for attention families
    plan, loaded = load_or_autotune(path, gemms, require_bwd=train,
                                    mesh=mesh_spec, measure=measure,
                                    buckets=decode_buckets, attn=attn,
                                    scan=scan, quant=quant,
                                    quant_budget=quant_budget,
                                    epilogue=model_epilogues(cfg))
    activate_plan(plan)
    src = "loaded" if loaded else "autotuned"
    stripped = sum(
        (lp.strip > 1)
        + sum(s.strip > 1 for s in (lp.bwd_dx, lp.bwd_dw) if s is not None)
        for lp in plan.layers
    )
    meshed = {lp.mesh.dataflow.name for lp in plan.layers if lp.mesh}
    logging.getLogger(__name__).info(
        "plan cache %s: %s (%d layers%s, histogram %s, %d strip schedules%s)",
        src, path, len(plan.layers),
        " incl. bwd sub-plans" if plan.has_bwd() else "", plan.histogram(),
        stripped,
        f", mesh dataflows {sorted(meshed)} on {plan.mesh.axes}"
        if plan.mesh else "",
    )
    if decode_buckets:
        logging.getLogger(__name__).info(
            "decode sub-plans for buckets %s: %s",
            tuple(decode_buckets),
            {b: {lp.decode[b].dataflow.name for lp in plan.layers if lp.decode}
             for b in decode_buckets},
        )
    ap = plan.attention_plan() if attn is not None else None
    if ap is not None:
        logging.getLogger(__name__).info(
            "attention schedule: %s-stationary bq=%d bk=%d (%s)%s",
            ap.sweep, ap.block[0], ap.block[1], ap.source,
            f", decode kinds {({b: s.sweep for b, s in sorted(ap.decode.items())})}"
            if ap.decode else "",
        )
    sp = plan.scan_plan() if scan is not None else None
    if sp is not None:
        logging.getLogger(__name__).info(
            "scan schedule: %s-stationary chunk=%d (%s)%s",
            sp.sweep, sp.chunk, sp.source,
            f", decode kinds {({b: s.sweep for b, s in sorted(sp.decode.items())})}"
            if sp.decode else "",
        )
    if quant:
        qh: dict[str, int] = {}
        for lp in plan.layers:
            qh[lp.qdtype or "none"] = qh.get(lp.qdtype or "none", 0) + 1
            for gp in (lp.decode or {}).values():
                qh[gp.qdtype or "none"] = qh.get(gp.qdtype or "none", 0) + 1
        logging.getLogger(__name__).info(
            "quant verdicts for %s: %s (bf16 = gated/rejected fallback)",
            tuple(quant), qh,
        )
    return plan


def init_train_state(model: Model, key, quantize_opt: bool = False):
    params = model.init(key)
    opt = adamw_init(params, quantize=quantize_opt)
    return params, opt


def use_quantized_opt(arch_name: str) -> bool:
    """int8 moments for the 100B+ MoE configs (memory fit, DESIGN.md §5)."""
    return arch_name in ("arctic_480b", "qwen3_moe_235b")
