"""Mesh-level dataflow selection — the Flex-TPU insight promoted to the pod.

For a GEMM sharded over a `model`-axis of size T, there are three classic
SPMD strategies, and they are exactly the paper's three stationarities one
more level up the hierarchy (chip <-> PE, ICI <-> systolic wiring):

  WS (weight-stationary / tensor parallel):
      weights stay sharded on their chips; activations are all-gathered in
      and partial outputs reduce-scattered out.
      comm_bytes = allgather(A) + reducescatter(C)  ~  M*K + M*N   (per chip x (T-1)/T)
  IS (input-stationary / weight-gathered, ZeRO-3 style):
      activations stay put (sharded over tokens); weight shards are
      all-gathered to every chip.
      comm_bytes = allgather(B)                      ~  K*N
  OS (output-stationary):
      both A and B arrive as shards that already match the local output
      block (2D-sharded "SUMMA" step); partials accumulate locally,
      collective-permute rotates the shards.
      comm_bytes = rotate(A) + rotate(B)             ~  M*K + K*N  (pipelined)

The optimum depends on layer shape exactly as in the paper: training steps
(M = tokens >> K,N/T) prefer IS (gather the small weights), decode steps
(M ~ batch) prefer WS (move the tiny activations), and square-ish cases with
huge both prefer OS rotation.  ``plan_mesh`` is the CMU at mesh level: a
pure shape-driven offline decision, emitted into the model's sharding config.
"""

from __future__ import annotations

from dataclasses import dataclass

from .dataflow import ALL_DATAFLOWS, Dataflow, GemmShape


@dataclass(frozen=True)
class MeshGemmCost:
    dataflow: Dataflow
    comm_bytes: int      # ICI bytes per chip for this layer
    flops_per_chip: int

    def time_s(
        self, peak_flops: float = 197e12, ici_bw: float = 50e9, overlap: float = 0.0
    ) -> float:
        """Step time with `overlap` in [0,1] fraction of comm hidden under compute."""
        t_c = self.flops_per_chip / peak_flops
        t_m = self.comm_bytes / ici_bw
        return max(t_c, t_m) if overlap >= 1.0 else t_c + (1 - overlap) * t_m


def mesh_gemm_cost(
    shape: GemmShape, dataflow: Dataflow, tp: int, bytes_per_el: int = 2
) -> MeshGemmCost:
    """ICI bytes/chip + FLOPs/chip for C[M,N] = A[M,K] @ B[K,N] over tp chips."""
    M, K, N = shape.M, shape.K, shape.N
    ring = (tp - 1) / tp  # ring all-gather / reduce-scatter factor
    if dataflow is Dataflow.WS:
        comm = (M * K + M * N) * bytes_per_el * ring
    elif dataflow is Dataflow.IS:
        comm = (K * N) * bytes_per_el * ring
    elif dataflow is Dataflow.OS:
        comm = (M * K / tp + K * N / tp) * bytes_per_el * (tp - 1)
    else:  # pragma: no cover
        raise ValueError(dataflow)
    return MeshGemmCost(
        dataflow=dataflow,
        comm_bytes=int(comm),
        flops_per_chip=shape.flops // tp,
    )


def best_mesh_dataflow(
    shape: GemmShape, tp: int, overlap: float = 0.0
) -> tuple[Dataflow, MeshGemmCost]:
    costs = {df: mesh_gemm_cost(shape, df, tp) for df in ALL_DATAFLOWS}
    best = min(costs, key=lambda d: costs[d].time_s(overlap=overlap))
    return best, costs[best]


def plan_mesh(
    gemms: list[GemmShape], tp: int, overlap: float = 0.0
) -> dict[str, Dataflow]:
    """Mesh-level CMU: per-layer stationary-operand choice for a TP degree."""
    return {g.name: best_mesh_dataflow(g, tp, overlap)[0] for g in gemms}
