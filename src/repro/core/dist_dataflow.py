"""Mesh-level dataflow selection — the Flex-TPU insight promoted to the pod.

For a GEMM ``C[M,N] = A[M,K] @ B[K,N]`` whose tokens (M) are sharded over a
``model``-axis of size T and whose weight is K-sharded over the same axis,
there are three classic SPMD strategies, and they are exactly the paper's
three stationarities one more level up the hierarchy (chip <-> PE,
ICI <-> systolic wiring).  ``kernels.mesh_ops`` implements precisely these
schedules around the local Pallas kernels, and the byte formulas below are
the bytes those schedules put on the wire (per chip, ring collectives,
``r = (T-1)/T``):

  WS (weight-stationary / tensor parallel):
      weight shards never move; the activations are all-gathered in and the
      partial outputs reduce-scattered back out.  Both collectives sit on
      the critical path: A is produced by the previous layer (no prefetch)
      and C's reduction must finish before the epilogue.  The partials
      cross the wire in **f32** (4 bytes — the ICI analogue of the
      kernels' f32-accumulate policy), whatever the input dtype.
      comm_bytes    = allgather(A) + reducescatter(C_f32)
                    = (M*K*b + M*N*4) * r
      gather_bytes  = M*K*b      (full A materialised per chip)
  IS (input-stationary / weight-gathered, ZeRO-3 style):
      activations stay put (sharded over tokens); the weight shards are
      all-gathered to every chip.  Weights are static parameters, so the
      gather is prefetchable (issued during the previous layer's compute —
      the standard ZeRO-3 overlap), i.e. the comm pipelines against
      compute.
      comm_bytes    = allgather(B) = K*N * b * r
      gather_bytes  = K*N*b      (the full weight materialised per chip)
  OS (output-stationary / SUMMA ring rotation):
      nothing is gathered: each chip's output shard stays resident while
      the K-sharded weight rotates around the ring (collective-permute),
      one local partial GEMM per rotation step, partials accumulating
      locally.  A's matching k-slices are already local (the token shard
      carries full K), so only B moves.  Same total wire bytes as the IS
      gather, but delivered in T-1 pipelined hops with only a
      double-buffered shard resident — the dataflow that stays feasible
      when the gathered weight would not fit.
      comm_bytes    = rotate(B) = K*N * b * r      (pipelined, ring-period
                      floor K*N*b/bw when comm-bound)
      gather_bytes  = 2 * K*N*b / T  (double-buffered rotating shard)

The optimum depends on layer shape exactly as in the paper:

  * decode steps (M ~ batch << K, N) -> WS: moving the tiny activations
    costs almost nothing, the weights never move at all;
  * training steps (M = tokens >> K*N/(K+N), weights fit the gather
    budget) -> IS: gather the small static weights once, keep the fused
    local kernel (mesh-IS is the only schedule whose epilogue stays
    in-kernel);
  * square-ish layers where both operands are huge (the gathered weight
    exceeds ``MESH_GATHER_BUDGET_BYTES``) -> OS rotation: WS would
    materialise full A and IS full B, both infeasible — the ring keeps
    per-chip residency at 1/T and hides the rotation under the step
    compute.

``plan_mesh`` is the CMU at mesh level: a pure shape-driven offline
decision, emitted into the model's sharding config.  The local per-shard
GEMM geometry under each mesh choice is tuned by the chip-level CMU
(``cmu.autotune_plan(mesh=...)``).
"""

from __future__ import annotations

from dataclasses import dataclass

from .dataflow import ALL_DATAFLOWS, Dataflow, GemmShape

# Per-chip HBM headroom a mesh dataflow may spend on *materialised gathered
# operands* (the full A for WS, the full B for IS).  Weights, optimizer
# state, and activations own most of a chip's HBM; a per-layer gather
# beyond this is how ZeRO-3 runs out of memory mid-step, so the planner
# treats it as infeasible rather than merely slow.
MESH_GATHER_BUDGET_BYTES = 256 * 1024**2


@dataclass(frozen=True)
class MeshGemmCost:
    dataflow: Dataflow
    comm_bytes: int      # ICI bytes per chip this layer puts on the wire
    flops_per_chip: int
    gather_bytes: int    # per-chip HBM the schedule materialises
    pipelined: bool      # comm structurally overlaps compute (IS prefetch,
                         # OS rotation); WS's collectives are exposed
    ring_steps: int = 1  # kernel launches per layer (OS: one per rotation)

    def time_s(
        self, peak_flops: float = 197e12, ici_bw: float = 50e9, overlap: float = 0.0
    ) -> float:
        """Step time.  Pipelined dataflows run at ``max(compute, comm)``
        (the OS ring's comm floor is the full ring period,
        ``comm * T/(T-1)``); WS exposes its collectives, hidden only by the
        caller-asserted ``overlap`` fraction in [0, 1]."""
        t_c = self.flops_per_chip / peak_flops
        t_m = self.comm_bytes / ici_bw
        if self.pipelined:
            if self.ring_steps > 1:  # ring period: T hops pay (T-1) transfers
                t_m *= self.ring_steps / (self.ring_steps - 1)
            return max(t_c, t_m)
        return max(t_c, t_m) if overlap >= 1.0 else t_c + (1 - overlap) * t_m


def mesh_gemm_cost(
    shape: GemmShape, dataflow: Dataflow, tp: int, bytes_per_el: int = 2
) -> MeshGemmCost:
    """ICI bytes/chip + FLOPs/chip for C[M,N] = A[M,K] @ B[K,N] over tp chips.

    ``shape`` is the per-data-parallel-group GEMM (tokens already divided by
    the DP degree); ``tp`` is the tensor/model-axis extent the schedule's
    collectives run over.  The formulas are the wire bytes of the schedules
    ``kernels.mesh_ops`` actually emits — see the module docstring.
    """
    M, K, N = shape.M, shape.K, shape.N
    b = bytes_per_el
    ring = (tp - 1) / tp  # ring all-gather / reduce-scatter / rotation factor
    if dataflow is Dataflow.WS:
        # the reduce-scattered partials are f32 on the wire regardless of
        # the input dtype (kernels/mesh_ops psum-scatters the f32 partial)
        comm = (M * K * b + M * N * 4) * ring
        gather = M * K * b
        pipelined, steps = False, 1
    elif dataflow is Dataflow.IS:
        comm = (K * N) * b * ring
        gather = K * N * b
        pipelined, steps = True, 1
    elif dataflow is Dataflow.OS:
        comm = (K * N) * b * ring
        gather = 2 * K * N * b // tp
        pipelined, steps = True, tp
    else:  # pragma: no cover
        raise ValueError(dataflow)
    return MeshGemmCost(
        dataflow=dataflow,
        comm_bytes=int(comm),
        flops_per_chip=shape.flops // tp,
        gather_bytes=int(gather),
        pipelined=pipelined,
        ring_steps=steps,
    )


def best_mesh_dataflow(
    shape: GemmShape,
    tp: int,
    overlap: float = 0.0,
    gather_budget: int = MESH_GATHER_BUDGET_BYTES,
) -> tuple[Dataflow, MeshGemmCost]:
    """Mesh-level argmin for one GEMM.

    A dataflow whose ``gather_bytes`` exceed ``gather_budget`` is
    infeasible (it would materialise an operand that does not fit the
    per-chip headroom), not merely slow.  Time ties break toward fewer
    kernel launches (a fused full-K local GEMM beats tp rotation steps)
    and then toward fewer wire bytes — so compute-bound training shapes
    resolve to IS, as the gathered weight keeps the epilogue in-kernel.
    OS is always kept feasible as the escape hatch: its residency is the
    smallest any schedule can achieve.
    """
    costs = {df: mesh_gemm_cost(shape, df, tp) for df in ALL_DATAFLOWS}
    feasible = {
        df: c for df, c in costs.items()
        if c.gather_bytes <= gather_budget or df is Dataflow.OS
    }
    best = min(
        feasible,
        key=lambda d: (
            feasible[d].time_s(overlap=overlap),
            feasible[d].ring_steps,
            feasible[d].comm_bytes,
        ),
    )
    return best, costs[best]


def plan_mesh(
    gemms: list[GemmShape],
    tp: int,
    overlap: float = 0.0,
    gather_budget: int = MESH_GATHER_BUDGET_BYTES,
) -> dict[str, Dataflow]:
    """Mesh-level CMU: per-layer stationary-operand choice for a TP degree."""
    return {
        g.name: best_mesh_dataflow(g, tp, overlap, gather_budget)[0]
        for g in gemms
    }


@dataclass(frozen=True)
class MeshSpec:
    """Fingerprint of the mesh a plan was tuned for — axis names x extents
    plus which axes play the tensor and data-parallel roles.  Deliberately
    jax-free (a plain record, not a ``jax.sharding.Mesh``) so the CMU and
    the plan cache stay importable without device state; build one from a
    live mesh with ``from_mesh``.
    """

    axes: tuple[tuple[str, int], ...]
    tensor_axis: str = "model"
    dp_axes: tuple[str, ...] = ("pod", "data")

    @property
    def tp(self) -> int:
        return dict(self.axes).get(self.tensor_axis, 1)

    @property
    def dp(self) -> int:
        ext = dict(self.axes)
        out = 1
        for a in self.dp_axes:
            out *= ext.get(a, 1)
        return out

    @classmethod
    def from_mesh(cls, mesh, tensor_axis: str = "model",
                  dp_axes: tuple[str, ...] = ("pod", "data")) -> "MeshSpec":
        """From anything with ``.axis_names`` and a ``.shape`` mapping
        (a ``jax.sharding.Mesh``, or a stand-in in tests)."""
        names = tuple(mesh.axis_names)
        return cls(
            axes=tuple((a, int(mesh.shape[a])) for a in names),
            tensor_axis=tensor_axis,
            dp_axes=tuple(a for a in dp_axes if a in names),
        )

    def to_row(self) -> dict:
        return {
            "axes": [[a, e] for a, e in self.axes],
            "tensor_axis": self.tensor_axis,
            "dp_axes": list(self.dp_axes),
        }

    @classmethod
    def from_row(cls, row: dict | None) -> "MeshSpec | None":
        if row is None:
            return None
        return cls(
            axes=tuple((str(a), int(e)) for a, e in row["axes"]),
            tensor_axis=row["tensor_axis"],
            dp_axes=tuple(row["dp_axes"]),
        )
