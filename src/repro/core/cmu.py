"""Configuration Management Unit (CMU) — offline per-layer dataflow selection.

Paper Section II: "To find the optimal dataflow strategy for each layer in the
DNN, we should run each trained model on the Flex-TPU three times, once for
each dataflow, during the development phase. [...] the optimal dataflow is
then programmed into the CMU".

We implement that exact pre-deployment procedure at three levels:

* ``plan_systolic``  — the faithful reproduction: 3 simulator runs per layer,
  keep the per-layer argmin (drives Table I / Fig. 6 / Fig. 7 benchmarks).
* ``plan_kernels``   — the TPU-native port: 3 HBM-traffic evaluations per GEMM
  in an LM architecture, keep the per-layer roofline-argmin.
* ``autotune_plan``  — the production tuner: the analytical model *prunes*
  the (dataflow, block) candidate set, then each survivor is timed with real
  kernel executions (interpret-mode walltime on CPU, on-device walltime on
  TPU) — the paper's "run each model three times" made literal, per candidate.
  This mirrors FlexNN (Raha et al., 2024): per-layer dataflow selection pays
  off most when the selector is driven by measured cost, not a single
  analytical model.

**Training plans.**  ``autotune_plan(..., train=True)`` plans the *three*
GEMMs of each layer as a group — the forward ``C[M,N] = A[M,K] @ B[K,N]``
plus its two cotangent GEMMs ``dX = dY @ W^T`` ((M,N)x(N,K)) and
``dW = X^T @ dY`` ((K,M)x(M,N)).  The backward shapes transpose the
forward's aspect ratio, so they generally want *different* dataflows (e.g.
a WS-favouring tall fwd GEMM yields an OS-favouring dW) — the paper's
per-layer reconfiguration argument applied within a single training step.
The sub-plans land in ``LayerPlan.bwd_dx`` / ``bwd_dw`` and flow through
``models.layers.linear`` into ``ops.flex_linear``'s custom VJP.

The winning ``DataflowPlan`` (now carrying block shapes and optional
backward sub-plans) is persisted as JSON via ``core.plan_cache`` so
serve/train reload plans instead of re-tuning.  All selection remains
one-time, offline, and trace-time static — exactly the paper's deployment
model (no lax.switch on the hot path).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import NamedTuple

from .dataflow import (
    ALL_DATAFLOWS,
    ATTN_BLOCK_CANDIDATES,
    SCAN_CHUNK_CANDIDATES,
    VMEM_BUDGET_BYTES,
    AttnShape,
    ConvLayer,
    Dataflow,
    GemmShape,
    ScanShape,
    attn_decode_traffic_bytes,
    attn_traffic_bytes,
    best_kernel_dataflow,
    hbm_traffic_bytes,
    kernel_block_candidates,
    scan_decode_traffic_bytes,
    scan_traffic_bytes,
    strip_blocks,
    strip_candidates,
    systolic_cycles,
    tune_kernel_dataflow,
)
from .dist_dataflow import MeshSpec, best_mesh_dataflow


class EpilogueSig(NamedTuple):
    """The epilogue signature of one layer's forward GEMM — what
    ``measure_kernel`` times when the autotune is epilogue-aware, so the
    measured op matches the op the model actually issues."""

    activation: str | None = None
    bias: bool = False
    residual: bool = False


def _epilogue_sig(epilogue) -> EpilogueSig | None:
    """Normalise a ``measure_kernel``/``autotune_plan`` epilogue argument:
    False/None -> bare matmul, True -> the legacy bias+gelu probe, an
    ``EpilogueSig`` -> itself."""
    if isinstance(epilogue, EpilogueSig):
        return epilogue
    if epilogue:
        return EpilogueSig(activation="gelu", bias=True)
    return None


# Zero-copy operand layouts of the two backward GEMM roles (trans_a, trans_b):
# dX = dY @ W^T streams W as stored via trans_b; dW = X^T @ dY streams X as
# stored via trans_a.  (False, False) is the copy-based fallback.
TRANS_DX = (False, True)
TRANS_DW = (True, False)
NO_TRANS = (False, False)

# Serving batch-size buckets the CMU keys decode GEMM plans on — the
# sublane-aligned skinny-bm candidates (kernel_block_candidates(M,
# sublane=True)), so a continuous-batching scheduler that quantizes its live
# batch to these sizes dispatches a plan whose bm never pads past the batch.
DECODE_BUCKETS = (8, 16, 32, 64)


def decode_bucket(m: int, buckets: tuple[int, ...] = DECODE_BUCKETS) -> int | None:
    """The smallest bucket that fits an ``m``-row decode GEMM, or None when
    ``m`` exceeds every bucket (prefill-sized batches keep the forward plan)."""
    for b in sorted(buckets):
        if m <= b:
            return b
    return None


#: The layer row an attention schedule rides on.  Attention is not a GEMM the
#: plan fingerprints (``plan_matches`` keys on (name, M, K, N)), so its
#: schedule attaches to the query projection's row — one attention op per
#: transformer layer shape, planned next to the projections that feed it.
ATTN_ANCHOR = "attn.wq"

#: Prefill sweep orders / decode kinds, mirroring
#: ``kernels.flash_attention.ATTN_SWEEPS`` / ``ATTN_DECODE_KINDS`` (kept as
#: literals here so the planning layer never imports kernel modules at
#: module scope).
ATTN_SWEEPS = ("q", "kv")
ATTN_DECODE_KINDS = ("paged", "gather")

#: The layer row a chunked-scan schedule rides on.  Like attention, the SSM
#: scan is not a GEMM the plan fingerprints, so its schedule attaches to the
#: one row every family emits — the lm_head projection (SSM/hybrid configs
#: have no ``attn.wq`` usage of their own; hybrid's shared block does, but
#: the scan is a property of the *backbone* layers, not that one block).
SCAN_ANCHOR = "lm_head"

#: Chunk-grid sweep orders / decode kinds, mirroring
#: ``kernels.flex_scan.SCAN_SWEEPS`` / ``SCAN_DECODE_KINDS`` (kept as
#: literals here so the planning layer never imports kernel modules at
#: module scope).
SCAN_SWEEPS = ("state", "out")
SCAN_DECODE_KINDS = ("fused", "einsum")


@dataclass(frozen=True)
class AttnPlan:
    """One flash-attention schedule decision — the attention analogue of
    ``GemmPlan``.  For the prefill row, ``sweep`` is the grid order
    (``"q"`` / ``"kv"``) and ``block`` the ``(bq, bk)`` tile shape.  For
    the per-bucket ``decode`` sub-plans, ``sweep`` is the decode *kind*
    (``"paged"`` = the in-place Pallas block-table kernel, ``"gather"`` =
    the pure-jnp densify baseline) and ``block`` is empty."""

    sweep: str
    block: tuple[int, ...]
    est_cost: float
    source: str = "analytical"  # "analytical" | "measured"
    # decode sub-plans keyed by batch-size bucket, mirroring
    # ``LayerPlan.decode``.  None = planned before serving buckets existed.
    decode: dict[int, "AttnPlan"] | None = None

    def decode_plan(self, m: int) -> "AttnPlan | None":
        """The decode-attention sub-plan for an ``m``-slot dispatch: the
        smallest tuned bucket that fits, else None (caller keeps the
        gather baseline)."""
        if not self.decode:
            return None
        b = decode_bucket(m, tuple(self.decode))
        return self.decode.get(b) if b is not None else None

    def to_row(self) -> dict:
        return {
            "sweep": self.sweep,
            "block": list(self.block),
            "est_cost": self.est_cost,
            "source": self.source,
            "decode": {str(b): p.to_row() for b, p in sorted(self.decode.items())}
            if self.decode else None,
        }

    @classmethod
    def from_row(cls, row: dict | None) -> "AttnPlan | None":
        if row is None:
            return None
        dec = row.get("decode")
        return cls(
            sweep=row["sweep"],
            block=tuple(row.get("block") or ()),
            est_cost=row["est_cost"],
            source=row.get("source", "analytical"),
            decode={int(b): cls.from_row(r) for b, r in dec.items()}
            if dec else None,
        )


@dataclass(frozen=True)
class ScanPlan:
    """One chunked-scan schedule decision — the SSM analogue of
    ``AttnPlan``.  For the prefill row, ``sweep`` is where the running
    (N, M) state lives across the chunk grid (``"state"`` = VMEM-resident
    slab, ``"out"`` = HBM-streamed per-(b,h) block) and ``chunk`` the
    intra-chunk length L.  For the per-bucket ``decode`` sub-plans,
    ``sweep`` is the decode *kind* (``"fused"`` = the single Pallas step
    kernel, ``"einsum"`` = the jnp recurrence) and ``chunk`` is 0."""

    sweep: str
    chunk: int
    est_cost: float
    source: str = "analytical"  # "analytical" | "measured"
    # decode sub-plans keyed by batch-size bucket, mirroring
    # ``AttnPlan.decode``.  None = planned before serving buckets existed.
    decode: dict[int, "ScanPlan"] | None = None

    def decode_plan(self, m: int) -> "ScanPlan | None":
        """The decode-scan sub-plan for an ``m``-slot dispatch: the smallest
        tuned bucket that fits, else None (caller keeps the fused
        default)."""
        if not self.decode:
            return None
        b = decode_bucket(m, tuple(self.decode))
        return self.decode.get(b) if b is not None else None

    def to_row(self) -> dict:
        return {
            "sweep": self.sweep,
            "chunk": self.chunk,
            "est_cost": self.est_cost,
            "source": self.source,
            "decode": {str(b): p.to_row() for b, p in sorted(self.decode.items())}
            if self.decode else None,
        }

    @classmethod
    def from_row(cls, row: dict | None) -> "ScanPlan | None":
        if row is None:
            return None
        dec = row.get("decode")
        return cls(
            sweep=row["sweep"],
            chunk=int(row.get("chunk") or 0),
            est_cost=row["est_cost"],
            source=row.get("source", "analytical"),
            decode={int(b): cls.from_row(r) for b, r in dec.items()}
            if dec else None,
        )


@dataclass(frozen=True)
class GemmPlan:
    """One (dataflow, block, operand-layout, strip) decision for a single
    GEMM — the unit the CMU programs.  Used for the backward sub-plans
    carried by ``LayerPlan``.  ``trans`` is the ``(trans_a, trans_b)`` the
    kernel runs with: the zero-copy transposed-operand variant for backward
    GEMMs, or ``(False, False)`` when the copy-based fallback measured
    faster.  ``strip`` is the WS/IS accumulator-strip depth: 1 streams
    partial sums through HBM (the pre-v4 schedule, and the only OS value);
    >= 2 pins a VMEM-resident strip so partials never leave the chip.

    ``qdtype`` is the operand-precision decision (v9): ``None`` = the plan
    predates quant tuning (v1–v8) or quant was never requested; ``"bf16"``
    = quant was searched and rejected (accuracy gate failed, or the
    unquantized candidate measured faster); ``"int8"`` / ``"fp8"`` = the
    dispatch quantizes the weight per output channel.  ``qerror`` records
    the measured calibration error of the chosen quantized dtype (None for
    unquantized picks)."""

    dataflow: Dataflow
    block: tuple[int, int, int] | None
    est_cost: float
    source: str = "analytical"  # "analytical" | "measured"
    trans: tuple[bool, bool] = NO_TRANS
    strip: int = 1
    qdtype: str | None = None
    qerror: float | None = None

    def to_row(self) -> dict:
        return {
            "dataflow": self.dataflow.name,
            "block": list(self.block) if self.block else None,
            "est_cost": self.est_cost,
            "source": self.source,
            "trans": list(self.trans),
            "strip": self.strip,
            "qdtype": self.qdtype,
            "qerror": self.qerror,
        }

    @classmethod
    def from_row(cls, row: dict | None) -> "GemmPlan | None":
        if row is None:
            return None
        blk = row.get("block")
        trans = row.get("trans")
        return cls(
            dataflow=Dataflow[row["dataflow"]],
            block=tuple(blk) if blk else None,
            est_cost=row["est_cost"],
            source=row.get("source", "analytical"),
            trans=tuple(bool(t) for t in trans) if trans else NO_TRANS,
            strip=int(row.get("strip") or 1),
            qdtype=row.get("qdtype"),
            qerror=row.get("qerror"),
        )


@dataclass(frozen=True)
class MeshPlan:
    """The second CMU planning level: how one layer's GEMM is composed
    across the mesh's tensor axis, and the local per-shard kernel geometry
    under that composition.

    ``dataflow`` is the *mesh-level* stationarity (``dist_dataflow``): WS
    emits all-gather(A) + reduce-scatter(C) around the weight-sharded local
    kernel, IS all-gathers the weight shard, OS runs the rotating
    collective-permute SUMMA schedule.  ``local`` / ``local_dx`` /
    ``local_dw`` are chip-level ``GemmPlan``s tuned for the
    *post-collective* shard shapes (``mesh_local_gemm``) — the shapes the
    pallas_call inside the shard_map actually sees.
    """

    dataflow: Dataflow          # mesh-level stationarity
    axis: str                   # tensor-axis name the collectives run over
    tp: int                     # its extent when planned
    dp: int                     # data-parallel degree when planned
    local: GemmPlan             # local per-shard forward GEMM geometry
    local_dx: GemmPlan | None = None  # local backward sub-geometries
    local_dw: GemmPlan | None = None
    comm_bytes: int = 0         # modeled ICI bytes/chip (mesh cost model)

    def to_row(self) -> dict:
        return {
            "dataflow": self.dataflow.name,
            "axis": self.axis,
            "tp": self.tp,
            "dp": self.dp,
            "comm_bytes": self.comm_bytes,
            "local": self.local.to_row(),
            "local_dx": self.local_dx.to_row() if self.local_dx else None,
            "local_dw": self.local_dw.to_row() if self.local_dw else None,
        }

    @classmethod
    def from_row(cls, row: dict | None) -> "MeshPlan | None":
        if row is None:
            return None
        return cls(
            dataflow=Dataflow[row["dataflow"]],
            axis=row["axis"],
            tp=int(row["tp"]),
            dp=int(row["dp"]),
            local=GemmPlan.from_row(row["local"]),
            local_dx=GemmPlan.from_row(row.get("local_dx")),
            local_dw=GemmPlan.from_row(row.get("local_dw")),
            comm_bytes=int(row.get("comm_bytes") or 0),
        )


@dataclass(frozen=True)
class LayerPlan:
    name: str
    gemm: GemmShape
    dataflow: Dataflow
    est_cost: float  # cycles (systolic), seconds (roofline), or measured s
    block: tuple[int, int, int] | None = None  # (bm, bk, bn) when co-tuned
    source: str = "analytical"  # "analytical" | "measured"
    # training sub-plans: the layer's two cotangent GEMMs (None = fwd-only)
    bwd_dx: GemmPlan | None = None  # dX = dY @ W^T, an (M,N)x(N,K) GEMM
    bwd_dw: GemmPlan | None = None  # dW = X^T @ dY, a (K,M)x(M,N) GEMM
    strip: int = 1  # forward accumulator-strip depth (1 = streamed)
    # mesh sub-plan: the distributed composition (None = single-device only)
    mesh: MeshPlan | None = None
    # decode sub-plans keyed by batch-size bucket (DECODE_BUCKETS): the same
    # (K, N) projection tuned at M = bucket rows, so the serving decode step
    # dispatches a skinny-bm geometry instead of the prefill-sized forward
    # row.  None = plan predates serving (v1–v5) or was tuned without buckets.
    decode: dict[int, GemmPlan] | None = None
    # flash-attention schedule (prefill sweep/blocks + per-bucket decode
    # kinds), carried only by the ``ATTN_ANCHOR`` row.  None = plan predates
    # attention scheduling (v1–v6) or was tuned without an attention shape.
    attention: AttnPlan | None = None
    # chunked-scan schedule (prefill sweep/chunk + per-bucket decode kinds),
    # carried only by the ``SCAN_ANCHOR`` row.  None = plan predates scan
    # scheduling (v1–v7) or was tuned without a scan shape.
    scan: ScanPlan | None = None
    # forward operand-precision decision (v9), mirroring ``GemmPlan.qdtype``:
    # None = plan predates quant tuning (v1–v8) or quant was never requested,
    # "bf16" = quant searched and rejected, "int8"/"fp8" = the forward
    # dispatch quantizes the weight per output channel.
    qdtype: str | None = None
    qerror: float | None = None

    def decode_plan(self, m: int) -> GemmPlan | None:
        """The decode sub-plan for an ``m``-row dispatch: the smallest tuned
        bucket that fits, or None (caller keeps the forward decision) when no
        buckets were tuned or ``m`` exceeds them all."""
        if not self.decode:
            return None
        b = decode_bucket(m, tuple(self.decode))
        return self.decode.get(b) if b is not None else None


@dataclass
class DataflowPlan:
    """The CMU's program: one dataflow (+ block shape) per layer, decided
    pre-deployment.  ``mesh`` records the mesh fingerprint the per-layer
    mesh sub-plans were tuned for (None = single-device plan)."""

    layers: list[LayerPlan] = field(default_factory=list)
    mesh: MeshSpec | None = None

    def get(self, name: str) -> LayerPlan | None:
        for l in self.layers:
            if l.name == name:
                return l
        return None

    def dataflow_for(self, name: str) -> Dataflow:
        lp = self.get(name)
        if lp is None:
            raise KeyError(name)
        return lp.dataflow

    def histogram(self) -> dict[str, int]:
        h = {df.name: 0 for df in ALL_DATAFLOWS}
        for l in self.layers:
            h[l.dataflow.name] += 1
        return h

    def has_bwd(self) -> bool:
        """True when every layer carries both backward sub-plans — the bar
        a plan must clear before it can drive ``--pallas`` training."""
        return bool(self.layers) and all(
            l.bwd_dx is not None and l.bwd_dw is not None for l in self.layers
        )

    def has_decode(self, buckets: tuple[int, ...]) -> bool:
        """True when every layer carries a decode sub-plan for every
        requested bucket — the bar a plan must clear before it can drive a
        bucketed serving run without re-tuning."""
        return bool(self.layers) and all(
            l.decode is not None and all(b in l.decode for b in buckets)
            for l in self.layers
        )

    def has_attention(self, buckets: tuple[int, ...] = ()) -> bool:
        """True when the anchor row carries an attention schedule, including
        a decode sub-plan for every requested bucket — the bar a plan must
        clear before it can drive ``attn_pallas`` without re-tuning."""
        lp = self.get(ATTN_ANCHOR)
        if lp is None or lp.attention is None:
            return False
        if not buckets:
            return True
        dec = lp.attention.decode
        return dec is not None and all(b in dec for b in buckets)

    def attention_plan(self) -> AttnPlan | None:
        """The model's attention schedule (rides the ``ATTN_ANCHOR`` row)."""
        lp = self.get(ATTN_ANCHOR)
        return lp.attention if lp is not None else None

    def has_scan(self, buckets: tuple[int, ...] = ()) -> bool:
        """True when the anchor row carries a chunked-scan schedule,
        including a decode sub-plan for every requested bucket — the bar a
        plan must clear before it can drive ``ssm_pallas`` without
        re-tuning."""
        lp = self.get(SCAN_ANCHOR)
        if lp is None or lp.scan is None:
            return False
        if not buckets:
            return True
        dec = lp.scan.decode
        return dec is not None and all(b in dec for b in buckets)

    def scan_plan(self) -> ScanPlan | None:
        """The model's chunked-scan schedule (rides the ``SCAN_ANCHOR``
        row)."""
        lp = self.get(SCAN_ANCHOR)
        return lp.scan if lp is not None else None

    def has_quant(self, buckets: tuple[int, ...] = ()) -> bool:
        """True when every layer (and every requested decode bucket) carries
        a quant verdict — the bar a plan must clear before it can drive
        ``--quant`` without re-tuning.  A "bf16" verdict counts: quant was
        searched and rejected by the accuracy gate or the ranking, which is
        a decision, not an omission."""
        if not self.layers:
            return False
        for l in self.layers:
            if l.qdtype is None:
                return False
            for b in buckets:
                gp = (l.decode or {}).get(b)
                if gp is None or gp.qdtype is None:
                    return False
        return True

    def to_json(self) -> str:
        return json.dumps(
            [
                {
                    "name": l.name,
                    "M": l.gemm.M,
                    "K": l.gemm.K,
                    "N": l.gemm.N,
                    "dataflow": l.dataflow.name,
                    "est_cost": l.est_cost,
                    "block": list(l.block) if l.block else None,
                    "source": l.source,
                    "strip": l.strip,
                    "bwd_dx": l.bwd_dx.to_row() if l.bwd_dx else None,
                    "bwd_dw": l.bwd_dw.to_row() if l.bwd_dw else None,
                    "mesh": l.mesh.to_row() if l.mesh else None,
                    "decode": {str(b): gp.to_row() for b, gp in sorted(l.decode.items())}
                    if l.decode else None,
                    "attention": l.attention.to_row() if l.attention else None,
                    "scan": l.scan.to_row() if l.scan else None,
                    "qdtype": l.qdtype,
                    "qerror": l.qerror,
                }
                for l in self.layers
            ],
            indent=2,
        )

    @classmethod
    def from_json(cls, s: str) -> "DataflowPlan":
        plan = cls()
        for row in json.loads(s):
            gemm = GemmShape(M=row["M"], K=row["K"], N=row["N"], name=row["name"])
            blk = row.get("block")
            dec = row.get("decode")
            plan.layers.append(
                LayerPlan(
                    name=row["name"],
                    gemm=gemm,
                    dataflow=Dataflow[row["dataflow"]],
                    est_cost=row["est_cost"],
                    block=tuple(blk) if blk else None,
                    source=row.get("source", "analytical"),
                    strip=int(row.get("strip") or 1),
                    bwd_dx=GemmPlan.from_row(row.get("bwd_dx")),
                    bwd_dw=GemmPlan.from_row(row.get("bwd_dw")),
                    mesh=MeshPlan.from_row(row.get("mesh")),
                    decode={int(b): GemmPlan.from_row(r) for b, r in dec.items()}
                    if dec else None,
                    attention=AttnPlan.from_row(row.get("attention")),
                    scan=ScanPlan.from_row(row.get("scan")),
                    qdtype=row.get("qdtype"),
                    qerror=row.get("qerror"),
                )
            )
        return plan


def plan_systolic(layers: list[ConvLayer | GemmShape], array: int) -> DataflowPlan:
    """The paper's offline search on the cycle model (3 runs per layer)."""
    plan = DataflowPlan()
    for layer in layers:
        gemm = layer.gemm() if isinstance(layer, ConvLayer) else layer
        cycles = {df: systolic_cycles(gemm, df, array, array) for df in ALL_DATAFLOWS}
        best = min(cycles, key=cycles.get)  # type: ignore[arg-type]
        plan.layers.append(
            LayerPlan(name=gemm.name, gemm=gemm, dataflow=best, est_cost=cycles[best])
        )
    return plan


def plan_kernels(
    gemms: list[GemmShape],
    bm: int = 512,
    bk: int = 512,
    bn: int = 512,
    vmem_limit: int = VMEM_BUDGET_BYTES,
) -> DataflowPlan:
    """TPU-native CMU: pick per-GEMM dataflow by HBM-traffic roofline."""
    plan = DataflowPlan()
    for gemm in gemms:
        df, cost = best_kernel_dataflow(gemm, bm=bm, bk=bk, bn=bn, vmem_limit=vmem_limit)
        plan.layers.append(
            LayerPlan(name=gemm.name, gemm=gemm, dataflow=df, est_cost=cost.time_s(),
                      block=(bm, bk, bn))
        )
    return plan


def plan_kernels_tuned(
    gemms: list[GemmShape], vmem_limit: int = VMEM_BUDGET_BYTES
) -> list[tuple[GemmShape, Dataflow, tuple[int, int, int], float]]:
    """Full CMU: co-tuned (dataflow, block) per GEMM. Returns rich rows."""
    rows = []
    for g in gemms:
        df, blk, cost = tune_kernel_dataflow(g, vmem_limit=vmem_limit)
        rows.append((g, df, blk, cost.time_s()))
    return rows


# ---------------------------------------------------------------------------
# Measured autotune — the production CMU
# ---------------------------------------------------------------------------

# Interpret-mode timing on CPU is only meaningful (and affordable) up to this
# many MACs; beyond it autotune_plan keeps the analytical ranking instead.
MAX_INTERPRET_MACS = 64 * 1024 ** 2


def measure_kernel(
    gemm: GemmShape,
    dataflow: Dataflow,
    block: tuple[int, int, int],
    *,
    dtype=None,
    iters: int = 3,
    warmup: int = 1,
    interpret: bool | None = None,
    epilogue: "bool | EpilogueSig" = False,
    trans: tuple[bool, bool] = NO_TRANS,
    via_copy: bool = False,
    strip: int = 1,
    qdtype: str | None = None,
) -> float:
    """Walltime (s) of one real kernel execution of ``gemm`` under
    (dataflow, block, strip) — interpret mode on CPU, on-device on TPU.

    Returns the best of ``iters`` timed runs (min filters scheduler noise).
    ``epilogue`` selects what is timed for forward GEMMs: ``False`` the bare
    matmul, ``True`` the legacy bias+gelu probe, or an ``EpilogueSig`` for
    the layer's actual fused signature (so the measurement covers the op the
    model actually issues).

    ``trans`` gives the operand layouts of a backward GEMM: operands are
    *created* transposed ((K, M) / (N, K)) and the transposed-variant kernel
    streams them as stored.  With ``via_copy`` the same transposed operands
    are instead materialised back to plain layout inside the timed region
    before the plain kernel runs — the copy-based fallback, **its HBM
    transpose cost included**, which is what makes the CMU's re-ranking of
    the two variants honest.

    ``strip`` times the WS/IS two-level schedule (VMEM-resident accumulator
    strip); 1 is the streamed schedule.

    ``qdtype`` ("int8" / "fp8") times the weight-quantized variant: the
    per-channel quantize runs inside the timed region (it is part of the
    dispatch) and the kernel streams the 1-byte operand with the fused
    dequant epilogue.  Quantized timing is forward-only (``trans`` must be
    ``NO_TRANS``).
    """
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    if interpret is None:
        interpret = ops.default_interpret()
    dtype = dtype or jnp.float32
    sig = _epilogue_sig(epilogue)
    if sig is not None and (trans != NO_TRANS or via_copy):
        raise ValueError(
            "epilogue timing is for forward GEMMs, which never run "
            "transposed — drop epilogue or trans/via_copy"
        )
    if qdtype is not None and (trans != NO_TRANS or via_copy):
        raise ValueError("quantized timing is forward-only (trans=NO_TRANS)")
    trans_a, trans_b = trans
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (gemm.K, gemm.M) if trans_a else (gemm.M, gemm.K),
                          dtype)
    w = jax.random.normal(kw, (gemm.N, gemm.K) if trans_b else (gemm.K, gemm.N),
                          dtype)
    if sig is not None:
        b = jnp.zeros((gemm.N,), dtype) if sig.bias else None
        res = (jnp.zeros((gemm.M, gemm.N), dtype) if sig.residual else None)
        run = lambda: ops.flex_linear(
            x, w, b, activation=sig.activation, residual=res,
            dataflow=dataflow, block=block, interpret=interpret, strip=strip,
            qdtype=qdtype,
        )
    elif via_copy:
        # eager .T executes an HBM transpose copy on every timed call
        run = lambda: ops.flex_matmul(
            x.T if trans_a else x, w.T if trans_b else w,
            dataflow=dataflow, block=block, interpret=interpret, strip=strip,
        )
    else:
        run = lambda: ops.flex_matmul(
            x, w, dataflow=dataflow, block=block, interpret=interpret,
            trans_a=trans_a, trans_b=trans_b, strip=strip, qdtype=qdtype,
        )
    for _ in range(warmup):
        run().block_until_ready()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        run().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def bwd_gemms(gemm: GemmShape) -> tuple[GemmShape, GemmShape]:
    """The two cotangent GEMMs of a forward ``C[M,N] = A[M,K] @ B[K,N]``:

      dX = dY @ B^T   — an (M,N)x(N,K) GEMM  (M=M, K=N, N=K)
      dW = A^T @ dY   — a  (K,M)x(M,N) GEMM  (M=K, K=M, N=N)

    Both transpose the forward's aspect ratio, which is why they generally
    land on different dataflows than the forward pass.
    """
    return (
        GemmShape(M=gemm.M, K=gemm.N, N=gemm.K, name=gemm.name + ".dx"),
        GemmShape(M=gemm.K, K=gemm.M, N=gemm.N, name=gemm.name + ".dw"),
    )


# Default accuracy budget for the quant gate: a quantized dtype is only
# eligible when its measured calibration error (relative RMS of the layer's
# output vs full precision) stays under this bound.  int8 per-channel lands
# around 0.8% on Gaussian weights, fp8(e4m3) around 3% — the default admits
# both; tighten it (``--quant-budget`` / ``quant_budget=``) to force int8-only
# or full bf16 fallback.
QUANT_ERROR_BUDGET = 0.05

# Analytical per-operand byte widths of a weight-quantized candidate: the
# activation stays bf16, the weight streams at 1 byte/element, and the
# per-output-channel f32 scale rides the epilogue (folded into the B term of
# the traffic model so stationarity re-fetch factors multiply it).
_QUANT_TRAFFIC = dict(a_bytes=2, b_bytes=1, scale_bytes=4)


def measure_quant_error(gemm: GemmShape, qdtype: str) -> float:
    """Calibration error of quantizing ``gemm``'s weight to ``qdtype``:
    relative RMS of ``x @ dequant(quantize(w))`` against ``x @ w`` on a
    deterministic probe batch (16 rows, weight columns subsampled to 512).

    This is the accuracy gate's oracle — a module global, like
    ``measure_kernel``, so tests can substitute a fake (e.g. force a layer
    over budget and assert the recorded fallback).  Deterministic by
    construction: seeded PRNG, shapes only from ``gemm`` — the same
    (K, N, qdtype) always scores the same error.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels.quantize import dequantize_channel, quantize_channel

    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    n = min(gemm.N, 512)
    x = jax.random.normal(kx, (16, gemm.K), jnp.float32)
    w = jax.random.normal(kw, (gemm.K, n), jnp.float32)
    ref = x @ w
    out = x @ dequantize_channel(*quantize_channel(w, qdtype, axis=0))
    err = jnp.linalg.norm(out - ref) / (jnp.linalg.norm(ref) + 1e-12)
    return float(err)


def _ranked_candidates(
    gemm: GemmShape, vmem_limit: int, quant: tuple[str, ...] = ()
) -> list[tuple[float, Dataflow, tuple[int, int, int], int, str | None]]:
    """All VMEM-feasible (dataflow, block, strip) configs, best analytical
    first.

    The strip axis makes the schedule space three-dimensional: for WS/IS
    every accumulator-strip depth that tiles the streamed output axis is a
    distinct schedule (strip=1 streams partials through HBM; deeper strips
    trade stationary-operand re-fetches for zero partial traffic), and the
    strip's f32 scratch counts against the same ``VMEM_BUDGET_BYTES`` as
    the operand blocks — a candidate whose strip doesn't fit is discarded,
    never silently shrunk.  OS contributes strip=1 only (its accumulator is
    already resident; the wider-accumulator OS *is* the IS strip schedule).
    The M-axis candidates include the sublane-aligned skinny blocks so
    decode-geometry GEMMs (M <= 32) are not forced to pad to 128 rows.

    ``quant`` adds a fourth axis: for each qdtype that already passed the
    accuracy gate (callers pre-filter — ranking never decides accuracy),
    every schedule is re-costed with the weight at 1 byte/element plus the
    f32 per-channel scale.  Pass the eligible dtypes sorted by calibration
    error: the sort is stable, so when two 1-byte dtypes tie on traffic the
    lower-error one ranks first.
    """
    ranked = []
    for df in ALL_DATAFLOWS:
        for bm in kernel_block_candidates(gemm.M, sublane=True):
            for bk in kernel_block_candidates(gemm.K):
                for bn in kernel_block_candidates(gemm.N):
                    for strip in strip_candidates(
                        strip_blocks(gemm, df, bm, bn)
                    ):
                        for qd in (None, *quant):
                            # explicit per-operand widths — the byte model
                            # must not fall back to a silent dtype default
                            kw = (_QUANT_TRAFFIC if qd
                                  else dict(a_bytes=2, b_bytes=2))
                            cost = hbm_traffic_bytes(gemm, df, bm, bk, bn,
                                                     strip=strip, **kw)
                            if cost.vmem_bytes <= vmem_limit:
                                ranked.append(
                                    (cost.time_s(), cost.hbm_bytes, df,
                                     (bm, bk, bn), strip, qd)
                                )
    # roofline ties (compute-bound shapes) break toward less HBM traffic —
    # same walltime, less bandwidth and energy
    ranked.sort(key=lambda t: (t[0], t[1]))
    return [(t, df, blk, strip, qd) for t, _, df, blk, strip, qd in ranked]


def _tune_gemm(
    gemm: GemmShape,
    *,
    vmem_limit: int,
    top_k: int,
    measure: bool,
    iters: int,
    interpret: bool,
    epilogue: "bool | EpilogueSig",
    trans: tuple[bool, bool] = NO_TRANS,
    quant: tuple[str, ...] = (),
    quant_budget: float | None = None,
) -> GemmPlan:
    """Tune one GEMM: analytical pruning over the (dataflow, block, strip)
    space, then real-execution timing of the ``top_k`` survivors (falls
    back to the analytical winner when the GEMM is too large for
    interpret-mode timing or measurement is off).

    ``trans`` marks a backward GEMM whose operands live in transposed
    layout.  Each surviving (dataflow, block, strip) is then timed
    **twice**: the zero-copy transposed-operand variant, and the copy-based
    fallback with its HBM transpose executed inside the timed region — so
    the ranking sees the transpose traffic the old tuner (which timed
    pre-transposed operands) never saw.  Analytically the zero-copy variant
    strictly dominates (same kernel traffic, minus the copy), so it is the
    pick whenever measurement is off.

    ``quant`` requests weight-quantized candidates ("int8"/"fp8").  The
    accuracy gate runs first — ``measure_quant_error`` scores each dtype
    and only those under ``quant_budget`` (default ``QUANT_ERROR_BUDGET``)
    enter the ranking; the gate runs even under ``measure=False``, because
    accuracy is a numerical property, not a timing one.  When quant was
    requested the returned plan always records a verdict: the winning
    quantized dtype (with its ``qerror``), or ``qdtype="bf16"`` when every
    dtype failed the gate or lost the ranking — so a cached plan can prove
    quant was considered, not merely absent.
    """
    budget = QUANT_ERROR_BUDGET if quant_budget is None else quant_budget
    eligible: tuple[str, ...] = ()
    qerrs: dict[str, float] = {}
    if quant and trans == NO_TRANS:
        qerrs = {qd: measure_quant_error(gemm, qd) for qd in quant}
        eligible = tuple(sorted((qd for qd in quant if qerrs[qd] <= budget),
                                key=lambda qd: qerrs[qd]))
    ranked = _ranked_candidates(gemm, vmem_limit, quant=eligible)
    if not ranked:
        raise ValueError(f"no (dataflow, block, strip) fits VMEM for {gemm}")
    fallback = "bf16" if quant else None
    measurable = measure and not (interpret and gemm.macs > MAX_INTERPRET_MACS)
    if measurable:
        timed = []
        for _, df, blk, strip, qd in ranked[:top_k]:
            timed.append(
                (measure_kernel(gemm, df, blk, iters=iters, interpret=interpret,
                                epilogue=epilogue, trans=trans, strip=strip,
                                qdtype=qd),
                 trans, df, blk, strip, qd)
            )
            if trans != NO_TRANS:
                timed.append(
                    (measure_kernel(gemm, df, blk, iters=iters,
                                    interpret=interpret, trans=trans,
                                    via_copy=True, strip=strip),
                     NO_TRANS, df, blk, strip, qd)
                )
        cost, tr, df, blk, strip, qd = min(timed, key=lambda t: t[0])
        return GemmPlan(dataflow=df, block=blk, est_cost=cost,
                        source="measured", trans=tr, strip=strip,
                        qdtype=qd or fallback, qerror=qerrs.get(qd))
    cost, df, blk, strip, qd = ranked[0]
    return GemmPlan(dataflow=df, block=blk, est_cost=cost,
                    source="analytical", trans=trans, strip=strip,
                    qdtype=qd or fallback, qerror=qerrs.get(qd))


def mesh_local_gemm(gemm: GemmShape, mesh_df: Dataflow, tp: int,
                    dp: int = 1) -> GemmShape:
    """The *post-collective* per-shard GEMM a mesh dataflow hands the local
    kernel, for a global forward ``C[M,N] = A[M,K] @ B[K,N]`` with tokens
    sharded over ``dp * tp`` chips and the weight K-sharded over ``tp``:

      WS: the all-gather rebuilds the DP group's full token block and the
          local kernel contracts only this chip's K shard — (M/dp, K/tp, N);
      IS: tokens stay put, the gathered weight is whole — (M/(dp*tp), K, N);
      OS: one rotation step's partial GEMM — (M/(dp*tp), K/tp, N).
    """
    M, K, N = gemm.M, gemm.K, gemm.N
    if mesh_df is Dataflow.WS:
        return GemmShape(M // dp, K // tp, N, name=gemm.name + ".shard")
    if mesh_df is Dataflow.IS:
        return GemmShape(M // (dp * tp), K, N, name=gemm.name + ".shard")
    if mesh_df is Dataflow.OS:
        return GemmShape(M // (dp * tp), K // tp, N, name=gemm.name + ".shard")
    raise ValueError(mesh_df)  # pragma: no cover


def mesh_shardable(gemm: GemmShape, tp: int, dp: int = 1) -> bool:
    """Whether the distributed path can run this GEMM at all: the token dim
    must divide the full ``dp * tp`` grid and K the tensor axis (the weight
    arrives K-sharded in every mesh dataflow).  The same predicate gates
    both planning (no mesh sub-plan is emitted for a non-dividing layer)
    and trace-time routing (``models.layers.linear`` falls back cleanly)."""
    return tp > 1 and gemm.M % (dp * tp) == 0 and gemm.K % tp == 0


def _tune_mesh(
    gemm: GemmShape,
    mesh: MeshSpec,
    *,
    train: bool,
    epilogue: "bool | EpilogueSig",
    **tune_kw,
) -> MeshPlan | None:
    """Plan one layer's mesh composition: pick the mesh-level dataflow with
    the analytical ICI model (``best_mesh_dataflow`` — CPU cannot measure
    ICI, so this level stays shape-driven, exactly the paper's offline
    argument), then tune the local per-shard kernel geometry for the
    post-collective shapes with the full measured chip-level CMU.

    Only mesh-IS keeps the fused epilogue in-kernel (the gathered weight
    makes the local GEMM the whole layer); WS/OS apply it post-reduction,
    so their local candidates are timed bare.  Returns None when the layer
    doesn't divide the mesh (``mesh_shardable``) — the dispatch then falls
    back to the single-device plan row.
    """
    tp, dp = mesh.tp, mesh.dp
    if not mesh_shardable(gemm, tp, dp):
        return None
    per_dp = GemmShape(gemm.M // dp, gemm.K, gemm.N, name=gemm.name)
    mesh_df, cost = best_mesh_dataflow(per_dp, tp)
    local_shape = mesh_local_gemm(gemm, mesh_df, tp, dp)
    local = _tune_gemm(
        local_shape,
        epilogue=epilogue if mesh_df is Dataflow.IS else False,
        **tune_kw,
    )
    dx = dw = None
    if train:
        g_dx, g_dw = bwd_gemms(local_shape)
        dx = _tune_gemm(g_dx, epilogue=False, trans=TRANS_DX, **tune_kw)
        dw = _tune_gemm(g_dw, epilogue=False, trans=TRANS_DW, **tune_kw)
    return MeshPlan(
        dataflow=mesh_df, axis=mesh.tensor_axis, tp=tp, dp=dp,
        local=local, local_dx=dx, local_dw=dw, comm_bytes=cost.comm_bytes,
    )


def _tune_decode(
    gemm: GemmShape,
    buckets: tuple[int, ...],
    *,
    epilogue: "bool | EpilogueSig" = False,
    **tune_kw,
) -> dict[int, GemmPlan]:
    """Tune one layer's decode sub-plans: the same (K, N) projection at
    M = bucket rows for every serving batch-size bucket, timed with the
    layer's fused-epilogue signature (decode issues the same fused op as
    prefill, just skinny)."""
    out = {}
    for b in sorted(set(buckets)):
        g = GemmShape(M=b, K=gemm.K, N=gemm.N, name=f"{gemm.name}@b{b}")
        out[b] = _tune_gemm(g, epilogue=epilogue, **tune_kw)
    return out


def measure_attention(
    shape: AttnShape,
    sweep: str,
    block: tuple[int, int],
    *,
    dtype=None,
    iters: int = 3,
    warmup: int = 1,
    interpret: bool | None = None,
) -> float:
    """Walltime (s) of one real prefill flash-attention execution of
    ``shape`` under (sweep, (bq, bk)) — the attention analogue of
    ``measure_kernel``, and like it a module global so tests can substitute
    a fake timer."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.flash_attention import mha_flash

    if interpret is None:
        interpret = ops.default_interpret()
    dtype = dtype or jnp.float32
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (1, shape.seq, shape.heads, shape.head_dim), dtype)
    k = jax.random.normal(kk, (1, shape.kv, shape.kv_heads, shape.head_dim), dtype)
    v = jax.random.normal(kv, (1, shape.kv, shape.kv_heads, shape.head_dim), dtype)
    bq, bk = block
    run = lambda: mha_flash(q, k, v, causal=True, interpret=interpret,
                            block_q=bq, block_k=bk, sweep=sweep)
    for _ in range(warmup):
        run().block_until_ready()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        run().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def measure_attention_decode(
    shape: AttnShape,
    bucket: int,
    kind: str,
    *,
    block_size: int = 16,
    dtype=None,
    iters: int = 3,
    warmup: int = 1,
    interpret: bool | None = None,
) -> float:
    """Walltime (s) of one bucketed decode-attention step over a proxy paged
    cache: ``kind="paged"`` times the in-place Pallas block-table kernel,
    ``kind="gather"`` the pure-jnp densify baseline — both jitted, so the
    ranking compares the dispatches the serve scheduler would issue."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.flash_attention import (
        paged_attention,
        paged_attention_reference,
    )

    if interpret is None:
        interpret = ops.default_interpret()
    dtype = dtype or jnp.float32
    cache_len = max(min(shape.kv, 64), block_size)
    nb = -(-cache_len // block_size)
    kq, kp = jax.random.split(jax.random.PRNGKey(0))
    q = jax.random.normal(kq, (bucket, shape.heads, shape.head_dim), dtype)
    pools = jax.random.normal(
        kp, (2, bucket * nb + 1, block_size, shape.kv_heads, shape.head_dim),
        dtype)
    table = 1 + jnp.arange(bucket * nb, dtype=jnp.int32).reshape(bucket, nb)
    positions = jnp.full((bucket,), cache_len - 1, jnp.int32)
    if kind == "paged":
        run = jax.jit(lambda a, k_, v_, t, p: paged_attention(
            a, k_, v_, t, p, interpret=interpret))
    elif kind == "gather":
        run = jax.jit(paged_attention_reference)
    else:
        raise ValueError(f"unknown decode attention kind {kind!r}")
    args = (q, pools[0], pools[1], table, positions)
    for _ in range(warmup):
        run(*args).block_until_ready()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        run(*args).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def _attn_block_candidates(d: int) -> list[int]:
    """(bq, bk) candidates covering one attention grid axis of extent ``d``:
    the standard tile ladder up to the rounded extent, plus the
    sublane-aligned exact fit when the axis is smaller than one tile (smoke
    prefills, decode-folded rows)."""
    rounded = max(-(-d // 128) * 128, 128)
    cs = {c for c in ATTN_BLOCK_CANDIDATES if c <= rounded}
    small = max(-(-d // 8) * 8, 8)
    if small < 128:
        cs.add(small)
    return sorted(cs)


def _tune_attention(
    shape: AttnShape,
    buckets: tuple[int, ...] | None = None,
    *,
    vmem_limit: int,
    top_k: int,
    measure: bool,
    iters: int,
    interpret: bool,
    **_ignored,
) -> AttnPlan:
    """Tune the flash-attention schedule for one model shape: analytical
    pruning over (sweep, bq, bk) under the VMEM budget — the same
    analytical-rank → timed-execution flow as ``_tune_gemm`` — then
    per-bucket decode-kind tuning (``_tune_attn_decode``) when serving
    buckets are requested."""
    ranked = []
    seen = set()
    for sweep in ATTN_SWEEPS:
        for bq in _attn_block_candidates(shape.rows):
            for bk in _attn_block_candidates(shape.kv):
                # dedup schedules that clamp to the same effective geometry
                eff = (sweep, min(bq, max(-(-shape.rows // 8) * 8, 8)),
                       min(bk, max(-(-shape.kv // 8) * 8, 8)))
                if eff in seen:
                    continue
                seen.add(eff)
                # explicit widths: attention streams bf16 activations + KV
                # cache (weight quantization never touches these operands)
                cost = attn_traffic_bytes(shape, sweep, bq, bk,
                                          in_bytes=2, out_bytes=2)
                if cost.vmem_bytes <= vmem_limit:
                    ranked.append(
                        (cost.time_s(), cost.hbm_bytes, sweep, (bq, bk)))
    if not ranked:
        raise ValueError(f"no attention schedule fits VMEM for {shape}")
    ranked.sort(key=lambda t: (t[0], t[1]))
    measurable = measure and not (interpret and shape.macs > MAX_INTERPRET_MACS)
    if measurable:
        timed = [
            (measure_attention(shape, sweep, blk, iters=iters,
                               interpret=interpret), sweep, blk)
            for _, _, sweep, blk in ranked[:top_k]
        ]
        cost, sweep, blk = min(timed, key=lambda t: t[0])
        plan = AttnPlan(sweep=sweep, block=blk, est_cost=cost,
                        source="measured")
    else:
        cost, _, sweep, blk = ranked[0]
        plan = AttnPlan(sweep=sweep, block=blk, est_cost=cost,
                        source="analytical")
    if buckets:
        import dataclasses

        plan = dataclasses.replace(
            plan, decode=_tune_attn_decode(
                shape, tuple(buckets), measure=measure, iters=iters,
                interpret=interpret, vmem_limit=vmem_limit))
    return plan


def _tune_attn_decode(
    shape: AttnShape,
    buckets: tuple[int, ...],
    *,
    measure: bool,
    iters: int,
    interpret: bool,
    vmem_limit: int = VMEM_BUDGET_BYTES,
    **_ignored,
) -> dict[int, AttnPlan]:
    """Pick the decode-attention kind (paged Pallas kernel vs pure-jnp
    gather) per serving bucket: analytical HBM ranking — the gather's 3x
    cache traffic makes "paged" the analytical default — then timed
    execution of both kinds when measurement is on."""
    out = {}
    for b in sorted(set(buckets)):
        ranked = []
        for kind in ATTN_DECODE_KINDS:
            cost = attn_decode_traffic_bytes(shape, kind, b,
                                             in_bytes=2, out_bytes=2)
            if cost.vmem_bytes <= vmem_limit:
                ranked.append((cost.time_s(), cost.hbm_bytes, kind))
        ranked.sort(key=lambda t: (t[0], t[1]))
        if measure:
            timed = [
                (measure_attention_decode(shape, b, kind, iters=iters,
                                          interpret=interpret), kind)
                for _, _, kind in ranked
            ]
            cost, kind = min(timed, key=lambda t: t[0])
            out[b] = AttnPlan(sweep=kind, block=(), est_cost=cost,
                              source="measured")
        else:
            cost, _, kind = ranked[0]
            out[b] = AttnPlan(sweep=kind, block=(), est_cost=cost,
                              source="analytical")
    return out


def _scan_inputs(shape: ScanShape, seq: int, dtype):
    """Random (r, k, v, log_w, u) probe operands for one scan timing run —
    log_w drawn in the clipped [LOG_DECAY_MIN, -1e-6] band the models
    produce, u only for the RWKV (pre-update) convention."""
    import jax
    import jax.numpy as jnp

    from repro.models.ssm import LOG_DECAY_MIN

    B, H = shape.batch, shape.heads
    n, m = shape.key_dim, shape.val_dim
    kr, kk, kv, kw, ku = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(kr, (B, seq, H, n), dtype)
    k = jax.random.normal(kk, (B, seq, H, n), dtype)
    v = jax.random.normal(kv, (B, seq, H, m), dtype)
    lw = jnp.clip(
        -jax.nn.softplus(jax.random.normal(kw, (B, seq, H, n))),
        LOG_DECAY_MIN, -1e-6).astype(jnp.float32)
    u = (None if shape.post_update
         else jax.random.normal(ku, (H, n), jnp.float32) * 0.5)
    return r, k, v, lw, u


def measure_scan(
    shape: ScanShape,
    sweep: str,
    chunk: int,
    *,
    dtype=None,
    iters: int = 3,
    warmup: int = 1,
    interpret: bool | None = None,
) -> float:
    """Walltime (s) of one real prefill chunked-scan execution of ``shape``
    under (sweep, chunk) — the scan analogue of ``measure_attention``, and
    like it a module global so tests can substitute a fake timer."""
    import time

    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.flex_scan import flex_scan

    if interpret is None:
        interpret = ops.default_interpret()
    dtype = dtype or jnp.float32
    seq = -(-shape.seq // chunk) * chunk  # the padded T the model dispatches
    r, k, v, lw, u = _scan_inputs(shape, seq, dtype)
    run = lambda: flex_scan(r, k, v, lw, u, chunk=chunk, sweep=sweep,
                            post_update=shape.post_update,
                            interpret=interpret)[0]
    for _ in range(warmup):
        run().block_until_ready()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        run().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def measure_scan_decode(
    shape: ScanShape,
    bucket: int,
    kind: str,
    *,
    dtype=None,
    iters: int = 3,
    warmup: int = 1,
    interpret: bool | None = None,
) -> float:
    """Walltime (s) of one bucketed decode-scan step: ``kind="fused"`` times
    the single Pallas step kernel, ``kind="einsum"`` the jnp recurrence —
    both jitted, so the ranking compares the dispatches the decode step
    would issue."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.kernels.flex_scan import flex_recurrent_step
    from repro.models.ssm import recurrent_step

    if interpret is None:
        interpret = ops.default_interpret()
    dtype = dtype or jnp.float32
    bshape = ScanShape(batch=bucket, seq=1, heads=shape.heads,
                       key_dim=shape.key_dim, val_dim=shape.val_dim,
                       post_update=shape.post_update)
    r, k, v, lw, u = _scan_inputs(bshape, 1, dtype)
    r, k, v, lw = r[:, 0], k[:, 0], v[:, 0], lw[:, 0]
    S = jnp.zeros((bucket, shape.heads, shape.key_dim, shape.val_dim),
                  jnp.float32)
    if kind == "fused":
        run = jax.jit(lambda *a: flex_recurrent_step(
            *a, post_update=shape.post_update, interpret=interpret)[0])
    elif kind == "einsum":
        run = jax.jit(lambda *a: recurrent_step(
            *a, post_update=shape.post_update)[0])
    else:
        raise ValueError(f"unknown decode scan kind {kind!r}")
    args = (r, k, v, lw, S, u)
    for _ in range(warmup):
        run(*args).block_until_ready()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        run(*args).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def _tune_scan(
    shape: ScanShape,
    buckets: tuple[int, ...] | None = None,
    *,
    vmem_limit: int,
    top_k: int,
    measure: bool,
    iters: int,
    interpret: bool,
    **_ignored,
) -> ScanPlan:
    """Tune the chunked-scan schedule for one model shape: analytical
    pruning over (sweep, chunk) under the VMEM budget — the same
    analytical-rank → timed-execution flow as ``_tune_attention`` — then
    per-bucket decode-kind tuning (``_tune_scan_decode``) when serving
    buckets are requested."""
    ranked = []
    seen = set()
    for sweep in SCAN_SWEEPS:
        for chunk in SCAN_CHUNK_CANDIDATES:
            # dedup schedules whose padded grid collapses to one chunk
            eff = (sweep, min(chunk, -(-shape.seq // 8) * 8))
            if eff in seen:
                continue
            seen.add(eff)
            # explicit widths: the scan streams bf16 activations/state
            cost = scan_traffic_bytes(shape, sweep, chunk,
                                      in_bytes=2, out_bytes=2)
            if cost.vmem_bytes <= vmem_limit:
                ranked.append((cost.time_s(), cost.hbm_bytes, sweep, chunk))
    if not ranked:
        raise ValueError(f"no scan schedule fits VMEM for {shape}")
    ranked.sort(key=lambda t: (t[0], t[1]))
    measurable = measure and not (interpret and shape.macs > MAX_INTERPRET_MACS)
    if measurable:
        timed = [
            (measure_scan(shape, sweep, chunk, iters=iters,
                          interpret=interpret), sweep, chunk)
            for _, _, sweep, chunk in ranked[:top_k]
        ]
        cost, sweep, chunk = min(timed, key=lambda t: t[0])
        plan = ScanPlan(sweep=sweep, chunk=chunk, est_cost=cost,
                        source="measured")
    else:
        cost, _, sweep, chunk = ranked[0]
        plan = ScanPlan(sweep=sweep, chunk=chunk, est_cost=cost,
                        source="analytical")
    if buckets:
        import dataclasses

        plan = dataclasses.replace(
            plan, decode=_tune_scan_decode(
                shape, tuple(buckets), measure=measure, iters=iters,
                interpret=interpret, vmem_limit=vmem_limit))
    return plan


def _tune_scan_decode(
    shape: ScanShape,
    buckets: tuple[int, ...],
    *,
    measure: bool,
    iters: int,
    interpret: bool,
    vmem_limit: int = VMEM_BUDGET_BYTES,
    **_ignored,
) -> dict[int, ScanPlan]:
    """Pick the decode-scan kind (fused Pallas step kernel vs jnp
    recurrence) per serving bucket: analytical HBM ranking — the jnp path's
    materialized k v^T intermediate makes "fused" the analytical default —
    then timed execution of both kinds when measurement is on."""
    out = {}
    for b in sorted(set(buckets)):
        ranked = []
        for kind in SCAN_DECODE_KINDS:
            cost = scan_decode_traffic_bytes(shape, kind, b,
                                             in_bytes=2, out_bytes=2)
            if cost.vmem_bytes <= vmem_limit:
                ranked.append((cost.time_s(), cost.hbm_bytes, kind))
        ranked.sort(key=lambda t: (t[0], t[1]))
        if measure:
            timed = [
                (measure_scan_decode(shape, b, kind, iters=iters,
                                     interpret=interpret), kind)
                for _, _, kind in ranked
            ]
            cost, kind = min(timed, key=lambda t: t[0])
            out[b] = ScanPlan(sweep=kind, chunk=0, est_cost=cost,
                              source="measured")
        else:
            cost, _, kind = ranked[0]
            out[b] = ScanPlan(sweep=kind, chunk=0, est_cost=cost,
                              source="analytical")
    return out


def autotune_plan(
    gemms: list[GemmShape],
    *,
    vmem_limit: int = VMEM_BUDGET_BYTES,
    top_k: int = 3,
    measure: bool = True,
    iters: int = 2,
    interpret: bool | None = None,
    epilogue: "bool | EpilogueSig | dict[str, EpilogueSig | None]" = False,
    train: bool = False,
    mesh: MeshSpec | None = None,
    decode_buckets: tuple[int, ...] | None = None,
    attn: AttnShape | None = None,
    scan: ScanShape | None = None,
    quant: tuple[str, ...] | None = None,
    quant_budget: float | None = None,
) -> DataflowPlan:
    """Measured-autotune CMU: analytical pruning + real-execution timing.

    Per GEMM: rank every VMEM-feasible (dataflow, block, strip) config with
    the strip-aware roofline model — WS/IS accumulator-strip depths are
    schedules in their own right, trading stationary-operand re-fetches for
    zero partial-sum HBM traffic under one shared ``VMEM_BUDGET_BYTES`` —
    keep the ``top_k`` best, time each survivor with real kernel
    executions, and program the walltime argmin into the plan.  When
    measurement is disabled (or the GEMM is too large for interpret-mode
    timing on CPU) the analytical winner is kept, marked
    ``source="analytical"`` so callers can tell which decisions were measured.

    ``epilogue`` makes the forward measurements epilogue-aware: a bool
    applies the same probe to every layer (legacy), while a dict maps layer
    names to each layer's actual ``EpilogueSig`` — the serve/train drivers
    pass ``model_epilogues(cfg)`` so every candidate is timed as the fused
    op the model issues, not the bare matmul.

    With ``train=True`` each layer is planned as a **group of three GEMMs**:
    the forward plus its two cotangent GEMMs (``bwd_gemms``).  The backward
    sub-GEMMs are tuned over *both* operand layouts — the zero-copy
    transposed-variant kernels and the copy-based fallback with its
    transpose cost included (see ``_tune_gemm``) — and land in
    ``LayerPlan.bwd_dx`` / ``bwd_dw`` with their winning ``trans``.

    With ``mesh`` (a ``MeshSpec`` fingerprint) every layer additionally
    gets a **mesh sub-plan** (``_tune_mesh``): the mesh-level stationarity
    from the analytical ICI model plus the local per-shard kernel geometry
    tuned for the post-collective shapes.  The single-device decisions
    above are still tuned for the global geometry — they remain the
    dispatch for layers the mesh can't divide.

    With ``decode_buckets`` every layer additionally gets per-bucket
    **decode sub-plans** (``_tune_decode``): the same projection tuned at
    M = bucket rows for each serving batch-size bucket, so a
    continuous-batching decode step dispatches a skinny-bm geometry keyed
    on its quantized live batch instead of the prefill-sized forward row.

    With ``attn`` (the model's ``AttnShape``) the ``ATTN_ANCHOR`` row
    additionally carries an **attention schedule** (``_tune_attention``):
    the flash-kernel sweep order and (bq, bk) blocks for prefill, plus —
    when ``decode_buckets`` is also given — the per-bucket decode-attention
    kind (paged Pallas kernel vs jnp gather), all under the same
    analytical-pruning → timed-execution flow and VMEM budget.

    With ``scan`` (the model's ``ScanShape``) the ``SCAN_ANCHOR`` row
    additionally carries a **chunked-scan schedule** (``_tune_scan``): the
    state-residency sweep and chunk length for SSM/RWKV prefill, plus —
    when ``decode_buckets`` is also given — the per-bucket decode-scan
    kind (fused Pallas step kernel vs jnp recurrence), under the same
    flow and budget as attention.

    With ``quant`` (a tuple of "int8"/"fp8") the forward rows and decode
    sub-plans additionally search **weight-quantized candidates**: each
    requested dtype is accuracy-gated per layer (``measure_quant_error``
    vs ``quant_budget``, default ``QUANT_ERROR_BUDGET``) before entering
    the ranking, and every row records its verdict in ``qdtype`` /
    ``qerror`` — a quantized winner, or "bf16" when quant lost or failed
    the gate.  Backward and mesh sub-plans never quantize: gradients flow
    through the saved full-precision weight (straight-through), and the
    sharded dispatch has no quantized path.
    """
    if interpret is None:
        from repro.kernels import ops

        interpret = ops.default_interpret()
    kw = dict(vmem_limit=vmem_limit, top_k=top_k, measure=measure,
              iters=iters, interpret=interpret)
    qkw = dict(quant=tuple(quant or ()), quant_budget=quant_budget)
    plan = DataflowPlan(mesh=mesh)
    for gemm in gemms:
        sig = epilogue.get(gemm.name) if isinstance(epilogue, dict) else epilogue
        fwd = _tune_gemm(gemm, epilogue=sig or False, **qkw, **kw)
        dx = dw = None
        if train:
            g_dx, g_dw = bwd_gemms(gemm)
            dx = _tune_gemm(g_dx, epilogue=False, trans=TRANS_DX, **kw)
            dw = _tune_gemm(g_dw, epilogue=False, trans=TRANS_DW, **kw)
        mp = None
        if mesh is not None:
            mp = _tune_mesh(gemm, mesh, train=train, epilogue=sig or False,
                            **kw)
        dec = None
        if decode_buckets:
            dec = _tune_decode(gemm, tuple(decode_buckets),
                               epilogue=sig or False, **qkw, **kw)
        ap = None
        if attn is not None and gemm.name == ATTN_ANCHOR:
            ap = _tune_attention(attn, tuple(decode_buckets or ()) or None,
                                 **kw)
        sp = None
        if scan is not None and gemm.name == SCAN_ANCHOR:
            sp = _tune_scan(scan, tuple(decode_buckets or ()) or None, **kw)
        plan.layers.append(
            LayerPlan(name=gemm.name, gemm=gemm, dataflow=fwd.dataflow,
                      est_cost=fwd.est_cost, block=fwd.block, source=fwd.source,
                      bwd_dx=dx, bwd_dw=dw, strip=fwd.strip, mesh=mp,
                      decode=dec, attention=ap, scan=sp,
                      qdtype=fwd.qdtype, qerror=fwd.qerror)
        )
    return plan


def add_mesh_subplans(
    plan: DataflowPlan,
    mesh: MeshSpec,
    *,
    train: bool = False,
    epilogue: "bool | EpilogueSig | dict[str, EpilogueSig | None]" = False,
    vmem_limit: int = VMEM_BUDGET_BYTES,
    top_k: int = 3,
    measure: bool = True,
    iters: int = 2,
    interpret: bool | None = None,
    **_ignored,
) -> DataflowPlan:
    """Upgrade a plan for a (new) mesh **incrementally**: every
    single-device decision — forward rows and backward sub-plans — is kept
    verbatim (so a migrated v1–v4 cache keeps dispatching bit-for-bit on
    layers that fall back), and only the mesh sub-plans are (re)tuned for
    ``mesh``'s post-collective shapes."""
    import dataclasses

    if interpret is None:
        from repro.kernels import ops

        interpret = ops.default_interpret()
    kw = dict(vmem_limit=vmem_limit, top_k=top_k, measure=measure,
              iters=iters, interpret=interpret)
    out = DataflowPlan(mesh=mesh)
    for l in plan.layers:
        sig = epilogue.get(l.name) if isinstance(epilogue, dict) else epilogue
        mp = _tune_mesh(l.gemm, mesh, train=train, epilogue=sig or False, **kw)
        out.layers.append(dataclasses.replace(l, mesh=mp))
    return out


def add_bwd_subplans(
    plan: DataflowPlan,
    *,
    vmem_limit: int = VMEM_BUDGET_BYTES,
    top_k: int = 3,
    measure: bool = True,
    iters: int = 2,
    interpret: bool | None = None,
    **_ignored,
) -> DataflowPlan:
    """Upgrade a forward-only plan for training **incrementally**: keep every
    already-tuned forward decision (measurements are expensive) and tune only
    the missing dX/dW sub-GEMMs.  Layers that already carry both sub-plans
    are passed through untouched, and the plan's mesh fingerprint (plus any
    per-layer mesh sub-plans) is preserved."""
    import dataclasses

    if interpret is None:
        from repro.kernels import ops

        interpret = ops.default_interpret()
    kw = dict(vmem_limit=vmem_limit, top_k=top_k, measure=measure,
              iters=iters, interpret=interpret, epilogue=False)
    out = DataflowPlan(mesh=plan.mesh)
    for l in plan.layers:
        if l.bwd_dx is not None and l.bwd_dw is not None:
            out.layers.append(l)
            continue
        g_dx, g_dw = bwd_gemms(l.gemm)
        out.layers.append(dataclasses.replace(
            l, bwd_dx=_tune_gemm(g_dx, trans=TRANS_DX, **kw),
            bwd_dw=_tune_gemm(g_dw, trans=TRANS_DW, **kw)
        ))
    return out


def add_decode_subplans(
    plan: DataflowPlan,
    buckets: tuple[int, ...],
    *,
    epilogue: "bool | EpilogueSig | dict[str, EpilogueSig | None]" = False,
    vmem_limit: int = VMEM_BUDGET_BYTES,
    top_k: int = 3,
    measure: bool = True,
    iters: int = 2,
    interpret: bool | None = None,
    **_ignored,
) -> DataflowPlan:
    """Upgrade a plan for bucketed serving **incrementally**: every existing
    decision — forward rows, backward and mesh sub-plans, and decode buckets
    already tuned — is kept verbatim (a migrated v1–v5 cache keeps
    dispatching bit-for-bit everywhere else), and only the missing decode
    buckets are tuned."""
    import dataclasses

    if interpret is None:
        from repro.kernels import ops

        interpret = ops.default_interpret()
    kw = dict(vmem_limit=vmem_limit, top_k=top_k, measure=measure,
              iters=iters, interpret=interpret)
    out = DataflowPlan(mesh=plan.mesh)
    want = tuple(sorted(set(buckets)))
    for l in plan.layers:
        have = dict(l.decode or {})
        missing = tuple(b for b in want if b not in have)
        if not missing:
            out.layers.append(l)
            continue
        sig = epilogue.get(l.name) if isinstance(epilogue, dict) else epilogue
        have.update(_tune_decode(l.gemm, missing, epilogue=sig or False, **kw))
        out.layers.append(dataclasses.replace(l, decode=have))
    return out


def add_attention_subplans(
    plan: DataflowPlan,
    attn: AttnShape,
    buckets: tuple[int, ...] | None = None,
    *,
    vmem_limit: int = VMEM_BUDGET_BYTES,
    top_k: int = 3,
    measure: bool = True,
    iters: int = 2,
    interpret: bool | None = None,
    **_ignored,
) -> DataflowPlan:
    """Upgrade a plan with an attention schedule **incrementally**: every
    existing decision — forward rows, backward/mesh/decode sub-plans, and
    any attention schedule already tuned — is kept verbatim (a migrated
    v1–v6 cache keeps dispatching bit-for-bit everywhere else), and only
    the missing attention pieces (the prefill schedule, or just the decode
    buckets a wider run added) are tuned."""
    import dataclasses

    if interpret is None:
        from repro.kernels import ops

        interpret = ops.default_interpret()
    kw = dict(vmem_limit=vmem_limit, top_k=top_k, measure=measure,
              iters=iters, interpret=interpret)
    want = tuple(sorted(set(buckets or ())))
    out = DataflowPlan(mesh=plan.mesh)
    for l in plan.layers:
        if l.name != ATTN_ANCHOR:
            out.layers.append(l)
            continue
        ap = l.attention
        if ap is None:
            ap = _tune_attention(attn, want or None, **kw)
        else:
            have = dict(ap.decode or {})
            missing = tuple(b for b in want if b not in have)
            if missing:
                have.update(_tune_attn_decode(attn, missing, **kw))
                ap = dataclasses.replace(ap, decode=have)
        out.layers.append(dataclasses.replace(l, attention=ap))
    return out


def add_scan_subplans(
    plan: DataflowPlan,
    scan: ScanShape,
    buckets: tuple[int, ...] | None = None,
    *,
    vmem_limit: int = VMEM_BUDGET_BYTES,
    top_k: int = 3,
    measure: bool = True,
    iters: int = 2,
    interpret: bool | None = None,
    **_ignored,
) -> DataflowPlan:
    """Upgrade a plan with a chunked-scan schedule **incrementally**: every
    existing decision — forward rows, backward/mesh/decode/attention
    sub-plans, and any scan schedule already tuned — is kept verbatim (a
    migrated v1–v7 cache keeps dispatching bit-for-bit everywhere else),
    and only the missing scan pieces (the prefill schedule, or just the
    decode buckets a wider run added) are tuned."""
    import dataclasses

    if interpret is None:
        from repro.kernels import ops

        interpret = ops.default_interpret()
    kw = dict(vmem_limit=vmem_limit, top_k=top_k, measure=measure,
              iters=iters, interpret=interpret)
    want = tuple(sorted(set(buckets or ())))
    out = DataflowPlan(mesh=plan.mesh)
    for l in plan.layers:
        if l.name != SCAN_ANCHOR:
            out.layers.append(l)
            continue
        sp = l.scan
        if sp is None:
            sp = _tune_scan(scan, want or None, **kw)
        else:
            have = dict(sp.decode or {})
            missing = tuple(b for b in want if b not in have)
            if missing:
                have.update(_tune_scan_decode(scan, missing, **kw))
                sp = dataclasses.replace(sp, decode=have)
        out.layers.append(dataclasses.replace(l, scan=sp))
    return out


def _quant_choice(
    gemm: GemmShape,
    dataflow: Dataflow,
    block: tuple[int, int, int] | None,
    strip: int,
    *,
    quant: tuple[str, ...],
    budget: float,
    measure: bool,
    iters: int,
    interpret: bool,
    epilogue: "bool | EpilogueSig" = False,
) -> tuple[str, float | None]:
    """Decide the qdtype for an **already-tuned geometry**: the incremental
    upgrade's analogue of ``_tune_gemm``'s quant axis.  The accuracy gate
    runs first; surviving dtypes are then compared against the unquantized
    dispatch at the *same* (dataflow, block, strip) — timed when
    measurement is on, by the dtype-aware traffic model otherwise — so the
    upgrade never perturbs a cached schedule decision, only annotates it.
    Returns ``(qdtype, qerror)`` with "bf16" when everything fails the gate
    or loses."""
    qerrs = {qd: measure_quant_error(gemm, qd) for qd in quant}
    eligible = sorted((qd for qd in quant if qerrs[qd] <= budget),
                      key=lambda qd: qerrs[qd])
    if not eligible:
        return "bf16", None
    blk = block or (256, 256, 256)  # kernels' DEFAULT_BLOCK
    measurable = measure and not (interpret and gemm.macs > MAX_INTERPRET_MACS)
    if measurable:
        timed = [
            (measure_kernel(gemm, dataflow, blk, iters=iters,
                            interpret=interpret, epilogue=epilogue,
                            strip=strip, qdtype=qd), qd)
            for qd in (None, *eligible)
        ]
        _, qd = min(timed, key=lambda t: t[0])
    else:
        bm, bk, bn = blk
        base = hbm_traffic_bytes(gemm, dataflow, bm, bk, bn, strip=strip,
                                 a_bytes=2, b_bytes=2)
        qcost = hbm_traffic_bytes(gemm, dataflow, bm, bk, bn, strip=strip,
                                  **_QUANT_TRAFFIC)
        better = (qcost.time_s(), qcost.hbm_bytes) < (base.time_s(),
                                                      base.hbm_bytes)
        qd = eligible[0] if better else None
    return (qd or "bf16"), qerrs.get(qd)


def add_quant_subplans(
    plan: DataflowPlan,
    quant: tuple[str, ...],
    *,
    quant_budget: float | None = None,
    epilogue: "bool | EpilogueSig | dict[str, EpilogueSig | None]" = False,
    vmem_limit: int = VMEM_BUDGET_BYTES,
    top_k: int = 3,
    measure: bool = True,
    iters: int = 2,
    interpret: bool | None = None,
    **_ignored,
) -> DataflowPlan:
    """Upgrade a plan with quant verdicts **incrementally**: every existing
    decision — forward (dataflow, block, strip, trans, est_cost), backward,
    mesh, decode, attention and scan sub-plans — is kept **verbatim** (a
    migrated v1–v8 cache keeps dispatching bit-for-bit), and only the
    missing ``qdtype`` / ``qerror`` annotations are decided: per forward
    row and per decode bucket, each at its already-tuned geometry
    (``_quant_choice``).  Rows that already carry a verdict are passed
    through untouched, so re-running with the same dtypes is a no-op."""
    import dataclasses

    if interpret is None:
        from repro.kernels import ops

        interpret = ops.default_interpret()
    del vmem_limit, top_k  # geometry is frozen — nothing to re-search
    kw = dict(quant=tuple(quant), measure=measure, iters=iters,
              interpret=interpret,
              budget=QUANT_ERROR_BUDGET if quant_budget is None
              else quant_budget)
    out = DataflowPlan(mesh=plan.mesh)
    for l in plan.layers:
        sig = epilogue.get(l.name) if isinstance(epilogue, dict) else epilogue
        new = l
        if l.qdtype is None:
            qd, qe = _quant_choice(l.gemm, l.dataflow, l.block, l.strip,
                                   epilogue=sig or False, **kw)
            new = dataclasses.replace(new, qdtype=qd, qerror=qe)
        if new.decode and any(gp.qdtype is None for gp in new.decode.values()):
            dec = {}
            for b, gp in new.decode.items():
                if gp.qdtype is None:
                    g = GemmShape(M=b, K=l.gemm.K, N=l.gemm.N,
                                  name=f"{l.gemm.name}@b{b}")
                    qd, qe = _quant_choice(g, gp.dataflow, gp.block, gp.strip,
                                           epilogue=sig or False, **kw)
                    gp = dataclasses.replace(gp, qdtype=qd, qerror=qe)
                dec[b] = gp
            new = dataclasses.replace(new, decode=dec)
        out.layers.append(new)
    return out


def model_gemms(cfg, tokens: int) -> list[GemmShape]:
    """The per-layer GEMMs an LM config issues for one batch of ``tokens``.

    Names match the ``name=`` keys ``models.layers.linear`` looks up, so one
    autotuned plan drives every projection in the stack.
    """
    D = cfg.d_model
    gemms = [
        GemmShape(M=tokens, K=D, N=cfg.q_dim, name="attn.wq"),
        GemmShape(M=tokens, K=D, N=cfg.kv_dim, name="attn.wk"),
        GemmShape(M=tokens, K=D, N=cfg.kv_dim, name="attn.wv"),
        GemmShape(M=tokens, K=cfg.q_dim, N=D, name="attn.wo"),
    ]
    if cfg.d_ff:
        gemms += [
            GemmShape(M=tokens, K=D, N=cfg.d_ff, name="mlp.w1"),
            GemmShape(M=tokens, K=cfg.d_ff, N=D, name="mlp.w2"),
        ]
        if cfg.activation in ("silu", "gelu"):
            gemms.append(GemmShape(M=tokens, K=D, N=cfg.d_ff, name="mlp.w3"))
    gemms.append(GemmShape(M=tokens, K=D, N=cfg.padded_vocab, name="lm_head"))
    return gemms


def model_attn_shape(cfg, tokens: int) -> AttnShape:
    """The self-attention planning fingerprint an LM config issues for one
    batch of ``tokens`` — the companion of ``model_gemms`` for the
    ``ATTN_ANCHOR`` row's attention schedule."""
    return AttnShape(
        seq=tokens,
        kv=tokens,
        heads=cfg.num_heads,
        kv_heads=cfg.num_kv_heads or cfg.num_heads,
        head_dim=cfg.head_dim,
    )


def model_scan_shape(cfg, tokens: int) -> "ScanShape | None":
    """The chunked-scan planning fingerprint an SSM/hybrid LM config issues
    for one batch of ``tokens`` — the companion of ``model_attn_shape`` for
    the ``SCAN_ANCHOR`` row's scan schedule.  None for families with no
    recurrent mixer (pure attention)."""
    fam = getattr(cfg, "family", "attn")
    if fam == "hybrid":
        return ScanShape(
            batch=1,
            seq=tokens,
            heads=cfg.ssm_heads,
            key_dim=cfg.ssm_state,
            val_dim=cfg.ssm_head_dim,
            post_update=True,
        )
    if fam == "ssm":
        return ScanShape(
            batch=1,
            seq=tokens,
            heads=cfg.rwkv_heads,
            key_dim=cfg.rwkv_head_size,
            val_dim=cfg.rwkv_head_size,
            post_update=False,
        )
    return None


def model_epilogues(cfg) -> dict[str, EpilogueSig]:
    """Per-layer epilogue signatures matching what ``models.layers`` fuses
    into each projection's kernel — keys mirror ``model_gemms``.  Passed as
    ``autotune_plan(..., epilogue=...)`` so forward candidates are timed as
    the ops the model actually issues (bias on q/k/v when ``qkv_bias``,
    activation on mlp.w1, residual folded into attn.wo / mlp.w2)."""
    qkv = EpilogueSig(bias=cfg.qkv_bias)
    sigs = {
        "attn.wq": qkv,
        "attn.wk": qkv,
        "attn.wv": qkv,
        "attn.wo": EpilogueSig(residual=True),
        "lm_head": EpilogueSig(),
    }
    if cfg.d_ff:
        act = "silu" if cfg.activation == "silu" else "gelu"
        sigs["mlp.w1"] = EpilogueSig(activation=act)
        sigs["mlp.w2"] = EpilogueSig(residual=True)
        if cfg.activation in ("silu", "gelu"):
            sigs["mlp.w3"] = EpilogueSig()
    return sigs


def static_vs_flex_traffic(
    gemms: list[GemmShape], bm: int = 512, bk: int = 512, bn: int = 512
) -> dict[str, int]:
    """Total HBM bytes for each static dataflow vs. the flex (per-layer) plan.

    The kernel-level analogue of the paper's Table I: same exhaustive offline
    search, cost = HBM traffic instead of cycles.
    """
    totals = {df.name: 0 for df in ALL_DATAFLOWS}
    flex = 0
    for g in gemms:
        per = {df: hbm_traffic_bytes(g, df, bm, bk, bn, in_bytes=2).hbm_bytes
               for df in ALL_DATAFLOWS}
        for df, v in per.items():
            totals[df.name] += v
        flex += min(per.values())
    totals["FLEX"] = flex
    return totals
