"""Configuration Management Unit (CMU) — offline per-layer dataflow selection.

Paper Section II: "To find the optimal dataflow strategy for each layer in the
DNN, we should run each trained model on the Flex-TPU three times, once for
each dataflow, during the development phase. [...] the optimal dataflow is
then programmed into the CMU".

We implement that exact pre-deployment procedure at both levels the framework
supports:

* ``plan_systolic``  — the faithful reproduction: 3 simulator runs per layer,
  keep the per-layer argmin (drives Table I / Fig. 6 / Fig. 7 benchmarks).
* ``plan_kernels``   — the TPU-native port: 3 HBM-traffic evaluations per GEMM
  in an LM architecture, keep the per-layer roofline-argmin; the resulting
  ``DataflowPlan`` is attached to the model config and dispatched *statically*
  at trace time (the JAX analogue of programming the CMU's MUX signals).

Both are one-time, offline, shape-only decisions — exactly the paper's
deployment model, which is why no runtime switching machinery (lax.switch)
is needed on the hot path.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

from .dataflow import (
    ALL_DATAFLOWS,
    ConvLayer,
    Dataflow,
    GemmShape,
    best_kernel_dataflow,
    hbm_traffic_bytes,
    systolic_cycles,
    tune_kernel_dataflow,
)


@dataclass(frozen=True)
class LayerPlan:
    name: str
    gemm: GemmShape
    dataflow: Dataflow
    est_cost: float  # cycles (systolic) or seconds (kernel roofline)


@dataclass
class DataflowPlan:
    """The CMU's program: one dataflow per layer, decided pre-deployment."""

    layers: list[LayerPlan] = field(default_factory=list)

    def dataflow_for(self, name: str) -> Dataflow:
        for l in self.layers:
            if l.name == name:
                return l.dataflow
        raise KeyError(name)

    def histogram(self) -> dict[str, int]:
        h = {df.name: 0 for df in ALL_DATAFLOWS}
        for l in self.layers:
            h[l.dataflow.name] += 1
        return h

    def to_json(self) -> str:
        return json.dumps(
            [
                {
                    "name": l.name,
                    "M": l.gemm.M,
                    "K": l.gemm.K,
                    "N": l.gemm.N,
                    "dataflow": l.dataflow.name,
                    "est_cost": l.est_cost,
                }
                for l in self.layers
            ],
            indent=2,
        )

    @classmethod
    def from_json(cls, s: str) -> "DataflowPlan":
        plan = cls()
        for row in json.loads(s):
            gemm = GemmShape(M=row["M"], K=row["K"], N=row["N"], name=row["name"])
            plan.layers.append(
                LayerPlan(
                    name=row["name"],
                    gemm=gemm,
                    dataflow=Dataflow[row["dataflow"]],
                    est_cost=row["est_cost"],
                )
            )
        return plan


def plan_systolic(layers: list[ConvLayer | GemmShape], array: int) -> DataflowPlan:
    """The paper's offline search on the cycle model (3 runs per layer)."""
    plan = DataflowPlan()
    for layer in layers:
        gemm = layer.gemm() if isinstance(layer, ConvLayer) else layer
        cycles = {df: systolic_cycles(gemm, df, array, array) for df in ALL_DATAFLOWS}
        best = min(cycles, key=cycles.get)  # type: ignore[arg-type]
        plan.layers.append(
            LayerPlan(name=gemm.name, gemm=gemm, dataflow=best, est_cost=cycles[best])
        )
    return plan


def plan_kernels(
    gemms: list[GemmShape],
    bm: int = 512,
    bk: int = 512,
    bn: int = 512,
    vmem_limit: int = 128 * 1024 * 1024,
) -> DataflowPlan:
    """TPU-native CMU: pick per-GEMM dataflow by HBM-traffic roofline."""
    plan = DataflowPlan()
    for gemm in gemms:
        df, cost = best_kernel_dataflow(gemm, bm=bm, bk=bk, bn=bn, vmem_limit=vmem_limit)
        plan.layers.append(
            LayerPlan(name=gemm.name, gemm=gemm, dataflow=df, est_cost=cost.time_s())
        )
    return plan


def plan_kernels_tuned(
    gemms: list[GemmShape], vmem_limit: int = 96 * 1024 * 1024
) -> list[tuple[GemmShape, Dataflow, tuple[int, int, int], float]]:
    """Full CMU: co-tuned (dataflow, block) per GEMM. Returns rich rows."""
    rows = []
    for g in gemms:
        df, blk, cost = tune_kernel_dataflow(g, vmem_limit=vmem_limit)
        rows.append((g, df, blk, cost.time_s()))
    return rows


def static_vs_flex_traffic(
    gemms: list[GemmShape], bm: int = 512, bk: int = 512, bn: int = 512
) -> dict[str, int]:
    """Total HBM bytes for each static dataflow vs. the flex (per-layer) plan.

    The kernel-level analogue of the paper's Table I: same exhaustive offline
    search, cost = HBM traffic instead of cycles.
    """
    totals = {df.name: 0 for df in ALL_DATAFLOWS}
    flex = 0
    for g in gemms:
        per = {df: hbm_traffic_bytes(g, df, bm, bk, bn).hbm_bytes for df in ALL_DATAFLOWS}
        for df, v in per.items():
            totals[df.name] += v
        flex += min(per.values())
    totals["FLEX"] = flex
    return totals
