"""Persistent CMU plan cache.

The measured autotune (``cmu.autotune_plan``) is a one-time, offline,
pre-deployment step — exactly the paper's CMU programming procedure.  This
module persists its output so serve/train **reload** plans instead of
re-tuning on every launch, and provides the process-wide "programmed CMU"
the model stack consults at trace time:

  * ``save_plan`` / ``load_plan``     — versioned JSON on disk
  * ``load_or_autotune``              — the serve/train entry point
  * ``activate_plan`` / ``active_plan`` — the in-process register file the
    paper's CMU MUX signals map to; ``models.layers.linear`` reads it when
    dispatching each projection to a flex kernel.
"""

from __future__ import annotations

import json
import os

from .cmu import DataflowPlan, autotune_plan

PLAN_CACHE_VERSION = 1

_ACTIVE_PLAN: DataflowPlan | None = None


def save_plan(path: str, plan: DataflowPlan) -> None:
    """Persist a plan as versioned JSON (atomic rename, so a crashed tune
    never leaves a half-written cache for the next launch to trip on)."""
    payload = {"version": PLAN_CACHE_VERSION, "layers": json.loads(plan.to_json())}
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2)
    os.replace(tmp, path)


def load_plan(path: str) -> DataflowPlan:
    with open(path) as f:
        try:
            payload = json.load(f)
        except json.JSONDecodeError as e:
            raise ValueError(
                f"plan cache {path} is not valid JSON ({e}) — delete it and re-tune"
            ) from e
    if payload.get("version") != PLAN_CACHE_VERSION:
        raise ValueError(
            f"plan cache {path} has version {payload.get('version')}, "
            f"expected {PLAN_CACHE_VERSION} — delete it and re-tune"
        )
    return DataflowPlan.from_json(json.dumps(payload["layers"]))


def plan_matches(plan: DataflowPlan, gemms) -> bool:
    """True when the plan was tuned for exactly these (name, M, K, N) GEMMs —
    the guard against silently applying a cache tuned for another arch or
    batch geometry."""
    planned = {(l.name, l.gemm.M, l.gemm.K, l.gemm.N) for l in plan.layers}
    wanted = {(g.name, g.M, g.K, g.N) for g in gemms}
    return planned == wanted


def load_or_autotune(path: str | None, gemms, **autotune_kw):
    """Return ``(plan, loaded)`` — the cached plan when ``path`` exists and
    matches ``gemms``, otherwise a fresh autotune persisted to ``path``
    (when given).  A cache tuned for different GEMM shapes (other arch,
    other batch geometry) is re-tuned and overwritten, not silently applied."""
    if path and os.path.exists(path):
        plan = load_plan(path)
        if plan_matches(plan, gemms):
            return plan, True
        import logging

        logging.getLogger(__name__).warning(
            "plan cache %s was tuned for different GEMM shapes; re-tuning", path
        )
    plan = autotune_plan(gemms, **autotune_kw)
    if path:
        save_plan(path, plan)
    return plan, False


def activate_plan(plan: DataflowPlan | None) -> None:
    """Program the process-wide CMU: subsequent traced ``linear`` calls
    dispatch per the plan.  Pass None to clear."""
    global _ACTIVE_PLAN
    _ACTIVE_PLAN = plan


def active_plan() -> DataflowPlan | None:
    return _ACTIVE_PLAN
