"""Persistent CMU plan cache.

The measured autotune (``cmu.autotune_plan``) is a one-time, offline,
pre-deployment step — exactly the paper's CMU programming procedure.  This
module persists its output so serve/train **reload** plans instead of
re-tuning on every launch, and provides the process-wide "programmed CMU"
the model stack consults at trace time:

  * ``save_plan`` / ``load_plan``     — versioned JSON on disk
  * ``load_or_autotune``              — the serve/train entry point
  * ``activate_plan`` / ``active_plan`` — the in-process register file the
    paper's CMU MUX signals map to; ``models.layers.linear`` reads it when
    dispatching each projection to a flex kernel.

Schema versions (see docs/autotune.md for the full JSON shape):

  * v1 — fwd-only rows: (name, M, K, N, dataflow, est_cost, block, source).
  * v2 — adds per-layer backward sub-plans ``bwd_dx`` / ``bwd_dw`` (each a
    {dataflow, block, est_cost, source} row, or null for fwd-only plans).
  * v3 — each backward sub-plan additionally carries ``trans``, the
    ``[trans_a, trans_b]`` operand layout its kernel runs with (the
    zero-copy transposed-operand variant, or ``[false, false]`` when the
    copy-based fallback measured faster).
  * v4 — every decision (forward row and backward sub-plan) additionally
    carries ``strip``, the WS/IS accumulator-strip depth: 1 is the
    streamed schedule (partial sums through HBM — all pre-v4 plans ran
    this), >= 2 the two-level schedule with a VMEM-resident strip.
  * v5 — the payload carries a top-level ``mesh`` fingerprint (axis names x
    extents + tensor/dp roles, or null for single-device plans) and each
    layer may carry a ``mesh`` sub-plan: the mesh-level dataflow (the
    collective schedule ``kernels.mesh_ops`` wraps around the local
    kernel) plus the local per-shard GEMM geometry tuned for the
    post-collective shapes.  A cached plan only matches when its mesh
    fingerprint equals the requested one — a plan tuned for a 2x4 mesh is
    never silently applied to an 8x1.
  * v6 — each layer may carry ``decode``: per-batch-size-bucket decode
    sub-plans (bucket -> {dataflow, block, est_cost, source, trans, strip}),
    the same projection tuned at M = bucket rows so the serving decode step
    dispatches a skinny-bm geometry keyed on its quantized live batch (see
    docs/serving.md).  Null / absent = no buckets tuned; the forward row
    remains the dispatch for every M, exactly the v5 behaviour.
  * v7 — the ``attn.wq`` anchor row may carry ``attention``: the flash
    attention schedule ({sweep, block: [bq, bk], est_cost, source}) plus
    per-bucket ``decode`` sub-rows (bucket -> {sweep: "paged"|"gather",
    ...}) picking the decode-attention kind the serve scheduler dispatches
    (see docs/autotune.md).  Null / absent = no attention schedule tuned;
    the jnp attention paths remain the dispatch, exactly the v6 behaviour.
  * v8 — the ``lm_head`` anchor row may carry ``scan``: the chunked-scan
    schedule ({sweep: "state"|"out", chunk, est_cost, source}) plus
    per-bucket ``decode`` sub-rows (bucket -> {sweep: "fused"|"einsum",
    chunk: 0, ...}) picking the decode-scan kind — the SSM/hybrid
    analogue of the v7 attention schedule (see docs/autotune.md).  Null /
    absent = no scan schedule tuned; the jnp chunked scan remains the
    dispatch, exactly the v7 behaviour.
  * v9 — every forward row and decode sub-plan may carry ``qdtype`` /
    ``qerror``, the operand-precision verdict: null = never quant-tuned
    (every v1–v8 plan), "bf16" = quant searched and rejected (accuracy
    gate or ranking), "int8"/"fp8" = the dispatch quantizes the weight per
    output channel with the fused dequant epilogue, ``qerror`` recording
    the gate's measured calibration error (see docs/autotune.md).

Older files still **load and migrate**: v1–v8 files load with ``qdtype``
None (v1–v7 also with ``scan`` None, v1–v6 with ``attention`` None,
v1–v5 with ``decode`` None, v1–v4 with ``mesh`` None), so their dispatch
is bit-for-bit what it was — the quant, scan, attention, decode-bucket
and mesh axes only enter via incremental upgrades (``add_quant_subplans``
/ ``add_scan_subplans`` / ``add_attention_subplans`` /
``add_decode_subplans`` / ``add_mesh_subplans``, which keep every
existing decision verbatim) or a re-tune.  v1 rows are
a strict subset (the
backward sub-plans come back as None); v2 backward sub-plans — tuned on
pre-transposed operands, so their (dataflow, block) remains valid for the
same logical GEMM — are migrated to the zero-copy layout of their role
(dX -> trans_b, dW -> trans_a), which never costs more than the copy path
the v2 code actually ran.  v1–v3 decisions all migrate with ``strip=1``:
that is exactly the schedule those plans were tuned on, so a migrated plan
keeps its (dataflow, block, trans) decisions and reproduces the old
results **bit-for-bit**; the strip axis only enters on re-tune.  One
traffic caveat: streamed WS/IS layers *with a residual* now add it
outside the kernel (one extra f32 output round-trip — same f32 op order,
identical bits; see docs/architecture.md, fused-epilogue contract), so a
migrated plan that hits that combination is worth re-tuning, which lets
the CMU route such layers to OS or a strip.  Training, which needs the
sub-plans,
passes ``require_bwd=True`` to ``load_or_autotune`` and a fwd-only cache
is then re-tuned and overwritten, never silently half-applied.  Files
from a *newer* schema than this build understands are rejected with a
clear re-tune message by ``load_plan``; ``load_or_autotune`` (the server
entry point) goes one step further and treats any unreadable file —
corrupt/truncated JSON or a future schema — as a degraded launch, not a
fatal one: the file is quarantined to ``<path>.corrupt`` and the run
falls back to a fresh re-tune (with a warning).
"""

from __future__ import annotations

import json
import os

from .cmu import (
    TRANS_DX,
    TRANS_DW,
    AttnShape,
    DataflowPlan,
    ScanShape,
    add_attention_subplans,
    add_bwd_subplans,
    add_decode_subplans,
    add_mesh_subplans,
    add_quant_subplans,
    add_scan_subplans,
    autotune_plan,
)
from .dist_dataflow import MeshSpec

PLAN_CACHE_VERSION = 9
# older schemas this build can still read and migrate
COMPATIBLE_VERSIONS = (1, 2, 3, 4, 5, 6, 7, 8, 9)

_ACTIVE_PLAN: DataflowPlan | None = None


def save_plan(path: str, plan: DataflowPlan) -> None:
    """Persist a plan as versioned JSON (atomic rename, so a crashed tune
    never leaves a half-written cache for the next launch to trip on)."""
    payload = {
        "version": PLAN_CACHE_VERSION,
        "mesh": plan.mesh.to_row() if plan.mesh else None,
        "layers": json.loads(plan.to_json()),
    }
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2)
    os.replace(tmp, path)


def load_plan(path: str) -> DataflowPlan:
    with open(path) as f:
        try:
            payload = json.load(f)
        except json.JSONDecodeError as e:
            raise ValueError(
                f"plan cache {path} is not valid JSON ({e}) — delete it and re-tune"
            ) from e
    version = payload.get("version")
    if version not in COMPATIBLE_VERSIONS:
        raise ValueError(
            f"plan cache {path} has schema version {version}, but this build "
            f"reads {COMPATIBLE_VERSIONS} — delete it and re-tune (or serve "
            "with a matching build)"
        )
    layers = payload["layers"]
    if version < PLAN_CACHE_VERSION:
        import logging

        migrated = _migrate_rows(layers, version)
        if migrated:
            note = (f"{migrated} decisions migrated (zero-copy layouts / "
                    "strip=1 streamed semantics); single-device dispatch "
                    "unchanged, mesh/decode sub-plans absent")
        elif version >= 2:
            note = ("rows are a structural subset — dispatch unchanged, "
                    "missing sub-plans (mesh/decode buckets) absent")
        else:
            note = "backward sub-plans absent — training will re-tune"
        logging.getLogger(__name__).info(
            "plan cache %s uses schema v%d; loaded as v%d (%s)",
            path, version, PLAN_CACHE_VERSION, note,
        )
    plan = DataflowPlan.from_json(json.dumps(layers))
    plan.mesh = MeshSpec.from_row(payload.get("mesh"))
    return plan


def _migrate_rows(layers: list[dict], version: int) -> int:
    """In-place v1/v2/v3 row migration; returns migrated field count.
    v4–v8 rows need no edits: v5 through v9 only *add* optional fields
    (the ``mesh`` sub-plan, the per-bucket ``decode`` sub-plans, the
    anchor rows' ``attention`` / ``scan`` schedules, and the ``qdtype`` /
    ``qerror`` quant verdicts), which absent keys already decode as None
    (single-device, unbucketed, jnp attention, jnp chunked scan, and
    unquantized dispatch).

    v2 backward sub-plans were tuned timing *pre-transposed* operands, i.e.
    the copy-based path minus the copy — their (dataflow, block) stays valid
    for the same logical GEMM, and the zero-copy transposed-operand layout
    runs that exact schedule without the HBM copy, so migration assigns each
    role its zero-copy ``trans`` rather than pinning the old copy behaviour.

    v1–v3 decisions (forward rows and sub-plans) gain ``strip=1``: the
    streamed schedule every pre-v4 plan was tuned on.  A migrated plan
    therefore keeps its (dataflow, block, trans) decisions and produces
    bit-for-bit identical outputs (streamed WS/IS residuals now fuse
    outside the kernel — same op order, extra f32 round-trip; see the
    module docstring), and only a re-tune explores the strip axis.
    """
    migrated = 0
    if version >= 4:
        return migrated
    for row in layers:
        if version < 4 and "strip" not in row:
            row["strip"] = 1
            migrated += 1
        for key, trans in (("bwd_dx", TRANS_DX), ("bwd_dw", TRANS_DW)):
            sub = row.get(key)
            if sub is None:
                continue
            if version < 3 and "trans" not in sub:
                sub["trans"] = list(trans)
                migrated += 1
            if version < 4 and "strip" not in sub:
                sub["strip"] = 1
                migrated += 1
    return migrated


def plan_matches(plan: DataflowPlan, gemms, require_bwd: bool = False,
                 mesh: MeshSpec | None = None,
                 buckets: tuple[int, ...] | None = None,
                 attn: AttnShape | None = None,
                 scan: ScanShape | None = None,
                 quant: tuple[str, ...] | None = None) -> bool:
    """True when the plan was tuned for exactly these (name, M, K, N) GEMMs —
    the guard against silently applying a cache tuned for another arch or
    batch geometry.  With ``require_bwd`` the plan must also carry backward
    sub-plans for every layer (the training bar).  With ``mesh`` the plan's
    mesh fingerprint must equal the requested one (a plan tuned for another
    mesh topology is stale at the mesh level); a mesh-tuned plan still
    matches a single-device request — its single-device rows are intact and
    the mesh sub-plans are simply never consulted.  With ``buckets`` every
    layer must carry a decode sub-plan for every requested batch-size bucket
    (the serving bar); a bucket-tuned plan still matches a bucketless
    request the same way.  With ``attn`` the anchor row must carry an
    attention schedule covering the requested buckets (the ``attn_pallas``
    bar); an attention-tuned plan still matches a request without one.
    ``scan`` applies the same bar to the chunked-scan schedule on the
    ``SCAN_ANCHOR`` row (the ``ssm_pallas`` bar).  With ``quant`` every
    layer (and requested decode bucket) must carry a quant verdict — a
    "bf16" rejection counts, a v1–v8 null does not (the ``--quant`` bar);
    a quant-annotated plan still matches an unquantized request, whose
    dispatch simply ignores the annotations."""
    planned = {(l.name, l.gemm.M, l.gemm.K, l.gemm.N) for l in plan.layers}
    wanted = {(g.name, g.M, g.K, g.N) for g in gemms}
    if planned != wanted:
        return False
    if mesh is not None and plan.mesh != mesh:
        return False
    if buckets and not plan.has_decode(tuple(buckets)):
        return False
    if attn is not None and not plan.has_attention(tuple(buckets or ())):
        return False
    if scan is not None and not plan.has_scan(tuple(buckets or ())):
        return False
    if quant and not plan.has_quant(tuple(buckets or ())):
        return False
    return plan.has_bwd() if require_bwd else True


def load_or_autotune(path: str | None, gemms, require_bwd: bool = False,
                     mesh: MeshSpec | None = None,
                     buckets: tuple[int, ...] | None = None,
                     attn: AttnShape | None = None,
                     scan: ScanShape | None = None,
                     quant: tuple[str, ...] | None = None,
                     quant_budget: float | None = None, **autotune_kw):
    """Return ``(plan, loaded)`` — the cached plan when ``path`` exists and
    matches ``gemms``, otherwise a fresh autotune persisted to ``path``
    (when given).  A cache tuned for different GEMM shapes (other arch,
    other batch geometry), or one missing the backward sub-plans a training
    run needs (``require_bwd``), is re-tuned and overwritten, not silently
    applied.  A cache whose *forward* decisions match but which lacks the
    sub-plans is upgraded incrementally (only the dX/dW GEMMs are tuned —
    the measured forward decisions are kept).  Likewise a cache whose
    single-device decisions match but whose mesh fingerprint differs from
    ``mesh`` (a migrated v1–v4 file, or a cache tuned for another topology)
    is upgraded incrementally: only the mesh sub-plans are tuned, every
    single-device decision is kept verbatim.  The same applies to
    ``buckets``: a cache missing decode sub-plans for some requested
    batch-size bucket (a migrated v1–v5 file, or one tuned for fewer
    buckets) gains only the missing buckets (``add_decode_subplans``), and
    to ``attn``: a cache without an attention schedule (a migrated v1–v6
    file) gains it via ``add_attention_subplans`` with every GEMM, mesh
    and decode decision kept verbatim, and to ``scan``: a cache without a
    chunked-scan schedule (a migrated v1–v7 file) gains it via
    ``add_scan_subplans`` the same way, and to ``quant``: a cache without
    quant verdicts (a migrated v1–v8 file) gains only the ``qdtype`` /
    ``qerror`` annotations via ``add_quant_subplans`` — every schedule
    decision, including the geometries the quantized kernels run with,
    is kept verbatim.

    Server-grade load hardening: a corrupt/truncated cache file, or one
    written by a *newer* build (a future schema version), must not kill the
    launch.  ``load_plan``'s ``ValueError`` is caught here, the offending
    file is **quarantined** (renamed to ``<path>.corrupt`` so the evidence
    survives for debugging and the next launch doesn't trip on it again),
    and the run falls back to a fresh re-tune persisted to ``path``."""
    if path and os.path.exists(path):
        try:
            plan = load_plan(path)
        except ValueError as e:
            import logging

            quarantine = path + ".corrupt"
            os.replace(path, quarantine)
            logging.getLogger(__name__).warning(
                "plan cache %s is unreadable (%s); quarantined to %s and "
                "re-tuning", path, e, quarantine,
            )
            plan = autotune_plan(gemms, train=require_bwd, mesh=mesh,
                                 decode_buckets=buckets, attn=attn, scan=scan,
                                 quant=quant, quant_budget=quant_budget,
                                 **autotune_kw)
            save_plan(path, plan)
            return plan, False
        if plan_matches(plan, gemms, require_bwd=require_bwd, mesh=mesh,
                        buckets=buckets, attn=attn, scan=scan, quant=quant):
            if autotune_kw.get("epilogue"):
                import logging

                # shape-keyed staleness can't see *how* cached forward rows
                # were measured; an old cache tuned bare is still honoured
                logging.getLogger(__name__).info(
                    "plan cache %s reused as-is; its forward decisions keep "
                    "their original measurement probe — delete the file to "
                    "re-tune with the current epilogue signatures", path,
                )
            return plan, True
        import logging

        log = logging.getLogger(__name__)
        if plan_matches(plan, gemms):
            # single-device fwd decisions are valid — upgrade incrementally
            added_bwd = False
            if not plan_matches(plan, gemms, require_bwd=require_bwd):
                log.warning(
                    "plan cache %s lacks backward sub-plans; tuning dX/dW "
                    "only (keeping the forward decisions)", path
                )
                plan = add_bwd_subplans(plan, **autotune_kw)
                added_bwd = True  # mesh locals (if any) also lack bwd
            if mesh is not None and (plan.mesh != mesh or added_bwd):
                log.warning(
                    "plan cache %s was tuned for mesh %s, not %s; tuning "
                    "mesh sub-plans only (keeping every single-device "
                    "decision)", path,
                    plan.mesh.axes if plan.mesh else None, mesh.axes,
                )
                plan = add_mesh_subplans(plan, mesh, train=require_bwd,
                                         **autotune_kw)
            if buckets and not plan.has_decode(tuple(buckets)):
                log.warning(
                    "plan cache %s lacks decode sub-plans for buckets %s; "
                    "tuning the missing buckets only (keeping every "
                    "existing decision)", path, tuple(buckets),
                )
                plan = add_decode_subplans(plan, tuple(buckets),
                                           **autotune_kw)
            if attn is not None and not plan.has_attention(
                    tuple(buckets or ())):
                log.warning(
                    "plan cache %s lacks an attention schedule for %s; "
                    "tuning the attention family only (keeping every "
                    "existing decision)", path, attn,
                )
                plan = add_attention_subplans(plan, attn, tuple(buckets or ())
                                              or None, **autotune_kw)
            if scan is not None and not plan.has_scan(tuple(buckets or ())):
                log.warning(
                    "plan cache %s lacks a chunked-scan schedule for %s; "
                    "tuning the scan family only (keeping every existing "
                    "decision)", path, scan,
                )
                plan = add_scan_subplans(plan, scan, tuple(buckets or ())
                                         or None, **autotune_kw)
            if quant and not plan.has_quant(tuple(buckets or ())):
                log.warning(
                    "plan cache %s lacks quant verdicts for %s; gating and "
                    "annotating qdtype only (keeping every schedule "
                    "decision verbatim)", path, tuple(quant),
                )
                plan = add_quant_subplans(plan, tuple(quant),
                                          quant_budget=quant_budget,
                                          **autotune_kw)
            save_plan(path, plan)
            return plan, False
        log.warning(
            "plan cache %s was tuned for different GEMM shapes; re-tuning", path
        )
    plan = autotune_plan(gemms, train=require_bwd, mesh=mesh,
                         decode_buckets=buckets, attn=attn, scan=scan,
                         quant=quant, quant_budget=quant_budget,
                         **autotune_kw)
    if path:
        save_plan(path, plan)
    return plan, False


def activate_plan(plan: DataflowPlan | None) -> None:
    """Program the process-wide CMU: subsequent traced ``linear`` calls
    dispatch per the plan.  Pass None to clear."""
    global _ACTIVE_PLAN
    _ACTIVE_PLAN = plan


def active_plan() -> DataflowPlan | None:
    return _ACTIVE_PLAN
