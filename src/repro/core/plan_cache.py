"""Persistent CMU plan cache.

The measured autotune (``cmu.autotune_plan``) is a one-time, offline,
pre-deployment step — exactly the paper's CMU programming procedure.  This
module persists its output so serve/train **reload** plans instead of
re-tuning on every launch, and provides the process-wide "programmed CMU"
the model stack consults at trace time:

  * ``save_plan`` / ``load_plan``     — versioned JSON on disk
  * ``load_or_autotune``              — the serve/train entry point
  * ``activate_plan`` / ``active_plan`` — the in-process register file the
    paper's CMU MUX signals map to; ``models.layers.linear`` reads it when
    dispatching each projection to a flex kernel.

Schema versions (see docs/autotune.md for the full JSON shape):

  * v1 — fwd-only rows: (name, M, K, N, dataflow, est_cost, block, source).
  * v2 — adds per-layer backward sub-plans ``bwd_dx`` / ``bwd_dw`` (each a
    {dataflow, block, est_cost, source} row, or null for fwd-only plans).

A v1 file still **loads** (its rows are a strict subset of v2; the backward
sub-plans come back as None) — serving keeps working across the upgrade.
Training, which needs the sub-plans, passes ``require_bwd=True`` to
``load_or_autotune`` and a fwd-only cache is then re-tuned and overwritten,
never silently half-applied.  Files from a *newer* schema than this build
understands are rejected with a clear re-tune message.
"""

from __future__ import annotations

import json
import os

from .cmu import DataflowPlan, add_bwd_subplans, autotune_plan

PLAN_CACHE_VERSION = 2
# older schemas this build can still read (v1 rows are a subset of v2 rows)
COMPATIBLE_VERSIONS = (1, 2)

_ACTIVE_PLAN: DataflowPlan | None = None


def save_plan(path: str, plan: DataflowPlan) -> None:
    """Persist a plan as versioned JSON (atomic rename, so a crashed tune
    never leaves a half-written cache for the next launch to trip on)."""
    payload = {"version": PLAN_CACHE_VERSION, "layers": json.loads(plan.to_json())}
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2)
    os.replace(tmp, path)


def load_plan(path: str) -> DataflowPlan:
    with open(path) as f:
        try:
            payload = json.load(f)
        except json.JSONDecodeError as e:
            raise ValueError(
                f"plan cache {path} is not valid JSON ({e}) — delete it and re-tune"
            ) from e
    version = payload.get("version")
    if version not in COMPATIBLE_VERSIONS:
        raise ValueError(
            f"plan cache {path} has schema version {version}, but this build "
            f"reads {COMPATIBLE_VERSIONS} — delete it and re-tune (or serve "
            "with a matching build)"
        )
    if version < PLAN_CACHE_VERSION:
        import logging

        logging.getLogger(__name__).info(
            "plan cache %s uses schema v%d; loaded as v%d (backward sub-plans "
            "absent — training will re-tune)", path, version, PLAN_CACHE_VERSION,
        )
    return DataflowPlan.from_json(json.dumps(payload["layers"]))


def plan_matches(plan: DataflowPlan, gemms, require_bwd: bool = False) -> bool:
    """True when the plan was tuned for exactly these (name, M, K, N) GEMMs —
    the guard against silently applying a cache tuned for another arch or
    batch geometry.  With ``require_bwd`` the plan must also carry backward
    sub-plans for every layer (the training bar)."""
    planned = {(l.name, l.gemm.M, l.gemm.K, l.gemm.N) for l in plan.layers}
    wanted = {(g.name, g.M, g.K, g.N) for g in gemms}
    if planned != wanted:
        return False
    return plan.has_bwd() if require_bwd else True


def load_or_autotune(path: str | None, gemms, require_bwd: bool = False,
                     **autotune_kw):
    """Return ``(plan, loaded)`` — the cached plan when ``path`` exists and
    matches ``gemms``, otherwise a fresh autotune persisted to ``path``
    (when given).  A cache tuned for different GEMM shapes (other arch,
    other batch geometry), or one missing the backward sub-plans a training
    run needs (``require_bwd``), is re-tuned and overwritten, not silently
    applied.  A cache whose *forward* decisions match but which lacks the
    sub-plans is upgraded incrementally (only the dX/dW GEMMs are tuned —
    the measured forward decisions are kept)."""
    if path and os.path.exists(path):
        plan = load_plan(path)
        if plan_matches(plan, gemms, require_bwd=require_bwd):
            return plan, True
        import logging

        log = logging.getLogger(__name__)
        if plan_matches(plan, gemms):
            # fwd decisions are valid — tune only the missing bwd sub-GEMMs
            log.warning(
                "plan cache %s lacks backward sub-plans; tuning dX/dW only "
                "(keeping the forward decisions)", path
            )
            plan = add_bwd_subplans(plan, **autotune_kw)
            save_plan(path, plan)
            return plan, False
        log.warning(
            "plan cache %s was tuned for different GEMM shapes; re-tuning", path
        )
    plan = autotune_plan(gemms, train=require_bwd, **autotune_kw)
    if path:
        save_plan(path, plan)
    return plan, False


def activate_plan(plan: DataflowPlan | None) -> None:
    """Program the process-wide CMU: subsequent traced ``linear`` calls
    dispatch per the plan.  Pass None to clear."""
    global _ACTIVE_PLAN
    _ACTIVE_PLAN = plan


def active_plan() -> DataflowPlan | None:
    return _ACTIVE_PLAN
