"""CNN workload layer tables for the paper's Table I / Figs. 1, 6, 7.

Layer topologies transcribed from the cited architecture papers in ScaleSim
CSV convention (ifmap includes padding; FC layers expressed as 1x1 / KxK
convs).  The original ScaleSim topology CSVs are not available offline, so
these tables are reconstructed from the architecture definitions — exact
cycle counts therefore differ from the paper's, but per-layer optima and
flex-vs-static speedup bands are validated against the paper in
tests/test_paper_claims.py and benchmarks/table1_cycles.py.
"""

from __future__ import annotations

from .dataflow import ConvLayer

C = ConvLayer


def _dw(name: str, hw: int, ch: int, stride: int = 1) -> ConvLayer:
    # Depthwise conv modelled as one GEMM with K = 3*3 (per-channel filter
    # volume) and N = channels, ScaleSim's grouped-conv approximation.
    return C(name, hw, hw, 3, 3, 1, ch, stride)


ALEXNET = [
    C("conv1", 227, 227, 11, 11, 3, 96, 4),
    C("conv2", 31, 31, 5, 5, 96, 256, 1),
    C("conv3", 15, 15, 3, 3, 256, 384, 1),
    C("conv4", 15, 15, 3, 3, 384, 384, 1),
    C("conv5", 15, 15, 3, 3, 384, 256, 1),
    C("fc6", 6, 6, 6, 6, 256, 4096, 1),
    C("fc7", 1, 1, 1, 1, 4096, 4096, 1),
    C("fc8", 1, 1, 1, 1, 4096, 1000, 1),
]

RESNET18 = (
    [C("conv1", 230, 230, 7, 7, 3, 64, 2)]
    + [C(f"conv2_{i}", 58, 58, 3, 3, 64, 64, 1) for i in range(1, 5)]
    + [
        C("conv3_1", 58, 58, 3, 3, 64, 128, 2),
        C("conv3_ds", 56, 56, 1, 1, 64, 128, 2),
        C("conv3_2", 30, 30, 3, 3, 128, 128, 1),
        C("conv3_3", 30, 30, 3, 3, 128, 128, 1),
        C("conv3_4", 30, 30, 3, 3, 128, 128, 1),
        C("conv4_1", 30, 30, 3, 3, 128, 256, 2),
        C("conv4_ds", 28, 28, 1, 1, 128, 256, 2),
        C("conv4_2", 16, 16, 3, 3, 256, 256, 1),
        C("conv4_3", 16, 16, 3, 3, 256, 256, 1),
        C("conv4_4", 16, 16, 3, 3, 256, 256, 1),
        C("conv5_1", 16, 16, 3, 3, 256, 512, 2),
        C("conv5_ds", 14, 14, 1, 1, 256, 512, 2),
        C("conv5_2", 9, 9, 3, 3, 512, 512, 1),
        C("conv5_3", 9, 9, 3, 3, 512, 512, 1),
        C("conv5_4", 9, 9, 3, 3, 512, 512, 1),
        C("fc", 1, 1, 1, 1, 512, 1000, 1),
    ]
)

VGG13 = [
    C("conv1_1", 226, 226, 3, 3, 3, 64, 1),
    C("conv1_2", 226, 226, 3, 3, 64, 64, 1),
    C("conv2_1", 114, 114, 3, 3, 64, 128, 1),
    C("conv2_2", 114, 114, 3, 3, 128, 128, 1),
    C("conv3_1", 58, 58, 3, 3, 128, 256, 1),
    C("conv3_2", 58, 58, 3, 3, 256, 256, 1),
    C("conv4_1", 30, 30, 3, 3, 256, 512, 1),
    C("conv4_2", 30, 30, 3, 3, 512, 512, 1),
    C("conv5_1", 16, 16, 3, 3, 512, 512, 1),
    C("conv5_2", 16, 16, 3, 3, 512, 512, 1),
    C("fc6", 7, 7, 7, 7, 512, 4096, 1),
    C("fc7", 1, 1, 1, 1, 4096, 4096, 1),
    C("fc8", 1, 1, 1, 1, 4096, 1000, 1),
]

MOBILENET = (
    [C("conv1", 226, 226, 3, 3, 3, 32, 2)]
    + [
        _dw("dw2", 112, 32), C("pw2", 112, 112, 1, 1, 32, 64, 1),
        _dw("dw3", 114, 64, 2), C("pw3", 56, 56, 1, 1, 64, 128, 1),
        _dw("dw4", 56, 128), C("pw4", 56, 56, 1, 1, 128, 128, 1),
        _dw("dw5", 58, 128, 2), C("pw5", 28, 28, 1, 1, 128, 256, 1),
        _dw("dw6", 28, 256), C("pw6", 28, 28, 1, 1, 256, 256, 1),
        _dw("dw7", 30, 256, 2), C("pw7", 14, 14, 1, 1, 256, 512, 1),
    ]
    + [
        l
        for i in range(5)
        for l in (_dw(f"dw{8+i}", 14, 512), C(f"pw{8+i}", 14, 14, 1, 1, 512, 512, 1))
    ]
    + [
        _dw("dw13", 16, 512, 2), C("pw13", 7, 7, 1, 1, 512, 1024, 1),
        _dw("dw14", 7, 1024), C("pw14", 7, 7, 1, 1, 1024, 1024, 1),
        C("fc", 1, 1, 1, 1, 1024, 1000, 1),
    ]
)


def _inception(tag: str, hw: int, cin: int, b1: int, b2a: int, b2b: int,
               b3a: int, b3b: int, pp: int) -> list[ConvLayer]:
    return [
        C(f"{tag}_1x1", hw, hw, 1, 1, cin, b1, 1),
        C(f"{tag}_3x3r", hw, hw, 1, 1, cin, b2a, 1),
        C(f"{tag}_3x3", hw + 2, hw + 2, 3, 3, b2a, b2b, 1),
        C(f"{tag}_5x5r", hw, hw, 1, 1, cin, b3a, 1),
        C(f"{tag}_5x5", hw + 4, hw + 4, 5, 5, b3a, b3b, 1),
        C(f"{tag}_pool", hw, hw, 1, 1, cin, pp, 1),
    ]


GOOGLENET = (
    [
        C("conv1", 230, 230, 7, 7, 3, 64, 2),
        C("conv2r", 56, 56, 1, 1, 64, 64, 1),
        C("conv2", 58, 58, 3, 3, 64, 192, 1),
    ]
    + _inception("i3a", 28, 192, 64, 96, 128, 16, 32, 32)
    + _inception("i3b", 28, 256, 128, 128, 192, 32, 96, 64)
    + _inception("i4a", 14, 480, 192, 96, 208, 16, 48, 64)
    + _inception("i4b", 14, 512, 160, 112, 224, 24, 64, 64)
    + _inception("i4c", 14, 512, 128, 128, 256, 24, 64, 64)
    + _inception("i4d", 14, 512, 112, 144, 288, 32, 64, 64)
    + _inception("i4e", 14, 528, 256, 160, 320, 32, 128, 128)
    + _inception("i5a", 7, 832, 256, 160, 320, 32, 128, 128)
    + _inception("i5b", 7, 832, 384, 192, 384, 48, 128, 128)
    + [C("fc", 1, 1, 1, 1, 1024, 1000, 1)]
)

YOLO_TINY = [
    C("conv1", 418, 418, 3, 3, 3, 16, 1),
    C("conv2", 210, 210, 3, 3, 16, 32, 1),
    C("conv3", 106, 106, 3, 3, 32, 64, 1),
    C("conv4", 54, 54, 3, 3, 64, 128, 1),
    C("conv5", 28, 28, 3, 3, 128, 256, 1),
    C("conv6", 15, 15, 3, 3, 256, 512, 1),
    C("conv7", 15, 15, 3, 3, 512, 1024, 1),
    C("conv8", 15, 15, 3, 3, 1024, 1024, 1),
    C("conv9", 13, 13, 1, 1, 1024, 125, 1),
]

FASTER_RCNN = [
    # ZF-style backbone + RPN + detection head (paper-cited Faster R-CNN [20]).
    C("conv1", 230, 230, 7, 7, 3, 96, 2),
    C("conv2", 58, 58, 5, 5, 96, 256, 2),
    C("conv3", 15, 15, 3, 3, 256, 384, 1),
    C("conv4", 15, 15, 3, 3, 384, 384, 1),
    C("conv5", 15, 15, 3, 3, 384, 256, 1),
    C("rpn_conv", 15, 15, 3, 3, 256, 512, 1),
    C("rpn_cls", 13, 13, 1, 1, 512, 18, 1),
    C("rpn_bbox", 13, 13, 1, 1, 512, 36, 1),
    C("fc6", 7, 7, 7, 7, 256, 4096, 1),
    C("fc7", 1, 1, 1, 1, 4096, 4096, 1),
    C("cls", 1, 1, 1, 1, 4096, 21, 1),
    C("bbox", 1, 1, 1, 1, 4096, 84, 1),
]

WORKLOADS: dict[str, list[ConvLayer]] = {
    "alexnet": ALEXNET,
    "fasterrcnn": FASTER_RCNN,
    "googlenet": GOOGLENET,
    "mobilenet": MOBILENET,
    "resnet18": RESNET18,
    "vgg13": VGG13,
    "yolo_tiny": YOLO_TINY,
}

# Paper Table I — reference values for validation (cycles, S = 32x32).
PAPER_TABLE1 = {
    "alexnet": {"flex": 8.598e5, "IS": 1.176e6, "OS": 8.852e5, "WS": 1.188e6},
    "fasterrcnn": {"flex": 3.922e6, "IS": 5.640e6, "OS": 4.368e6, "WS": 4.710e6},
    "googlenet": {"flex": 1.566e6, "IS": 2.525e6, "OS": 1.660e6, "WS": 1.988e6},
    "mobilenet": {"flex": 1.206e6, "IS": 2.349e6, "OS": 1.373e6, "WS": 1.531e6},
    "resnet18": {"flex": 1.636e6, "IS": 2.839e6, "OS": 1.718e6, "WS": 2.520e6},
    "vgg13": {"flex": 2.172e7, "IS": 2.971e7, "OS": 2.231e7, "WS": 3.046e7},
    "yolo_tiny": {"flex": 2.131e6, "IS": 3.729e6, "OS": 2.550e6, "WS": 3.337e6},
}
