"""Dataflow definitions and analytical cost models.

The Flex-TPU paper's object of study is the *dataflow* of a systolic array:
which operand is pinned ("stationary") in the PEs while the others stream.
This module defines the three dataflows and two cost models over them:

1. ``systolic_cycles`` — a ScaleSim-V2-style analytical clock-cycle model for an
   R x C systolic array executing a GEMM under IS/OS/WS.  This is the model the
   paper's own evaluation (Table I, Figs. 1/6/7) is built on; we re-derive the
   fold/fill/drain arithmetic from the systolic pipeline first principles and
   validate the resulting *per-layer optima and flex speedups* against the
   paper's reported ranges (see tests/test_paper_claims.py).

2. ``hbm_traffic_bytes`` — the TPU-native analogue used by the Pallas kernels:
   for a blocked matmul on a real TPU the "dataflow" is the grid loop order,
   and what differs between IS/OS/WS is how many times each operand's blocks
   are fetched from HBM into VMEM.  The CMU uses this model to pick the
   per-layer dataflow for the kernel path.

Both models are pure functions of layer shape — deliberately so: the paper's
core claim is that the optimum is a function of layer shape, decidable offline.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass


class Dataflow(enum.Enum):
    """The three classic systolic dataflows (paper Section I)."""

    IS = "input_stationary"
    OS = "output_stationary"
    WS = "weight_stationary"

    @property
    def short(self) -> str:
        return self.name


ALL_DATAFLOWS = (Dataflow.IS, Dataflow.OS, Dataflow.WS)


@dataclass(frozen=True)
class GemmShape:
    """A GEMM ``C[M,N] = A[M,K] @ B[K,N]``.

    For a conv layer lowered via im2col (ScaleSim's convention):
      M = output pixels = H_out * W_out  (per image)
      K = R * S * C_in   (filter volume)
      N = C_out          (number of filters)
    For an LM projection: M = tokens, K = d_in, N = d_out.
    """

    M: int
    K: int
    N: int
    name: str = ""

    @property
    def flops(self) -> int:
        return 2 * self.M * self.K * self.N

    @property
    def macs(self) -> int:
        return self.M * self.K * self.N


@dataclass(frozen=True)
class ConvLayer:
    """A convolution layer in the paper's CNN workloads (ScaleSim topology row)."""

    name: str
    ifmap_h: int
    ifmap_w: int
    filt_h: int
    filt_w: int
    channels: int
    num_filters: int
    stride: int

    def out_hw(self) -> tuple[int, int]:
        oh = (self.ifmap_h - self.filt_h) // self.stride + 1
        ow = (self.ifmap_w - self.filt_w) // self.stride + 1
        return max(oh, 1), max(ow, 1)

    def gemm(self) -> GemmShape:
        oh, ow = self.out_hw()
        return GemmShape(
            M=oh * ow,
            K=self.filt_h * self.filt_w * self.channels,
            N=self.num_filters,
            name=self.name,
        )


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def systolic_cycles(shape: GemmShape, dataflow: Dataflow, rows: int, cols: int) -> int:
    """Analytical cycles for one GEMM on an ``rows x cols`` systolic array.

    Fold/fill/drain model (ScaleSim-V2 "analytical" formulation):

    Each dataflow pins one operand tile of at most ``rows x cols`` elements in
    the array ("the fold") and streams a third dimension through it.  A fold
    costs: (preload of the stationary tile, where applicable) + (stream length)
    + (array skew fill/drain ``rows + cols - 2``) + (output drain, where the
    outputs are resident and must be shifted out).

      OS: stationary C tile (rows x cols over M x N); stream K.
          folds = ceil(M/rows) * ceil(N/cols)
          cycles/fold = K + (rows + cols - 2)   [skewed operand fill]
                        + rows                  [shift resident outputs out]
      WS: stationary B tile (rows x cols over K x N); stream M.
          folds = ceil(K/rows) * ceil(N/cols)
          cycles/fold = rows                    [preload weights, row/cycle]
                        + M + (rows + cols - 2) [stream + skew/drain]
      IS: stationary A tile (rows x cols over M x K); stream N.
          folds = ceil(M/rows) * ceil(K/cols)
          cycles/fold = rows                    [preload inputs]
                        + N + (rows + cols - 2)

    Folds are executed back-to-back without overlap (ScaleSim's conservative
    assumption).  The qualitative structure — WS wins when M >> K·N/S², OS wins
    for K-heavy deep layers, IS wins for N-light layers — is exactly the
    paper's Fig. 1 behaviour.
    """
    M, K, N = shape.M, shape.K, shape.N
    skew = rows + cols - 2
    if dataflow is Dataflow.OS:
        folds = _ceil_div(M, rows) * _ceil_div(N, cols)
        per_fold = K + skew + rows
    elif dataflow is Dataflow.WS:
        folds = _ceil_div(K, rows) * _ceil_div(N, cols)
        per_fold = rows + M + skew
    elif dataflow is Dataflow.IS:
        folds = _ceil_div(M, rows) * _ceil_div(K, cols)
        per_fold = rows + N + skew
    else:  # pragma: no cover - exhaustive enum
        raise ValueError(dataflow)
    return folds * per_fold


def best_dataflow(shape: GemmShape, rows: int, cols: int) -> tuple[Dataflow, int]:
    """Exhaustive 3-way search the paper performs offline per layer."""
    best = min(ALL_DATAFLOWS, key=lambda d: systolic_cycles(shape, d, rows, cols))
    return best, systolic_cycles(shape, best, rows, cols)


# ---------------------------------------------------------------------------
# TPU-native (kernel-level) cost model: HBM <-> VMEM block traffic.
# ---------------------------------------------------------------------------

# The single VMEM budget every planner and feasibility check shares: the
# analytical pruning, the measured autotune, and the strip-feasibility check
# all answer to this one constant (a conservative per-core figure — block
# working sets plus the f32 accumulator strip must fit under it).
VMEM_BUDGET_BYTES = 96 * 1024 * 1024


@dataclass(frozen=True)
class KernelCost:
    """Estimated cost of one blocked matmul under a given dataflow."""

    hbm_bytes: int
    mxu_flops: int
    vmem_bytes: int  # resident working set, must be <= VMEM capacity

    def time_s(self, peak_flops: float = 197e12, hbm_bw: float = 819e9) -> float:
        """Roofline time: max of compute and memory terms."""
        return max(self.mxu_flops / peak_flops, self.hbm_bytes / hbm_bw)


def hbm_traffic_bytes(
    shape: GemmShape,
    dataflow: Dataflow,
    bm: int,
    bk: int,
    bn: int,
    in_bytes: int = 2,
    out_bytes: int = 4,
    strip: int = 1,
    *,
    a_bytes: int | None = None,
    b_bytes: int | None = None,
    scale_bytes: int = 0,
) -> KernelCost:
    """HBM traffic for a blocked matmul with block sizes (bm, bk, bn).

    The Pallas grid order decides block residency (DESIGN.md §2.1):

      OS  grid (i, j, k): C block stays in VMEM across the k loop.
          A fetched Mb*Nb*Kb times? No: A[i,k] changes with (i,k) and is
          re-fetched for each j; B[k,j] re-fetched for each i.
          bytes = Nb * (M*K) * in  +  Mb * (K*N) * in  +  (M*N) * out
      WS  grid (j, k, i): B block pinned across the i loop.
          bytes = (K*N) * in  +  Nb * (M*K) * in  +  Kb * (M*N) * (rw partials)
      IS  grid (i, k, j): A block pinned across the j loop.
          bytes = (M*K) * in  +  Mb * (K*N) * in  +  Kb * (M*N) * (rw partials)

    where Mb=ceil(M/bm) etc.  WS/IS pay partial-sum read+write traffic when
    K doesn't fit one block (Kb > 1); OS never writes partials — this is the
    VMEM-level image of the paper's "outputs accumulate in place" argument.

    **Two-level stationarity (``strip`` >= 2).**  WS/IS can instead pin a
    *strip* of ``strip`` f32 output blocks in VMEM scratch and reorder the
    grid so each strip's k-revisits are consecutive: partial sums never
    touch HBM (one clean write per output block, like OS) and the stationary
    operand stays pinned across the strip's inner sweep exactly as before.
    The price is a re-fetch of the *stationary* operand once per strip —
    the schedule trades ``(2*Kb - 1)`` output round-trips for
    ``ceil(streamed_blocks / strip)`` fetches of the pinned operand:

      WS strip: bytes = ceil(Mb/strip) * (K*N) * in + Nb * (M*K) * in + c
      IS strip: bytes = ceil(Nb/strip) * (M*K) * in + Mb * (K*N) * in + c

    and the VMEM working set grows by the strip's resident output buffers:
    the f32 accumulator strip plus the same-extent copy-out block the
    fused kernels allocate, ``strip * bm * bn * (4 + out_bytes)`` (an
    over-count for the plain-f32 case, where the two share one buffer —
    conservative on purpose: a strip the budget admits must actually fit).
    ``strip=1`` is exactly the streamed schedule above; OS ignores
    ``strip`` (its accumulator is already VMEM-resident, and the strip
    generalisation of OS *is* the IS strip schedule).

    **Per-operand dtypes.**  ``in_bytes`` is the legacy both-operands
    width; quantized candidates instead pass ``a_bytes``/``b_bytes``
    explicitly (weight-only quant: ``a_bytes=2, b_bytes=1``) plus
    ``scale_bytes`` for the per-output-channel f32 scale row that streams
    with the B operand — folded into the B term so every refetch factor
    multiplies it too, and into the VMEM working set as one ``bn``-wide
    row per resident B block.
    """
    M, K, N = shape.M, shape.K, shape.N
    if a_bytes is None:
        a_bytes = in_bytes
    if b_bytes is None:
        b_bytes = in_bytes
    Mb, Kb, Nb = _ceil_div(M, bm), _ceil_div(K, bk), _ceil_div(N, bn)
    a = M * K * a_bytes
    b = K * N * b_bytes + N * scale_bytes
    c = M * N * out_bytes
    blocks_vmem = bm * bk * a_bytes + bk * bn * b_bytes + bn * scale_bytes
    if dataflow is Dataflow.OS:
        hbm = Nb * a + Mb * b + c
        vmem = blocks_vmem + bm * bn * 4  # f32 accumulator
    elif dataflow is Dataflow.WS:
        if strip > 1:
            hbm = _ceil_div(Mb, strip) * b + Nb * a + c
            # f32 accumulator strip + the fused kernels' copy-out strip
            vmem = blocks_vmem + strip * bm * bn * (4 + out_bytes)
        else:
            partial_rw = (2 * Kb - 1) * c if Kb > 1 else c
            hbm = b + Nb * a + partial_rw
            vmem = blocks_vmem + bm * bn * 4
    elif dataflow is Dataflow.IS:
        if strip > 1:
            hbm = _ceil_div(Nb, strip) * a + Mb * b + c
            vmem = blocks_vmem + strip * bm * bn * (4 + out_bytes)
        else:
            partial_rw = (2 * Kb - 1) * c if Kb > 1 else c
            hbm = a + Mb * b + partial_rw
            vmem = blocks_vmem + bm * bn * 4
    else:  # pragma: no cover
        raise ValueError(dataflow)
    return KernelCost(hbm_bytes=hbm, mxu_flops=shape.flops, vmem_bytes=vmem)


def strip_blocks(shape: GemmShape, dataflow: Dataflow, bm: int, bn: int) -> int:
    """Block count of the axis a WS/IS accumulator strip tiles (the streamed
    output axis): M-blocks for WS, N-blocks for IS.  1 for OS — its strip
    generalisation is the IS strip schedule, so OS only ever runs strip=1."""
    if dataflow is Dataflow.WS:
        return _ceil_div(shape.M, bm)
    if dataflow is Dataflow.IS:
        return _ceil_div(shape.N, bn)
    return 1


def strip_candidates(n_blocks: int) -> list[int]:
    """Strip depths worth trying over an axis of ``n_blocks`` output blocks:
    every divisor (ragged strips would need masked flushes, so the kernels
    require the strip to tile the axis exactly).  1 = the streamed schedule."""
    if n_blocks <= 1:
        return [1]
    divs = set()
    d = 1
    while d * d <= n_blocks:
        if n_blocks % d == 0:
            divs.add(d)
            divs.add(n_blocks // d)
        d += 1
    return sorted(divs)


def best_kernel_dataflow(
    shape: GemmShape,
    bm: int = 512,
    bk: int = 512,
    bn: int = 512,
    vmem_limit: int = VMEM_BUDGET_BYTES,
) -> tuple[Dataflow, KernelCost]:
    """Pick the dataflow minimising roofline time subject to VMEM fit."""
    candidates: list[tuple[float, Dataflow, KernelCost]] = []
    for df in ALL_DATAFLOWS:
        cost = hbm_traffic_bytes(shape, df, bm, bk, bn, in_bytes=2)
        if cost.vmem_bytes <= vmem_limit:
            candidates.append((cost.time_s(), df, cost))
    if not candidates:
        raise ValueError(f"no dataflow fits VMEM for {shape}")
    _, df, cost = min(candidates, key=lambda t: t[0])
    return df, cost


DEFAULT_BLOCK_CANDIDATES = (128, 256, 512, 1024, 2048, 4096, 8192)

# Sublane-aligned skinny blocks for the M dimension of decode-step GEMMs
# (M = batch, often <= 32): without them the tuner's smallest bm is 128 and
# a 16-row projection models >87% wasted MXU occupancy.  f32 tiles need 8
# sublanes (bf16 wants 16 — the tuner may still pick 8; Mosaic relayouts).
SKINNY_BLOCK_CANDIDATES = (8, 16, 32, 64)


def kernel_block_candidates(
    d: int,
    candidates: tuple[int, ...] = DEFAULT_BLOCK_CANDIDATES,
    sublane: bool = False,
) -> list[int]:
    """MXU-aligned block sizes worth trying for one GEMM dimension of ``d``.

    With ``sublane`` (the M dimension), a dim smaller than one MXU tile also
    offers the sublane-aligned skinny sizes covering it, so skinny GEMMs
    (decode-step projections) are not forced to pad to 128+ rows.
    """
    rounded = max(_ceil_div(d, 128) * 128, 128)
    cs = [c for c in candidates if c <= rounded]
    if sublane and d < 128:
        skinny = [s for s in SKINNY_BLOCK_CANDIDATES if s >= d]
        cs = [s for s in SKINNY_BLOCK_CANDIDATES if s < d] + skinny[:1] + cs
    if rounded <= 16384 and rounded not in cs:
        cs.append(rounded)  # exact-fit block (e.g. bk = K kills partials)
    return cs or [128]


def tune_kernel_dataflow(
    shape: GemmShape,
    vmem_limit: int = VMEM_BUDGET_BYTES,
    candidates: tuple[int, ...] = DEFAULT_BLOCK_CANDIDATES,
) -> tuple[Dataflow, tuple[int, int, int], KernelCost]:
    """Co-tune (dataflow, block shape) under a VMEM budget — streamed
    (strip=1) schedules only; the production tuner that also searches the
    accumulator-strip axis is ``cmu._ranked_candidates``/``autotune_plan``.

    This is the full CMU: the paper tunes which operand is pinned; on TPU the
    block shape decides *how much* of it is pinned, so the two must be chosen
    together.  E.g. with bk >= K the WS/IS partial-sum traffic vanishes and
    WS wins tall training GEMMs while IS wins decode (inputs pinned, weights
    streamed once) — matching the paper's per-layer narrative.
    """

    def blocks_for(d: int) -> list[int]:
        return kernel_block_candidates(d, candidates)

    best: tuple[float, Dataflow, tuple[int, int, int], KernelCost] | None = None
    for df in ALL_DATAFLOWS:
        for bm in blocks_for(shape.M):
            for bk in blocks_for(shape.K):
                for bn in blocks_for(shape.N):
                    cost = hbm_traffic_bytes(shape, df, bm, bk, bn,
                                             in_bytes=2)
                    if cost.vmem_bytes > vmem_limit:
                        continue
                    t = cost.time_s()
                    if best is None or t < best[0] - 1e-18 or (
                        abs(t - best[0]) < 1e-18 and cost.hbm_bytes < best[3].hbm_bytes
                    ):
                        best = (t, df, (bm, bk, bn), cost)
    assert best is not None
    return best[1], best[2], best[3]


def arithmetic_intensity(shape: GemmShape, in_bytes: int = 2, out_bytes: int = 2) -> float:
    """FLOPs per HBM byte at perfect reuse (the roofline upper bound)."""
    bytes_min = (shape.M * shape.K + shape.K * shape.N) * in_bytes + shape.M * shape.N * out_bytes
    return shape.flops / bytes_min


def mxu_utilization(shape: GemmShape, mxu: int = 128) -> float:
    """Fraction of MXU lanes busy given dimension padding to the MXU size."""

    def pad(d: int) -> int:
        return _ceil_div(d, mxu) * mxu

    return (shape.M * shape.K * shape.N) / (pad(shape.M) * pad(shape.K) * pad(shape.N))


# ---------------------------------------------------------------------------
# Attention: the flash-kernel schedule family's analytical cost model.
# ---------------------------------------------------------------------------

#: (bq, bk) candidates for the prefill flash-attention sweep.  Smaller than
#: the GEMM grid: score tiles are (bq, bk) f32 in VMEM and the row axis of a
#: smoke-sized prefill rarely exceeds a few hundred.
ATTN_BLOCK_CANDIDATES = (64, 128, 256, 512)


@dataclass(frozen=True)
class AttnShape:
    """Planning fingerprint of one self-attention op (per layer shape, like
    ``GemmShape`` for projections).  ``seq``/``kv`` are query / key lengths,
    heads are the model's query and KV head counts.  The GQA group axis is
    folded into rows exactly as ``kernels.flash_attention.mha_flash`` does,
    so the model prices what the kernel actually runs."""

    seq: int
    kv: int
    heads: int
    kv_heads: int
    head_dim: int
    name: str = "attn.sdpa"

    @property
    def group(self) -> int:
        return max(self.heads // self.kv_heads, 1)

    @property
    def rows(self) -> int:
        """Q rows per (batch, kv-head) kernel instance after GQA folding."""
        return self.group * self.seq

    @property
    def flops(self) -> int:
        # QK^T and PV each: 2 * rows * kv * hd MACs-as-flops, per kv head.
        return 4 * self.kv_heads * self.rows * self.kv * self.head_dim

    @property
    def macs(self) -> int:
        return self.flops // 2


def attn_traffic_bytes(shape: AttnShape, sweep: str, bq: int, bk: int,
                       in_bytes: int = 2, out_bytes: int = 2) -> KernelCost:
    """HBM traffic + VMEM residency of one prefill flash-attention schedule.

    Mirrors ``hbm_traffic_bytes`` for the attention grid.  Per kv head:

      q-stationary:  q + o move once; K/V re-stream once per q tile:
          hbm  = q_bytes + nq * kv_bytes + o_bytes
          vmem = (bq + 2*bk) * hd * in + bq * hd * 4 + 2 * bq * 4
      kv-stationary: K/V move once; q re-streams once per kv tile, and the
      whole-rows accumulator slab (f32 acc + copy-out + m/l stats) is
      VMEM-resident so the output flushes exactly once:
          hbm  = kv_bytes + nkv * q_bytes + o_bytes
          vmem = (bq + 2*bk) * hd * in + rows * hd * (4 + out) + 2 * rows * 4

    The kv-stationary HBM win scales with ``nq = rows / bq`` — i.e. with
    the GQA group and context length — which is exactly the paper's
    shape-decides-the-dataflow argument transplanted to attention.
    """
    if sweep not in ("q", "kv"):
        raise ValueError(f"unknown attention sweep {sweep!r}")
    rows, kv, hd = shape.rows, shape.kv, shape.head_dim
    bq, bk = min(bq, rows), min(bk, kv)
    nq, nkv = _ceil_div(rows, bq), _ceil_div(kv, bk)
    q_bytes = rows * hd * in_bytes
    kv_bytes = 2 * kv * hd * in_bytes
    o_bytes = rows * hd * out_bytes
    blocks_vmem = (bq + 2 * bk) * hd * in_bytes
    if sweep == "q":
        hbm = shape.kv_heads * (q_bytes + nq * kv_bytes + o_bytes)
        vmem = blocks_vmem + bq * hd * 4 + 2 * bq * 4
    else:
        hbm = shape.kv_heads * (kv_bytes + nkv * q_bytes + o_bytes)
        vmem = blocks_vmem + rows * hd * (4 + out_bytes) + 2 * rows * 4
    return KernelCost(hbm_bytes=hbm, mxu_flops=shape.flops, vmem_bytes=vmem)


#: Tunable chunk lengths for the flex chunked-scan family.  Exp-safety bounds
#: the ladder: every in-chunk exponent is within ``|LOG_DECAY_MIN| * chunk =
#: 3 * chunk`` (models.ssm), so all candidates keep exp() arguments well
#: inside f32 range (limit ~88).
SCAN_CHUNK_CANDIDATES = (8, 16, 24)


@dataclass(frozen=True)
class ScanShape:
    """Planning fingerprint of one chunked diagonal-decay scan (per layer
    shape, like ``AttnShape`` for attention).  ``seq`` is the (padded)
    token count per batch row, ``heads`` the recurrence head count,
    ``key_dim``/``val_dim`` the (N, M) state slab sides.  ``post_update``
    records the recurrence convention (True = Mamba2, False = RWKV) — it
    changes the fused epilogue the kernel runs, so measured timings key on
    it."""

    batch: int
    seq: int
    heads: int
    key_dim: int   # N: decay/state rows
    val_dim: int   # M: value/state cols
    post_update: bool = False
    name: str = "ssm.scan"

    @property
    def bh(self) -> int:
        """Folded (batch, head) kernel instances."""
        return self.batch * self.heads

    @property
    def state_bytes(self) -> int:
        """The full f32 state slab the "state" sweep pins in VMEM."""
        return self.bh * self.key_dim * self.val_dim * 4

    @property
    def flops(self) -> int:
        # per token: L-wide score row (N), output row (M), and the rank-1
        # state update + inter-chunk read (2*N*M) — L taken at the default
        # 16-chunk so the fingerprint doesn't depend on the tuned schedule
        L = 16
        per_tok = L * (self.key_dim + self.val_dim) + 2 * self.key_dim * self.val_dim
        return 2 * self.bh * self.seq * per_tok

    @property
    def macs(self) -> int:
        return self.flops // 2


def scan_traffic_bytes(shape: ScanShape, sweep: str, chunk: int,
                       in_bytes: int = 2, out_bytes: int = 2) -> KernelCost:
    """HBM traffic + VMEM residency of one chunked-scan schedule.

    Mirrors ``attn_traffic_bytes`` for the scan grid (C chunks outer x B*H
    inner, one (L, .) tile set per step).  The r/k/v/log_w inputs and the o
    output move exactly once under *both* sweeps (every block is visited
    once); the sweeps differ only in how the running (N, M) f32 state
    travels:

      state-stationary: the whole ``bh*N*M`` f32 slab is a never-moving
      output block — VMEM-resident across the grid, written once:
          hbm  = streams + state_bytes
          vmem = blocks + state_bytes
      output-stationary: the state is a per-(b,h) block revisited
      non-consecutively across the chunk axis, so it round-trips HBM every
      chunk step (read-modify-write), and VMEM holds just one block:
          hbm  = streams + 2 * C * state_bytes
          vmem = blocks + 2 * N * M * 4

    The state-stationary HBM win scales with C = seq/chunk; its VMEM cost
    scales with ``batch*heads*N*M`` — which is exactly the paper's
    shape-decides-the-dataflow argument transplanted to the scan: long
    prefills at small batch want "state", large-batch prefills overflow the
    96 MiB budget and fall back to "out".
    """
    if sweep not in ("state", "out"):
        raise ValueError(f"unknown scan sweep {sweep!r}")
    T, n, m = shape.seq, shape.key_dim, shape.val_dim
    L = min(chunk, T)
    C = _ceil_div(T, L)
    # per-(b,h) sequential streams, each moved exactly once
    rk_bytes = 2 * T * n * in_bytes          # r, k
    lw_bytes = T * n * 4                     # log_w (f32)
    v_bytes = T * m * in_bytes
    o_bytes = T * m * out_bytes
    streams = shape.bh * (rk_bytes + lw_bytes + v_bytes + o_bytes)
    # one grid step's tile set (f32 compute copies + the (L, L) score tile)
    blocks = (3 * L * n + L * m) * 4 + L * L * 4 + L * m * 4 + n * m * 4
    if sweep == "state":
        hbm = streams + shape.state_bytes
        vmem = blocks + shape.state_bytes
    else:
        hbm = streams + 2 * C * shape.state_bytes
        vmem = blocks + 2 * n * m * 4
    return KernelCost(hbm_bytes=hbm, mxu_flops=shape.flops, vmem_bytes=vmem)


def scan_decode_traffic_bytes(shape: ScanShape, kind: str, bucket: int,
                              in_bytes: int = 2,
                              out_bytes: int = 2) -> KernelCost:
    """HBM traffic of one bucketed decode-scan step.

    ``kind="fused"`` runs the single Pallas step kernel: state in + state
    out, one HBM round trip.  ``kind="einsum"`` is the jnp recurrence,
    which materializes the ``k v^T`` outer product as an HBM intermediate
    between ops — an extra state-sized write + read (3x the state bytes).
    The analytical gap makes "fused" the default pick; a measured run can
    still override it per bucket.
    """
    if kind not in ("fused", "einsum"):
        raise ValueError(f"unknown decode scan kind {kind!r}")
    n, m = shape.key_dim, shape.val_dim
    bh = bucket * shape.heads
    state = bh * n * m * 4
    io = bh * (3 * n * in_bytes + n * 4 + m * in_bytes + m * out_bytes)
    flops = 2 * bh * (2 * n * m + n + m)
    if kind == "fused":
        hbm = io + 2 * state
        vmem = io + 2 * state  # whole-problem blocks, no grid
    else:
        hbm = io + 3 * state + state  # + kv intermediate round trip
        vmem = 2 * state
    return KernelCost(hbm_bytes=hbm, mxu_flops=flops, vmem_bytes=vmem)


def attn_decode_traffic_bytes(shape: AttnShape, kind: str, bucket: int,
                              cache_len: int | None = None,
                              block_size: int = 16,
                              in_bytes: int = 2,
                              out_bytes: int = 2) -> KernelCost:
    """HBM traffic of one bucketed decode-attention step over a paged cache.

    ``kind="paged"`` reads each K/V block from the pool exactly once, in
    place; ``kind="gather"`` is the pure-jnp baseline, which reads the pool,
    writes a densified (bucket, cache_len) copy, then reads it back — 3x the
    cache bytes.  The analytical gap is what makes the paged kernel the
    default pick; a measured run can still override it per bucket.
    """
    if kind not in ("paged", "gather"):
        raise ValueError(f"unknown decode attention kind {kind!r}")
    kv = cache_len if cache_len is not None else shape.kv
    hd, hkv = shape.head_dim, shape.kv_heads
    q_bytes = bucket * shape.heads * hd * in_bytes
    o_bytes = bucket * shape.heads * hd * out_bytes
    cache_bytes = 2 * bucket * kv * hkv * hd * in_bytes
    flops = 4 * bucket * shape.heads * kv * hd
    if kind == "paged":
        hbm = q_bytes + cache_bytes + o_bytes
        vmem = (shape.heads * hd * in_bytes
                + 2 * block_size * hkv * hd * in_bytes
                + shape.heads * hd * 4 + 2 * shape.heads * 4)
    else:
        hbm = q_bytes + 3 * cache_bytes + o_bytes
        vmem = (shape.heads * hd + 2 * kv * hkv * hd) * in_bytes
    return KernelCost(hbm_bytes=hbm, mxu_flops=flops, vmem_bytes=vmem)
