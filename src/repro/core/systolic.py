"""ScaleSim-V2-style systolic array simulation for whole networks.

The paper evaluates Flex-TPU with ScaleSim V2 (cycle-accurate simulator):
run every layer of a CNN under each of IS/OS/WS, record per-layer cycles,
and — for Flex-TPU — take the per-layer minimum (the CMU's offline choice).
This module reproduces that evaluation pipeline on our analytical cycle model
(`core.dataflow.systolic_cycles`), plus an *event-exact* small-array simulator
used to validate the analytical model in tests.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .dataflow import (
    ALL_DATAFLOWS,
    ConvLayer,
    Dataflow,
    GemmShape,
    systolic_cycles,
)


@dataclass(frozen=True)
class LayerResult:
    name: str
    gemm: GemmShape
    cycles: dict[Dataflow, int]

    @property
    def best(self) -> tuple[Dataflow, int]:
        df = min(self.cycles, key=self.cycles.get)  # type: ignore[arg-type]
        return df, self.cycles[df]


@dataclass
class NetworkResult:
    """Per-network simulation summary — one row of the paper's Table I."""

    model: str
    array: int
    layers: list[LayerResult] = field(default_factory=list)

    @property
    def flex_cycles(self) -> int:
        return sum(l.best[1] for l in self.layers)

    def static_cycles(self, dataflow: Dataflow) -> int:
        return sum(l.cycles[dataflow] for l in self.layers)

    def speedup(self, dataflow: Dataflow) -> float:
        return self.static_cycles(dataflow) / self.flex_cycles

    @property
    def flex_schedule(self) -> list[Dataflow]:
        return [l.best[0] for l in self.layers]


def simulate_network(
    model: str, layers: list[ConvLayer | GemmShape], array: int
) -> NetworkResult:
    """Run every layer under all three dataflows on an ``array x array`` PE grid."""
    out = NetworkResult(model=model, array=array)
    for layer in layers:
        gemm = layer.gemm() if isinstance(layer, ConvLayer) else layer
        cycles = {df: systolic_cycles(gemm, df, array, array) for df in ALL_DATAFLOWS}
        out.layers.append(LayerResult(name=gemm.name, gemm=gemm, cycles=cycles))
    return out


# ---------------------------------------------------------------------------
# Event-exact reference simulator (small arrays) — validates the closed form.
# ---------------------------------------------------------------------------


def simulate_exact_os(M: int, K: int, N: int, rows: int, cols: int) -> int:
    """Cycle-exact OS systolic simulation by wavefront counting.

    For one OS fold of an ``r x c`` output tile: PE (i, j) receives its k-th
    operand pair at cycle ``k + i + j`` (skewed injection), so the last MAC of
    the fold lands at ``K - 1 + (r - 1) + (c - 1)``; shifting the r rows of
    results out takes ``r`` more cycles.  Total per fold = K + r + c - 2 + r,
    which is exactly the closed form in ``systolic_cycles`` — this function
    exists so tests can prove that equality by brute force on small shapes.
    """
    total = 0
    for m0 in range(0, M, rows):
        for n0 in range(0, N, cols):
            r = min(rows, M - m0)
            c = min(cols, N - n0)
            # wavefront: last MAC at K-1 + (r-1) + (c-1); +rows output drain.
            last_mac = (K - 1) + (r - 1) + (c - 1)
            total += last_mac + 1 + rows
    return total


def utilization(result: NetworkResult, dataflow: Dataflow | None = None) -> float:
    """MAC-array utilization: useful MACs / (cycles * array^2)."""
    macs = sum(l.gemm.macs for l in result.layers)
    cyc = result.flex_cycles if dataflow is None else result.static_cycles(dataflow)
    return macs / (cyc * result.array * result.array)


def layer_cycle_table(result: NetworkResult) -> np.ndarray:
    """(num_layers, 3) matrix of cycles in IS/OS/WS order — Fig. 1 data."""
    return np.array(
        [[l.cycles[df] for df in ALL_DATAFLOWS] for l in result.layers], dtype=np.int64
    )
