"""Core of the Flex-TPU reproduction: dataflows, cycle model, CMU, Table II model."""

from .area_power import PAPER_TABLE2, Overheads, SynthesisResult, overheads, synthesize
from .cmu import DataflowPlan, LayerPlan, plan_kernels, plan_kernels_tuned, plan_systolic, static_vs_flex_traffic
from .dataflow import (
    ALL_DATAFLOWS,
    ConvLayer,
    Dataflow,
    GemmShape,
    KernelCost,
    arithmetic_intensity,
    best_dataflow,
    best_kernel_dataflow,
    hbm_traffic_bytes,
    mxu_utilization,
    systolic_cycles,
    tune_kernel_dataflow,
)
from .dist_dataflow import best_mesh_dataflow, mesh_gemm_cost, plan_mesh
from .systolic import (
    LayerResult,
    NetworkResult,
    layer_cycle_table,
    simulate_exact_os,
    simulate_network,
    utilization,
)
from .workloads import PAPER_TABLE1, WORKLOADS

__all__ = [
    "ALL_DATAFLOWS",
    "ConvLayer",
    "Dataflow",
    "DataflowPlan",
    "GemmShape",
    "KernelCost",
    "LayerPlan",
    "LayerResult",
    "NetworkResult",
    "Overheads",
    "PAPER_TABLE1",
    "PAPER_TABLE2",
    "SynthesisResult",
    "WORKLOADS",
    "arithmetic_intensity",
    "best_dataflow",
    "best_kernel_dataflow",
    "best_mesh_dataflow",
    "hbm_traffic_bytes",
    "layer_cycle_table",
    "mesh_gemm_cost",
    "mxu_utilization",
    "overheads",
    "plan_kernels",
    "plan_kernels_tuned",
    "plan_mesh",
    "plan_systolic",
    "simulate_exact_os",
    "simulate_network",
    "static_vs_flex_traffic",
    "synthesize",
    "systolic_cycles",
    "tune_kernel_dataflow",
    "utilization",
]
