"""Component-level 45nm area/power/delay model — reproduces paper Table II.

The paper synthesises conventional (OS) and Flex TPUs with Synopsys DC on the
Nangate 45nm open cell library at S = 8/16/32.  Synopsys is not available
here, so we model the design bottom-up from component footprints (INT8
multiplier, 24-bit accumulator, DFFs, 2:1 MUXes) calibrated against the
paper's three synthesis points, with power-law periphery scaling (FIFOs,
SRAM ports, controller).  The *model form* mirrors the paper's architecture:

  area(S)  = S^2 * A_pe          + A_periph(S)
  flex(S)  = S^2 * (A_pe + A_fx) + A_periph(S) + A_regfile(S) + A_cmu

Calibration targets (paper Table II) are kept in PAPER_TABLE2 so the
benchmark prints model-vs-paper side by side and tests bound the error.
"""

from __future__ import annotations

from dataclasses import dataclass

# --- Component footprints (um^2, 45nm Nangate-class; calibrated) -----------
MULT8_AREA = 565.0          # INT8 array multiplier
ADDER24_AREA = 130.0        # 24-bit accumulator adder
DFF_AREA = 5.6              # per flip-flop bit
MUX2_AREA_PER_BIT = 2.2     # 2:1 mux per bit
PE_REG_BITS = 40            # in(8) + w(8) + psum(24) registers per PE
FLEX_REG_BITS = 8           # the paper's "+1 register"
FLEX_MUX_BITS = 16          # the paper's "+2 MUXes" (8-bit each)
FLEX_WIRING = 28.0          # routing/control overhead per PE

A_PE = MULT8_AREA + ADDER24_AREA + PE_REG_BITS * DFF_AREA            # ~919 um^2
A_FLEX_PE = FLEX_REG_BITS * DFF_AREA + FLEX_MUX_BITS * MUX2_AREA_PER_BIT + FLEX_WIRING

# Periphery (weight/input/output memories, FIFOs, main controller):
# power-law fit through the paper's three synthesis points.
PERIPH_AREA_COEF = 115.7
PERIPH_AREA_EXP = 2.23

# Flex-only periphery: Weight/IFMap register file (scales with S) + CMU +
# dataflow generator (fixed).
REGFILE_AREA_PER_ROW = 400.0
CMU_AREA = 280.0

# --- Power (uW) -------------------------------------------------------------
# Per-PE dynamic power grows with array size (clock-tree depth / wire load).
PE_POWER_BASE = -4.5
PE_POWER_LOG = 10.5          # P_pe(S) = BASE + LOG * log2(S)
FLEX_PE_POWER_BASE = 2.9
FLEX_PE_POWER_SLOPE = 0.09   # P_fx(S) = 2.9 + 0.09 * S
PERIPH_POWER_COEF = 269.0
PERIPH_POWER_EXP = 0.9

# --- Critical path (ns) -----------------------------------------------------
DELAY_BASE = 4.555
DELAY_LOG = 0.415            # d(S) = 4.555 + 0.415 * log2(S)
FLEX_MUX_DELAY = 0.07        # one 2:1 mux on the operand path


@dataclass(frozen=True)
class SynthesisResult:
    array: int
    flex: bool
    area_mm2: float
    power_mw: float
    delay_ns: float

    @property
    def systolic_area_fraction(self) -> float:
        import math

        pe = self.array**2 * (A_PE + (A_FLEX_PE if self.flex else 0.0)) * 1e-6
        return pe / self.area_mm2


def _log2(x: float) -> float:
    import math

    return math.log2(x)


def synthesize(array: int, flex: bool = False) -> SynthesisResult:
    """Analytical 'synthesis' of a TPU / Flex-TPU at a given array size."""
    s2 = array * array
    area_um2 = s2 * A_PE + PERIPH_AREA_COEF * array**PERIPH_AREA_EXP
    p_pe = PE_POWER_BASE + PE_POWER_LOG * _log2(array)
    power_uw = s2 * p_pe + PERIPH_POWER_COEF * array**PERIPH_POWER_EXP
    delay = DELAY_BASE + DELAY_LOG * _log2(array)
    if flex:
        area_um2 += s2 * A_FLEX_PE + REGFILE_AREA_PER_ROW * array + CMU_AREA
        power_uw += s2 * (FLEX_PE_POWER_BASE + FLEX_PE_POWER_SLOPE * array)
        delay += FLEX_MUX_DELAY
    return SynthesisResult(
        array=array,
        flex=flex,
        area_mm2=area_um2 * 1e-6,
        power_mw=power_uw * 1e-3,
        delay_ns=delay,
    )


@dataclass(frozen=True)
class Overheads:
    array: int
    area_pct: float
    power_pct: float
    delay_pct: float


def overheads(array: int) -> Overheads:
    base, fx = synthesize(array, flex=False), synthesize(array, flex=True)
    pct = lambda a, b: 100.0 * (b - a) / a
    return Overheads(
        array=array,
        area_pct=pct(base.area_mm2, fx.area_mm2),
        power_pct=pct(base.power_mw, fx.power_mw),
        delay_pct=pct(base.delay_ns, fx.delay_ns),
    )


# Paper Table II reference values for validation.
PAPER_TABLE2 = {
    8: {
        "tpu": {"area": 0.070, "power": 3.491, "delay": 5.80},
        "flex": {"area": 0.080, "power": 3.756, "delay": 5.92},
        "overhead": {"area": 13.607, "power": 7.591, "delay": 2.07},
    },
    16: {
        "tpu": {"area": 0.284, "power": 13.850, "delay": 6.44},
        "flex": {"area": 0.318, "power": 15.241, "delay": 6.48},
        "overhead": {"area": 12.180, "power": 10.045, "delay": 0.62},
    },
    32: {
        "tpu": {"area": 1.192, "power": 55.621, "delay": 6.63},
        "flex": {"area": 1.311, "power": 61.545, "delay": 6.69},
        "overhead": {"area": 10.052, "power": 10.650, "delay": 0.90},
    },
}
