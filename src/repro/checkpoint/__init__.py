"""Sharded checkpointing with manifest, async writes, and elastic restore.

Layout:  <dir>/step_<N>/
           manifest.json   {step, leaf paths, shapes, dtypes, config_hash, rng}
           <leaf>.npy      one file per pytree leaf (the per-shard unit)

Restore re-shards automatically: arrays are loaded on host then device_put
with the *current* mesh's shardings, so a checkpoint written on a 16x16 mesh
restores onto 8x16 (elastic downsize) or 2x16x16 (pod scale-out) unchanged —
this is the elastic-scaling mechanism exercised in tests/test_checkpoint.py.
"""

from .store import latest_step, load_checkpoint, save_checkpoint

__all__ = ["latest_step", "load_checkpoint", "save_checkpoint"]
