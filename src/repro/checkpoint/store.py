"""Checkpoint store: npy-per-leaf + JSON manifest, atomic rename commits."""

from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

Params = Any


def _leaf_paths(tree: Params) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "_".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", "x"))))
            for k in path
        )
        out.append((re.sub(r"[^A-Za-z0-9_.-]", "_", name) or "root", leaf))
    return out


def config_hash(obj: Any) -> str:
    return hashlib.sha1(repr(obj).encode()).hexdigest()[:12]


def save_checkpoint(
    directory: str,
    step: int,
    tree: Params,
    *,
    extra: dict | None = None,
    async_write: bool = False,
) -> str:
    """Write <dir>/step_<N>; commit via atomic rename from a .tmp dir."""

    # Pull to host before handing to a writer thread (donated buffers safe).
    host = jax.tree.map(lambda a: np.asarray(a), tree)

    def _write():
        tmp = os.path.join(directory, f".tmp_step_{step}")
        final = os.path.join(directory, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        leaves = _leaf_paths(host)
        manifest = {"step": step, "leaves": [], "extra": extra or {}}
        for name, leaf in leaves:
            arr = np.asarray(leaf)
            np.save(os.path.join(tmp, f"{name}.npy"), arr)
            manifest["leaves"].append(
                {"name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_write:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return os.path.join(directory, f"step_{step}")
    _write()
    return os.path.join(directory, f"step_{step}")


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_", 1)[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and os.path.exists(os.path.join(directory, d, "manifest.json"))
    ]
    return max(steps) if steps else None


def load_checkpoint(
    directory: str,
    step: int,
    like: Params,
    *,
    shardings: Params | None = None,
) -> tuple[Params, dict]:
    """Load into the structure of ``like``; re-shard with ``shardings`` if given."""
    d = os.path.join(directory, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    names = [n for n, _ in _leaf_paths(like)]
    leaves = []
    for name in names:
        leaves.append(np.load(os.path.join(d, f"{name}.npy")))
    treedef = jax.tree_util.tree_structure(like)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest.get("extra", {})
