"""Model stack: configs, layers, SSM blocks, transformer assembly, registry."""

from repro.models.config import ModelConfig
from repro.models.registry import ARCHS, build_model, get_config
from repro.models.transformer import Model

__all__ = ["ARCHS", "Model", "ModelConfig", "build_model", "get_config"]
