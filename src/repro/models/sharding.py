"""Logical-axis sharding: MaxText-style rules mapping logical names to mesh axes.

The model code annotates activations/params with *logical* axes; the launcher
installs a rules table + mesh via ``use_rules``.  Outside any rules context
(unit tests, single-CPU smoke runs) every constraint is a no-op, so the same
model code serves 1-device tests and 512-device dry-runs.

The rules table is deliberately a plain dict — it is the main §Perf hillclimb
lever (e.g. flipping 'act_seq' between None and 'model' toggles sequence
parallelism; flipping 'fsdp' between ('data',) and ('pod','data') widens
ZeRO-3 sharding).
"""

from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (str | tuple | None)
DEFAULT_RULES: dict[str, Any] = {
    # activations
    "act_batch": ("pod", "data"),
    "act_seq": "model",          # sequence-parallel residual stream
    "act_seq_np": None,          # sequence dim where SP is off (inside attention)
    "act_heads": "model",
    "act_embed": None,
    "act_vocab": "model",
    "act_expert": "model",
    # params
    "fsdp": ("pod", "data"),     # ZeRO-3 axis for the non-TP weight dim
    "tensor": "model",           # TP axis
    "expert": "model",           # EP axis
    "replicated": None,
}

_RULES: contextvars.ContextVar[dict[str, Any] | None] = contextvars.ContextVar(
    "sharding_rules", default=None
)
_MESH: contextvars.ContextVar[Mesh | None] = contextvars.ContextVar(
    "sharding_mesh", default=None
)


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: dict[str, Any] | None = None):
    """Install a mesh + logical rules for the enclosed trace."""
    rules = dict(DEFAULT_RULES if rules is None else rules)
    # Drop mesh axes that don't exist (e.g. 'pod' on the single-pod mesh).
    def _filter(v):
        if v is None:
            return None
        axes = (v,) if isinstance(v, str) else tuple(v)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        return axes if axes else None

    rules = {k: _filter(v) for k, v in rules.items()}
    t1, t2 = _RULES.set(rules), _MESH.set(mesh)
    try:
        yield rules
    finally:
        _RULES.reset(t1)
        _MESH.reset(t2)


def active_mesh() -> Mesh | None:
    return _MESH.get()


def spec_for(*logical: str | None) -> P:
    rules = _RULES.get()
    if rules is None:
        return P()
    return P(*(rules.get(ax) if ax else None for ax in logical))


# (logical axis, array shape) pairs already warned about — involuntary
# replication is logged once per site, not once per traced call
_REPLICATION_WARNED: set[tuple] = set()


def constrain(x: jax.Array, *logical: str | None) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op outside a rules context.

    Axes whose dim doesn't divide the mapped mesh extent are dropped
    (replicated) — e.g. 8 kv-heads on a 16-way tensor axis.  Uneven GSPMD
    shardings technically work but trigger involuntary full rematerialisation
    through reshapes, which is how 40GB/device attention temps happen.  Each
    drop is logged once per (logical axis, shape) so involuntary replication
    is visible in logs instead of silently costing memory.
    """
    mesh = _MESH.get()
    if mesh is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    spec = list(spec_for(*logical))
    import math

    for i, axes in enumerate(spec):
        if axes is None:
            continue
        tup = (axes,) if isinstance(axes, str) else tuple(axes)
        extent = math.prod(mesh.shape[a] for a in tup)
        if x.shape[i] % extent:
            key = (logical[i], tuple(x.shape))
            if key not in _REPLICATION_WARNED:
                _REPLICATION_WARNED.add(key)
                import logging

                logging.getLogger(__name__).warning(
                    "sharding: logical axis %r of a %s array does not divide "
                    "the %s mesh extent %d — replicating that dim "
                    "(involuntary; costs memory on every device)",
                    logical[i], tuple(x.shape), tup, extent,
                )
            spec[i] = None
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def extent(logical: str) -> int:
    """Mesh extent a logical axis maps to (1 outside a rules context)."""
    mesh = _MESH.get()
    rules = _RULES.get()
    if mesh is None or rules is None:
        return 1
    axes = rules.get(logical) or ()
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    import math

    return math.prod(mesh.shape[a] for a in axes) if axes else 1


def dp_size() -> int:
    """Data-parallel extent of the active mesh (1 outside a rules context).

    This is a documented re-export: the canonical extent computation lives
    in ``launch.mesh.dp_size`` (a pure function of a mesh); this wrapper
    only resolves the active rules table's ``act_batch`` mapping — which
    under ``DEFAULT_RULES`` is exactly ``launch.mesh.dp_axes`` — and
    delegates.  A test pins the two agree on the production meshes."""
    mesh = _MESH.get()
    rules = _RULES.get()
    if mesh is None or rules is None:
        return 1
    axes = rules.get("act_batch") or ()
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    if not axes:
        return 1
    from repro.launch.mesh import dp_size as _canonical_dp_size

    return _canonical_dp_size(mesh, axes)


def tensor_axis() -> str | None:
    """The single mesh axis the ``tensor`` rule maps to, or None when no
    rules context is active or the rule maps to zero/multiple axes — the
    gate for the mesh-native flex kernel path, whose collectives run over
    exactly one named axis."""
    mesh = _MESH.get()
    rules = _RULES.get()
    if mesh is None or rules is None:
        return None
    axes = rules.get("tensor") or ()
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    return axes[0] if len(axes) == 1 else None


# ---------------------------------------------------------------------------
# Parameter sharding: path-pattern -> logical axes per dim.
# ---------------------------------------------------------------------------

PARAM_RULES: list[tuple[str, tuple[str | None, ...]]] = [
    (r"embed$", ("tensor", "fsdp")),                 # (V, D) vocab-sharded
    (r"lm_head$", ("fsdp", "tensor")),               # (D, V)
    (r"(wq|wk|wv)$", ("fsdp", "tensor")),            # (D, H*hd)
    (r"wo$", ("tensor", "fsdp")),                    # (H*hd, D)
    (r"(bq|bk|bv)$", ("tensor",)),
    (r"(w1|w3)$", ("fsdp", "tensor")),               # (D, F)
    (r"w2$", ("tensor", "fsdp")),                    # (F, D)
    (r"router$", ("fsdp", None)),                    # (D, E)
    (r"(we1|we3)$", ("expert", "fsdp", None)),       # (E, D, Fe)
    (r"we2$", ("expert", None, "fsdp")),             # (E, Fe, D)
    (r"(in_proj)$", ("fsdp", "tensor")),             # ssm in projection
    (r"(out_proj)$", ("tensor", "fsdp")),
    (r"(r_proj|k_proj|v_proj|g_proj)$", ("fsdp", "tensor")),
    (r"(dw1)$", ("fsdp", None)),                     # decay lora down (D, r)
    (r"(dw2)$", (None, "tensor")),                   # decay lora up (r, D)
    (r"(ck|cr)$", ("fsdp", "tensor")),               # rwkv channel-mix (D, F')
    (r"cv$", ("tensor", "fsdp")),                    # (F', D)
    (r"vision_proj$", ("fsdp", "tensor")),
]


def _match_spec(path: str, ndim: int, stacked: bool) -> P:
    for pat, logical in PARAM_RULES:
        if re.search(pat, path):
            want = len(logical) + (1 if stacked else 0)
            if want == ndim:
                axes = ((None,) if stacked else ()) + tuple(logical)
                return spec_for(*axes)
    return P()  # 1-D scales/biases and anything unmatched: replicated


def param_shardings(params: Any) -> Any:
    """NamedSharding tree matching ``params`` (call inside use_rules)."""
    mesh = _MESH.get()
    assert mesh is not None, "param_shardings requires use_rules(mesh)"

    def leaf(path, x) -> NamedSharding:
        keys = [getattr(k, "key", getattr(k, "idx", "")) for k in path]
        pstr = "/".join(str(k) for k in keys)
        stacked = pstr.startswith("layers/") or "/layers/" in pstr
        return NamedSharding(mesh, _match_spec(pstr, x.ndim, stacked))

    return jax.tree_util.tree_map_with_path(leaf, params)
