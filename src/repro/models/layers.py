"""Transformer layer primitives shared by all ten architectures.

Everything is functional: ``params`` are nested dicts of arrays, layers are
pure functions of (params, x).  Activation sharding uses logical axes
(`sharding.constrain`), a no-op outside a mesh context.  Dense projections
route through ``linear`` which dispatches to the fused Pallas flex kernels
(config.use_pallas: bias/activation/residual fused into the kernel flush,
dataflow + block per the active CMU plan) or plain XLA einsum (dry-run
path, where XLA must see a fusible dot for cost_analysis).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.sharding import constrain

Params = dict[str, Any]

_XLA_ACT = {"relu": jax.nn.relu, "gelu": jax.nn.gelu, "silu": jax.nn.silu}


def linear(
    cfg: ModelConfig,
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    activation: str | None = None,
    residual: jax.Array | None = None,
    name: str = "",
) -> jax.Array:
    """``act(x @ w + b) + residual`` for (..., K) @ (K, N).

    With ``cfg.use_pallas`` this is one fused flex-kernel launch: the CMU
    plan (``core.plan_cache.active_plan``) supplies (dataflow, block) for
    ``name`` — including the per-layer backward sub-plans, so under
    ``jax.grad`` the cotangent GEMMs also run as flex kernels under their
    own dataflows.  Unplanned layers fall back to the trace-time roofline
    argmin.  Otherwise plain XLA ops (einsum + separate epilogue), the
    dry-run path.

    When the plan row (or the decode-bucket sub-plan that overrides it)
    carries a quantized ``qdtype`` verdict ("int8"/"fp8"), the dispatch
    quantizes the weight per output channel and the kernel fuses the
    dequant into its flush epilogue; "bf16" and None run full precision,
    and the mesh-native sharded path never quantizes.

    When a rules context is active (``sharding.use_rules``) and the GEMM
    divides the mesh, the Pallas path goes **mesh-native**: the layer runs
    as a shard_map-composed collective schedule around the local flex
    kernels (``kernels.mesh_ops.flex_linear_sharded``), with the mesh-level
    dataflow and local per-shard geometry from the plan's ``mesh``
    sub-plan (or the trace-time analytical argmin).  Layers that don't
    divide the mesh fall back cleanly to the single-device kernel path —
    the same contract as the attention shard_map path.
    """
    w = w.astype(x.dtype)
    if cfg.use_pallas:
        from repro.core.dataflow import GemmShape, best_kernel_dataflow
        from repro.core.plan_cache import active_plan
        from repro.kernels.flex_matmul import DEFAULT_BLOCK
        from repro.kernels.ops import default_interpret, flex_linear

        lead = x.shape[:-1]
        K, N = w.shape
        x2 = x.reshape(-1, K)
        r2 = None if residual is None else residual.reshape(-1, N)
        plan = active_plan()
        lp = plan.get(name) if (plan is not None and name) else None

        from repro.models.sharding import active_mesh, spec_for, tensor_axis

        mesh = active_mesh()
        axis = tensor_axis() if mesh is not None else None
        if axis is not None:
            from repro.core.cmu import mesh_shardable
            from repro.kernels.mesh_ops import flex_linear_sharded
            from repro.launch.mesh import dp_size as mesh_dp_size

            dp_axes = spec_for("act_batch")[0] or ()
            dp_axes = ((dp_axes,) if isinstance(dp_axes, str)
                       else tuple(dp_axes))
            tp = int(mesh.shape[axis])
            dp = mesh_dp_size(mesh, dp_axes)
            gemm = GemmShape(x2.shape[0], K, N, name=name)
            if mesh_shardable(gemm, tp, dp):
                out = flex_linear_sharded(
                    x2, w, None if b is None else b.astype(x.dtype),
                    mesh=mesh, axis=axis, dp_axes=dp_axes,
                    activation=activation, residual=r2,
                    plan=lp.mesh if lp is not None else None,
                    interpret=default_interpret(), out_dtype=x.dtype,
                )
                return out.reshape(*lead, N)

        bwd_dx = bwd_dw = None
        strip = 1
        qdtype = None
        if lp is not None:
            df, blk, strip = lp.dataflow, lp.block or DEFAULT_BLOCK, lp.strip
            qdtype = lp.qdtype
            # decode-bucket dispatch: a skinny (decode-geometry) call whose
            # row count fits a tuned batch-size bucket runs that bucket's
            # plan — the serving scheduler quantizes its live batch to the
            # same buckets, so every decode step hits a pre-tuned geometry
            sub = lp.decode_plan(x2.shape[0]) if lp.decode else None
            if sub is not None:
                df, blk, strip = sub.dataflow, sub.block or DEFAULT_BLOCK, sub.strip
                qdtype = sub.qdtype
            if lp.bwd_dx is not None:
                bwd_dx = (lp.bwd_dx.dataflow, lp.bwd_dx.block, lp.bwd_dx.trans,
                          lp.bwd_dx.strip)
            if lp.bwd_dw is not None:
                bwd_dw = (lp.bwd_dw.dataflow, lp.bwd_dw.block, lp.bwd_dw.trans,
                          lp.bwd_dw.strip)
        else:
            df, _ = best_kernel_dataflow(GemmShape(x2.shape[0], K, N, name=name))
            blk = DEFAULT_BLOCK
        # only a quantized verdict dispatches quantized — None (v1–v8 plan)
        # and "bf16" (quant searched and rejected) both run full precision
        if qdtype not in ("int8", "fp8"):
            qdtype = None
        out = flex_linear(
            x2, w, None if b is None else b.astype(x.dtype),
            activation=activation, residual=r2, dataflow=df, block=blk,
            interpret=default_interpret(), out_dtype=x.dtype,
            bwd_dx=bwd_dx, bwd_dw=bwd_dw, strip=strip, qdtype=qdtype,
        )
        return out.reshape(*lead, N)
    y = jnp.einsum("...d,df->...f", x, w)
    if b is not None:
        y = y + b.astype(y.dtype)
    if activation is not None:
        y = _XLA_ACT[activation](y)
    if residual is not None:
        y = y + residual
    return y


# ---------------------------------------------------------------------------
# initialisation helpers
# ---------------------------------------------------------------------------


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return jax.random.normal(key, shape, dtype) * scale


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * scale + bias).astype(dt)


def norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


def init_norm(cfg: ModelConfig, key) -> Params:
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,)), "bias": jnp.zeros((cfg.d_model,))}
    return {"scale": jnp.zeros((cfg.d_model,))}


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq: int, dim: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    inv = jnp.exp(-math.log(10000.0) * jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    ang = pos * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def init_attention(cfg: ModelConfig, key) -> Params:
    ks = split_keys(key, 4)
    D = cfg.d_model
    p: Params = {
        "wq": _init(ks[0], (D, cfg.q_dim)),
        "wk": _init(ks[1], (D, cfg.kv_dim)),
        "wv": _init(ks[2], (D, cfg.kv_dim)),
        "wo": _init(ks[3], (cfg.q_dim, D)),
    }
    if cfg.qkv_bias:
        p |= {
            "bq": jnp.zeros((cfg.q_dim,)),
            "bk": jnp.zeros((cfg.kv_dim,)),
            "bv": jnp.zeros((cfg.kv_dim,)),
        }
    if cfg.qk_norm:
        p |= {"q_norm": jnp.zeros((cfg.head_dim,)), "k_norm": jnp.zeros((cfg.head_dim,))}
    return p


def _project_qkv(cfg: ModelConfig, p: Params, x: jax.Array, xkv: jax.Array | None = None):
    """Returns q (B,S,H,hd), k/v (B,Skv,Hkv,hd)."""
    B, S, _ = x.shape
    xkv = x if xkv is None else xkv
    Skv = xkv.shape[1]
    q = linear(cfg, x, p["wq"], p.get("bq"), name="attn.wq")
    k = linear(cfg, xkv, p["wk"], p.get("bk"), name="attn.wk")
    v = linear(cfg, xkv, p["wv"], p.get("bv"), name="attn.wv")
    # Attention is context-parallel (seq-sharded q under shard_map), so the
    # flat projections stay SEQ-sharded and heads are never split — this is
    # head-count agnostic (56 or 8 heads on a 16-way axis both just work) and
    # avoids the reshape-misalignment full-remats GSPMD produces otherwise.
    q = constrain(q, "act_batch", "act_seq", None)
    k = constrain(k, "act_batch", "act_seq", None)
    v = constrain(v, "act_batch", "act_seq", None)
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, Skv, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, Skv, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _sdpa(q, k, v, mask, scale):
    """q (B,Sq,H,hd), k/v (B,Sk,Hkv,hd) GQA; mask (B|1, 1, Sq, Sk) bool.

    Score tensors are the attention memory hot-spot; they're sharded over the
    query dim ('act_seq' -> tensor axis) because head counts (8 kv / 7 group)
    rarely divide a 16-way axis while query chunks always do.
    """
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Sq, Hkv, g, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = constrain(scores, "act_batch", None, None, "act_seq", None)
    scores = scores * scale
    scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = constrain(probs, "act_batch", None, None, "act_seq", None)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _sdpa_local(q, k, v, mask, scale):
    """GQA attention on LOCAL (unsharded) arrays — the shard_map inner body."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Sq, Hkv, g, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * scale
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def _attention_core(
    cfg: ModelConfig,
    q: jax.Array,          # (B, Sq_local, H, hd)
    k: jax.Array,          # (B, Skv, Hkv, hd) — full kv
    v: jax.Array,
    *,
    q_offset,              # global position of q[0] (int or traced scalar)
    causal: bool,
    window: int,
    prefix_len: int,
    scale: float,
) -> jax.Array:
    """Online-softmax (flash) local attention — OUTPUT-STATIONARY in the
    paper's vocabulary: the (cq, hd) output tile and its running max/sum stay
    resident while KV tiles stream past; only (cq x ckv) score tiles ever
    materialise.  Runs identically under shard_map (q seq-sharded,
    q_offset = shard index * shard length) and standalone.  Windowed layers
    stream only a (window + cq)-wide KV slice — sub-quadratic for gemma3's
    local layers.  With cfg.attn_unroll the loops are python-unrolled with
    STATIC per-q-chunk KV bounds (exact HLO costs, no masked-tile waste)."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    Hkv = k.shape[2]
    g = H // Hkv
    cq = cfg.attn_chunk if (Sq % cfg.attn_chunk == 0 and Sq > cfg.attn_chunk) else Sq
    ckv = cfg.attn_chunk if Skv % cfg.attn_chunk == 0 and Skv > cfg.attn_chunk else Skv
    nq, nkv = Sq // cq, Skv // ckv
    kv_slice = min(Skv, window + cq) if (window and causal) else Skv

    def kv_tile(carry, q_c, qpos, kv0):
        """One KV tile starting at kv0: update (acc, m_run, l_run) online."""
        acc, m_run, l_run, _ = carry
        k_c = jax.lax.dynamic_slice_in_dim(k, kv0, ckv, axis=1)
        v_c = jax.lax.dynamic_slice_in_dim(v, kv0, ckv, axis=1)
        kpos = kv0 + jnp.arange(ckv)
        qg = q_c.reshape(B, cq, Hkv, g, hd)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k_c.astype(jnp.float32))
        s = s * scale
        m = jnp.ones((cq, ckv), bool)
        if causal:
            m = m & (kpos[None, :] <= qpos[:, None])
            if window:
                m = m & (qpos[:, None] - kpos[None, :] < window)
            if prefix_len:
                m = m | (kpos[None, :] < prefix_len)
        s = jnp.where(m[None, None, None], s, -1e30)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_run = l_run * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, v_c.astype(jnp.float32))
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l_run, 0), None

    def q_chunk(c):
        """Full online pass of one q chunk over its needed KV range."""
        q_c = jax.lax.dynamic_slice_in_dim(q, c * cq, cq, axis=1)
        qpos = q_offset + c * cq + jnp.arange(cq)
        # tie the carry init to q so its manual-axes "varying" status matches
        # the loop body's outputs under shard_map (folded away by XLA)
        zero = (q_c.astype(jnp.float32) * 0.0).sum()
        acc = jnp.zeros((B, Hkv, g, cq, hd), jnp.float32) + zero
        m_run = jnp.full((B, Hkv, g, cq), -1e30, jnp.float32) + zero
        l_run = jnp.zeros((B, Hkv, g, cq), jnp.float32) + zero
        if window and causal:
            start = jnp.clip(qpos[-1] + 1 - kv_slice, 0, Skv - kv_slice)
            # windowed: a fixed-width slice, tiled in one pass
            n_t = max(kv_slice // ckv, 1)
            ct = kv_slice // n_t
            carry = (acc, m_run, l_run, 0)
            for t in range(n_t):
                k0 = start + t * ct
                kc = jax.lax.dynamic_slice_in_dim(k, k0, ct, axis=1)
                vc = jax.lax.dynamic_slice_in_dim(v, k0, ct, axis=1)
                kpos = k0 + jnp.arange(ct)
                qg = q_c.reshape(B, cq, Hkv, g, hd)
                s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), kc.astype(jnp.float32)) * scale
                m = (kpos[None, :] <= qpos[:, None]) & (qpos[:, None] - kpos[None, :] < window)
                if prefix_len:
                    m = m | (kpos[None, :] < prefix_len)
                s = jnp.where(m[None, None, None], s, -1e30)
                m_new = jnp.maximum(carry[1], jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(carry[1] - m_new)
                l_new = carry[2] * corr + jnp.sum(p, axis=-1)
                pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
                acc_new = carry[0] * corr[..., None] + pv
                carry = (acc_new, m_new, l_new, 0)
            acc, m_run, l_run, _ = carry
        elif cfg.attn_unroll:
            # probe path: python-unrolled; static causal bound when the shard
            # offset is static, else conservatively all tiles (costs are then
            # an upper bound — documented in EXPERIMENTS §Roofline)
            carry = (acc, m_run, l_run, 0)
            for t in range(nkv):
                if causal and isinstance(q_offset, int) and t * ckv > q_offset + (c + 1) * cq - 1:
                    break
                carry, _ = kv_tile(carry, q_c, qpos, t * ckv)
            acc, m_run, l_run, _ = carry
        else:
            # differentiable path: scan all KV tiles (masked tiles waste ~2x
            # attention FLOPs for causal runs — the Pallas flash kernel with
            # a bounded grid is the production fix, kernels/flash_attention)
            def body(carry, t):
                carry, _ = kv_tile(carry, q_c, qpos, t * ckv)
                return carry, None

            (acc, m_run, l_run, _), _ = jax.lax.scan(
                body, (acc, m_run, l_run, 0), jnp.arange(nkv)
            )
        o = acc / jnp.maximum(l_run, 1e-30)[..., None]
        # (B,Hkv,g,cq,hd) -> (B,cq,H,hd)
        return jnp.moveaxis(o, 3, 1).reshape(B, cq, H, hd).astype(q.dtype)

    q_chunk_ck = jax.checkpoint(q_chunk, static_argnums=())
    if nq == 1:
        return q_chunk(0)
    if cfg.attn_unroll:
        return jnp.concatenate([q_chunk(c) for c in range(nq)], axis=1)
    _, os = jax.lax.scan(lambda _, c: (None, q_chunk_ck(c)), None, jnp.arange(nq))
    return jnp.moveaxis(os, 0, 1).reshape(B, Sq, H, hd)


def _attn_schedule() -> tuple[str, tuple[int, int]]:
    """The planned flash-attention schedule (sweep, (bq, bk)) from the
    active CMU plan's anchor row, or the default q-stationary 128x128 when
    no plan (or a pre-v7 plan) is active."""
    from repro.core.plan_cache import active_plan

    plan = active_plan()
    ap = plan.attention_plan() if plan is not None else None
    if ap is None or len(ap.block) < 2:
        return "q", (128, 128)
    return ap.sweep, (ap.block[0], ap.block[1])


def _attn_decode_kind(batch: int) -> str:
    """The planned decode-attention kind for a ``batch``-slot dispatch:
    the bucketed sub-plan's pick, else "paged" (turning ``attn_pallas`` on
    without a plan runs the Pallas kernel everywhere)."""
    from repro.core.plan_cache import active_plan

    plan = active_plan()
    ap = plan.attention_plan() if plan is not None else None
    sub = ap.decode_plan(batch) if ap is not None else None
    return sub.sweep if sub is not None else "paged"


def attention_full(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    *,
    window: int = 0,
    prefix_len: int = 0,
    causal: bool = True,
    xkv: jax.Array | None = None,
    use_rope: bool = True,
    positions: jax.Array | None = None,
    residual: jax.Array | None = None,
) -> jax.Array:
    """Full-sequence attention (train / prefill): context-parallel shard_map.

    q is sequence-sharded over the tensor axis; K/V are gathered per shard
    (they're GQA-small).  Inside each shard a chunked flash-style scan bounds
    score memory; windowed layers touch only a (window + chunk) KV slice, so
    gemma3's local layers stay sub-quadratic in the HLO.  Falls back to the
    single-device path when no mesh is active or shapes don't divide.
    """
    from repro.models.sharding import active_mesh, extent, spec_for

    B, S, D = x.shape
    q, k, v = _project_qkv(cfg, p, x, xkv)
    Skv = k.shape[1]
    if positions is None:
        positions = jnp.arange(S)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, jnp.arange(Skv), cfg.rope_theta)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    core = dict(causal=causal, window=window, prefix_len=prefix_len, scale=scale)

    mesh = active_mesh()
    ext = extent("act_seq")
    if mesh is None or ext <= 1 or S % ext:
        if (cfg.attn_pallas and causal and not window and not prefix_len
                and Skv == S):
            # the planned flex flash kernel (self-attention prefill shapes;
            # windowed/prefix/cross layers keep the jnp core)
            from repro.kernels.flash_attention import mha_flash
            from repro.kernels.ops import default_interpret

            sweep, (bq, bk) = _attn_schedule()
            o = mha_flash(q, k, v, causal=True, block_q=bq, block_k=bk,
                          sweep=sweep, interpret=default_interpret())
        else:
            o = _attention_core(cfg, q, k, v, q_offset=0, **core)
    else:
        from jax.sharding import PartitionSpec as P

        seq_axes = spec_for("act_seq")[0]
        dp = spec_for("act_batch")[0] if B % extent("act_batch") == 0 else None
        q_spec = P(dp, seq_axes, None, None)
        kv_spec = P(dp, None, None, None)
        Sloc = S // ext

        def local_fn(q_l, k_l, v_l):
            idx = jax.lax.axis_index(seq_axes)
            return _attention_core(cfg, q_l, k_l, v_l, q_offset=idx * Sloc, **core)

        from repro.launch.mesh import shard_map

        o = shard_map(
            local_fn, mesh=mesh,
            in_specs=(q_spec, kv_spec, kv_spec),
            out_specs=q_spec,
        )(q, k, v)

    o = constrain(o, "act_batch", "act_seq", None, None)
    return linear(cfg, o.reshape(B, S, cfg.q_dim), p["wo"],
                  residual=residual, name="attn.wo")


def _decode_core(q, k, v, kpos, pos, window: int, scale: float, axis: str | None):
    """Flash-style decode attention over a (possibly seq-sharded) cache.

    q (B,1,H,hd); k/v (B,Sloc,Hkv,hd) local shard; kpos global key positions.
    ``pos`` is a scalar (whole batch at one position) or a (B,) vector of
    per-slot positions (the continuous-batching paged path, where every slot
    is at a different depth in its own stream).
    With ``axis`` set (inside shard_map) the softmax is distributed:
    pmax for the max, psum for numerator/denominator — so a 32k..500k cache
    never gets gathered (observed: 40GB/step of cache all-gathers before).
    """
    B, _, H, hd = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, 1, Hkv, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if getattr(pos, "ndim", 0):
        m = kpos[None, :] <= pos[:, None]
        if window:
            m = m & ((pos[:, None] - kpos[None, :]) < window)
        s = jnp.where(m[:, None, None, None, :], s, -1e30)
    else:
        m = kpos <= pos
        if window:
            m = m & ((pos - kpos) < window)
        s = jnp.where(m[None, None, None, None, :], s, -1e30)
    mx = jnp.max(s, axis=-1, keepdims=True)
    if axis is not None:
        mx = jax.lax.pmax(mx, axis)
    pr = jnp.exp(s - mx)
    num = jnp.einsum("bhgqk,bkhd->bqhgd", pr, v.astype(jnp.float32))
    den = jnp.sum(pr, axis=-1)  # (B,Hkv,g,1)
    if axis is not None:
        num = jax.lax.psum(num, axis)
        den = jax.lax.psum(den, axis)
    o = num / jnp.maximum(den, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return o.reshape(B, 1, H, hd).astype(q.dtype)


def attention_decode(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    cache: dict[str, jax.Array],
    pos: jax.Array,
    *,
    window: int = 0,
    use_rope: bool = True,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """One-token decode against a KV cache. x: (B, 1, D); cache k/v (B,Smax,Hkv,hd)."""
    from repro.models.sharding import active_mesh, extent, spec_for

    B, _, D = x.shape
    q, k_new, v_new = _project_qkv(cfg, p, x)
    if use_rope:
        q = rope(q, pos[None] if pos.ndim == 0 else pos, cfg.rope_theta)
        k_new = rope(k_new, pos[None] if pos.ndim == 0 else pos, cfg.rope_theta)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)
    Smax = k.shape[1]
    scale = 1.0 / math.sqrt(cfg.head_dim)

    mesh = active_mesh()
    ext = extent("act_seq")
    Hkv = cfg.num_kv_heads
    if mesh is None or ext <= 1 or Smax % ext or Hkv % ext == 0:
        # single-device, or the cache is head-sharded (divisible kv heads)
        o = _decode_core(q, k, v, jnp.arange(Smax), pos, window, scale, None)
    else:
        from jax.sharding import PartitionSpec as P

        seq_ax = spec_for("act_seq")[0]
        dp = spec_for("act_batch")[0] if B % extent("act_batch") == 0 else None
        Sloc = Smax // ext

        def local_fn(q_l, k_l, v_l, pos_l):
            idx = jax.lax.axis_index(seq_ax)
            kpos = idx * Sloc + jnp.arange(Sloc)
            return _decode_core(q_l, k_l, v_l, kpos, pos_l, window, scale, seq_ax)

        from repro.launch.mesh import shard_map

        o = shard_map(
            local_fn, mesh=mesh,
            in_specs=(P(dp, None, None, None), P(dp, seq_ax, None, None),
                      P(dp, seq_ax, None, None), P()),
            out_specs=P(dp, None, None, None),
        )(q, k, v, pos)

    out = linear(cfg, o.reshape(B, 1, cfg.q_dim), p["wo"], name="attn.wo")
    return out, {"k": k, "v": v}


def init_kv_cache(cfg: ModelConfig, batch: int, seq: int, layers: int | None = None):
    L = layers if layers is not None else cfg.num_layers
    shape = (L, batch, seq, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, jnp.bfloat16), "v": jnp.zeros(shape, jnp.bfloat16)}


def attention_decode_paged(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    pk: jax.Array,
    pv: jax.Array,
    table: jax.Array,
    positions: jax.Array,
    *,
    window: int = 0,
    use_rope: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against one layer's paged KV block pool.

    x (B,1,D); pk/pv (num_blocks, bs, Hkv, hd) — this layer's block pools;
    table (B, nb) int32 per-slot block tables; positions (B,) per-slot write
    positions (= tokens already cached for that slot).  The new K/V lands at
    ``(table[pos // bs], pos % bs)`` per slot, then attention runs over the
    gathered dense view of each slot's table with the per-slot causal mask
    of ``_decode_core``.  Pad slots of a bucketed batch point their whole
    table at the reserved scratch block, so their writes never touch a live
    request's blocks and their garbage reads are masked to exact zeros.
    """
    B, _, D = x.shape
    q, k_new, v_new = _project_qkv(cfg, p, x)
    if use_rope:
        q = rope(q, positions[:, None], cfg.rope_theta)
        k_new = rope(k_new, positions[:, None], cfg.rope_theta)
    bs = pk.shape[1]
    Hkv, hd = pk.shape[2], pk.shape[3]
    blk = jnp.take_along_axis(table, (positions // bs)[:, None], axis=1)[:, 0]
    off = positions % bs
    pk = pk.at[blk, off].set(k_new[:, 0].astype(pk.dtype))
    pv = pv.at[blk, off].set(v_new[:, 0].astype(pv.dtype))
    scale = 1.0 / math.sqrt(cfg.head_dim)
    if cfg.attn_pallas and _attn_decode_kind(B) == "paged":
        # in-place Pallas kernel: K/V blocks stream straight out of the
        # pools through the scalar-prefetched table — no dense gather copy
        from repro.kernels.flash_attention import paged_attention
        from repro.kernels.ops import default_interpret

        o = paged_attention(q[:, 0], pk, pv, table, positions, scale=scale,
                            window=window,
                            interpret=default_interpret())[:, None]
    else:
        # dense per-slot view: gathered entry j is the slot's logical
        # position j
        k = pk[table].reshape(B, -1, Hkv, hd)
        v = pv[table].reshape(B, -1, Hkv, hd)
        o = _decode_core(q, k, v, jnp.arange(k.shape[1]), positions, window,
                         scale, None)
    out = linear(cfg, o.reshape(B, 1, cfg.q_dim), p["wo"], name="attn.wo")
    return out, pk, pv


# ---------------------------------------------------------------------------
# MLP (gated + plain)
# ---------------------------------------------------------------------------


def init_mlp(cfg: ModelConfig, key, d_ff: int | None = None) -> Params:
    ks = split_keys(key, 3)
    D, F = cfg.d_model, d_ff or cfg.d_ff
    p = {"w1": _init(ks[0], (D, F)), "w2": _init(ks[1], (F, D))}
    if cfg.activation in ("silu", "gelu"):
        p["w3"] = _init(ks[2], (D, F))
    return p


def mlp(
    cfg: ModelConfig, p: Params, x: jax.Array, residual: jax.Array | None = None
) -> jax.Array:
    """Sequence-parallel FFN: the hidden stays SEQ-sharded (weights are
    gathered instead — the IS mesh dataflow).  Sharding the hidden on the
    feature dim would force a per-layer seq all-gather of x, which §Perf C3
    measured at ~70% of qwen3-train's entire collective term.

    The activation fuses into the w1 kernel and ``residual`` into the w2
    kernel on the pallas path, so the hidden/output never re-stream through
    HBM for the epilogue."""
    act = "silu" if cfg.activation == "silu" else "gelu"
    if "w3" in p:
        h = linear(cfg, x, p["w1"], activation=act, name="mlp.w1")
        h = h * linear(cfg, x, p["w3"], name="mlp.w3")
    else:
        h = linear(cfg, x, p["w1"], activation=act, name="mlp.w1")
    h = constrain(h, "act_batch", "act_seq", None)
    return linear(cfg, h, p["w2"], residual=residual, name="mlp.w2")


# ---------------------------------------------------------------------------
# Mixture of Experts (capacity-based, EP-sharded, no one-hot matmul dispatch)
# ---------------------------------------------------------------------------


def init_moe(cfg: ModelConfig, key) -> Params:
    ks = split_keys(key, 4)
    D, E, Fe = cfg.d_model, cfg.num_experts, cfg.expert_d_ff or cfg.d_ff
    p = {
        "router": _init(ks[0], (D, E), scale=0.02),
        "we1": _init(ks[1], (E, D, Fe)),
        "we2": _init(ks[2], (E, Fe, D)),
        "we3": _init(ks[3], (E, D, Fe)),
    }
    return p


def moe(cfg: ModelConfig, p: Params, x: jax.Array) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Top-k capacity-based MoE with *block-local* dispatch (GShard/Switch).

    Tokens are grouped into NB blocks aligned with the data-parallel mesh
    extent; each block scatters into its own (E, cap_local) slots, so the
    scatter/gather have a leading batch dim that GSPMD shards cleanly (no
    replication), and the block->expert resharding lowers to an all-to-all —
    the production EP pattern.  Dispatch avoids one-hot einsums so HLO FLOPs
    stay proportional to *active* parameters (DESIGN.md §6).
    """
    from repro.models.sharding import dp_size

    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    NB = dp_size()
    if T % NB or NB < 1:
        NB = 1
    Tl = T // NB
    xf = x.reshape(NB, Tl, D)
    xf = constrain(xf, "act_batch", None, None)

    # router einsum in model dtype (an f32 copy of xf is 3.8GB/device on the
    # 480B config); only the small (T, E) logits are upcast for the softmax
    logits = jnp.einsum("btd,de->bte", xf, p["router"].astype(xf.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)  # (NB, Tl, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # per-block position of each (token, k) assignment within its expert's
    # capacity.  Small-T floor keeps decode/smoke paths drop-free; training
    # shapes (Tl >> 256) keep standard capacity-factor behaviour.
    cap = max(int(cfg.capacity_factor * Tl * K / E), 1, min(Tl, 256))
    flat_e = expert_idx.reshape(NB, Tl * K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (NB, TlK, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot               # exclusive
    flat_pos = jnp.take_along_axis(pos_in_e, flat_e[..., None], axis=2)[..., 0]
    keep = flat_pos < cap
    safe_pos = jnp.where(keep, flat_pos, cap - 1)

    # dispatch: (NB, E, cap, D) — vmapped scatter over the block dim
    xk = jnp.repeat(xf[:, :, None, :], K, axis=2).reshape(NB, Tl * K, D)
    xk = jnp.where(keep[..., None], xk, 0)
    xk = constrain(xk, "act_batch", None, None)

    def scatter_block(xk_b, e_b, pos_b):
        return jnp.zeros((E, cap, D), xf.dtype).at[e_b, pos_b].add(xk_b)

    disp = jax.vmap(scatter_block)(xk, flat_e, safe_pos)
    disp = constrain(disp, "act_batch", "act_expert", None, None)  # all-to-all

    # expert FFN (einsum over expert-sharded params; NB is a batch dim)
    h1 = jnp.einsum("becd,edf->becf", disp, p["we1"].astype(disp.dtype))
    h3 = jnp.einsum("becd,edf->becf", disp, p["we3"].astype(disp.dtype))
    h = jax.nn.silu(h1) * h3
    h = constrain(h, "act_batch", "act_expert", None, None)
    eo = jnp.einsum("becf,efd->becd", h, p["we2"].astype(disp.dtype))
    eo = constrain(eo, "act_batch", "act_expert", None, None)

    # combine: vmapped gather back to block-local tokens
    def gather_block(eo_b, e_b, pos_b):
        return eo_b[e_b, pos_b]

    gathered = jax.vmap(gather_block)(eo, flat_e, safe_pos)  # (NB, TlK, D)
    gathered = constrain(gathered, "act_batch", None, None)
    gathered = jnp.where(keep[..., None], gathered, 0)
    gates = gate_vals.reshape(NB, Tl * K).astype(gathered.dtype)
    out = jnp.sum((gathered * gates[..., None]).reshape(NB, Tl, K, D), axis=2)

    # aux losses: load balance (Switch) + router z-loss
    me = jnp.mean(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32).sum(2), axis=(0, 1))
    ce = jnp.mean(probs, axis=(0, 1))
    lb = E * jnp.sum(me * ce) / K
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return out.reshape(B, S, D), {"load_balance": lb, "router_z": z}
