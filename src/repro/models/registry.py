"""--arch <id> registry: full configs + reduced smoke configs per family."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig
from repro.models.transformer import Model

ARCHS = (
    "whisper_base",
    "zamba2_7b",
    "qwen15_4b",
    "minicpm_2b",
    "qwen3_4b",
    "gemma3_12b",
    "paligemma_3b",
    "rwkv6_7b",
    "arctic_480b",
    "qwen3_moe_235b",
)

_ALIASES = {
    "whisper-base": "whisper_base",
    "zamba2-7b": "zamba2_7b",
    "qwen1.5-4b": "qwen15_4b",
    "minicpm-2b": "minicpm_2b",
    "qwen3-4b": "qwen3_4b",
    "gemma3-12b": "gemma3_12b",
    "paligemma-3b": "paligemma_3b",
    "rwkv6-7b": "rwkv6_7b",
    "arctic-480b": "arctic_480b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
}


def canonical(arch: str) -> str:
    return _ALIASES.get(arch, arch.replace("-", "_").replace(".", ""))


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.smoke_config() if smoke else mod.config()


def build_model(arch: str, smoke: bool = False, remat: str = "none") -> Model:
    return Model(get_config(arch, smoke=smoke), remat=remat)
