"""State-space / linear-attention blocks: Mamba2 (zamba2) and RWKV-6 (Finch).

Both are instances of a diagonal-decay linear attention

    S_t = diag(w_t) S_{t-1} + k_t v_t^T ,   o_t = r_t^T S'_t

and share one chunked implementation, ``chunked_diag_linear_attn``:
a `lax.scan` over sequence chunks with exact intra-chunk einsums.  Decay
factors are kept in log space; chunk size and a log-decay clamp bound every
exponent so all `exp` calls stay in f32 range (see the in-function note).

For decode the recurrence is applied directly (O(1) per token) — this is why
the SSM/hybrid architectures run the ``long_500k`` cell that full-attention
models skip.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import _init, rmsnorm, split_keys
from repro.models.sharding import constrain

Params = dict[str, Any]

LOG_DECAY_MIN = -3.0   # per-step decay floor exp(-3) ~ 0.05
LA_CHUNK = 16          # intra-chunk exponent bound: |LOG_DECAY_MIN| * 16 = 48 < 88


def rwkv_groupnorm_eps(cfg: ModelConfig) -> float:
    """RWKV group-norm eps, derived from the head size.

    Upstream RWKV uses ``eps = 1e-5 * head_size_divisor**2`` with
    ``head_size_divisor = sqrt(head_size)`` (divisor 8 at the stock head
    size 64 -> 64e-5), i.e. eps scales linearly with ``rwkv_head_size``.
    """
    return 1e-5 * cfg.rwkv_head_size


def _pad_chunks(a: jax.Array, pad: int) -> jax.Array:
    """Zero-pad the time axis of a (B, T, ...) operand to a chunk multiple.

    Zero rows are exact no-ops for the scan: r = k = v = 0 keeps every pad
    output zero (and prefill slices outputs to ``[:T]`` anyway), and
    ``log_w = 0`` makes the pad steps decay the carried state by
    ``exp(0) = 1`` with a zero k v^T update — so the final state is *bitwise*
    invariant to ``T % chunk``.  (A historical ``where(lw == 0, -1e-6, lw)``
    guard here was doubly dead: real decay rows are already clipped to
    <= -1e-6, and it ran before the pad so pad rows kept log_w = 0 — which
    is exactly the value that makes them safe.)
    """
    return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))


def chunked_diag_linear_attn(
    r: jax.Array,       # (B, T, H, N)
    k: jax.Array,       # (B, T, H, N)
    v: jax.Array,       # (B, T, H, M)
    log_w: jax.Array,   # (B, T, H, N), in [LOG_DECAY_MIN, 0)
    diag_scale: jax.Array | None = None,  # (H, N): RWKV's u bonus; None -> ones
    chunk: int = LA_CHUNK,
    state0: jax.Array | None = None,      # (B, H, N, M)
    post_update: bool = False,            # Mamba2 convention: o_t reads S_t
) -> tuple[jax.Array, jax.Array]:
    """Returns (o (B,T,H,M), final_state (B,H,N,M)).

    RWKV convention (post_update=False): o_t reads the *pre*-update state plus
    a u-bonus diagonal -> contribution of j<i decays by exp(cum_{i-1}-cum_j),
    diagonal is r_i.(u*k_i) v_i.
    Mamba2 convention (post_update=True): o_t reads the *post*-update state ->
    j<i decays by exp(cum_i-cum_j), diagonal undecayed r_i.k_i v_i (this falls
    out of the inclusive-cumsum factoring with the diagonal inside the mask).

    Numerics: with cum = inclusive cumsum(log_w) within a chunk,
      r_fac = r * exp(cum or cum_prev)   (exponent <= 0)
      k_fac = k * exp(-cum)              (exponent <= |LOG_DECAY_MIN|*chunk)
    so every exp() argument is within +-48 — safe in f32.
    """
    B, T, H, N = r.shape
    M = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    C, L = T // chunk, chunk

    rs = r.reshape(B, C, L, H, N)
    ks = k.reshape(B, C, L, H, N)
    vs = v.reshape(B, C, L, H, M)
    lw = log_w.reshape(B, C, L, H, N).astype(jnp.float32)

    if state0 is None:
        state0 = jnp.zeros((B, H, N, M), jnp.float32)
    ds = jnp.ones((H, N), jnp.float32) if diag_scale is None else diag_scale.astype(jnp.float32)
    # strict lower triangle (j<i) for RWKV; lower incl. diagonal for Mamba2
    tri = jnp.tril(jnp.ones((L, L), bool), k=0 if post_update else -1)

    def body(S, inputs):
        rc, kc, vc, lwc = inputs  # (B, L, H, N/M)
        rc32, kc32, vc32 = rc.astype(jnp.float32), kc.astype(jnp.float32), vc.astype(jnp.float32)
        cum = jnp.cumsum(lwc, axis=1)          # inclusive (B, L, H, N)
        cum_prev = cum - lwc                   # exclusive
        r_fac = rc32 * jnp.exp(cum if post_update else cum_prev)
        k_fac = kc32 * jnp.exp(-cum)
        # intra-chunk scores (B, H, L, L)
        scores = jnp.einsum("blhn,bmhn->bhlm", r_fac, k_fac)
        scores = jnp.where(tri[None, None], scores, 0.0)
        o = jnp.einsum("bhlm,bmhv->blhv", scores, vc32)
        if not post_update:  # RWKV u-bonus diagonal
            diag = jnp.einsum("blhn,blhn->bhl", rc32 * ds[None, None], kc32)
            o = o + diag.transpose(0, 2, 1)[..., None] * vc32
        # inter-chunk: contribution of carried state
        o = o + jnp.einsum("blhn,bhnv->blhv", r_fac, S)
        # state update
        decay_all = jnp.exp(cum[:, -1])        # (B, H, N)
        k_tail = kc32 * jnp.exp(cum[:, -1:] - cum)  # exponent <= 0
        S = S * decay_all[..., None] + jnp.einsum("blhn,blhv->bhnv", k_tail, vc32)
        return S, o

    inputs = (
        jnp.moveaxis(rs, 1, 0),
        jnp.moveaxis(ks, 1, 0),
        jnp.moveaxis(vs, 1, 0),
        jnp.moveaxis(lw, 1, 0),
    )
    S, os = jax.lax.scan(body, state0, inputs)
    o = jnp.moveaxis(os, 0, 1).reshape(B, T, H, M)
    return o.astype(v.dtype), S


def _scan_schedule() -> tuple[str, int]:
    """The planned chunked-scan schedule ``(sweep, chunk)`` from the active
    CMU plan's anchor row, or the default state-stationary ``LA_CHUNK`` when
    no plan (or a pre-v8 plan) is active."""
    from repro.core.plan_cache import active_plan

    plan = active_plan()
    sp = plan.scan_plan() if plan is not None else None
    if sp is None or not sp.chunk:
        return "state", LA_CHUNK
    return sp.sweep, sp.chunk


def _scan_decode_kind(batch: int) -> str:
    """The planned decode-scan kind for a ``batch``-slot dispatch: the
    bucketed sub-plan's pick, else "fused" (turning ``ssm_pallas`` on
    without a plan runs the Pallas step kernel everywhere)."""
    from repro.core.plan_cache import active_plan

    plan = active_plan()
    sp = plan.scan_plan() if plan is not None else None
    sub = sp.decode_plan(batch) if sp is not None else None
    return sub.sweep if sub is not None else "fused"


def _chunked_scan(cfg, r, k, v, log_w, diag_scale=None, post_update=False):
    """Prefill/train chunked scan with ragged-T padding: the flex Pallas
    kernel family under the planned (sweep, chunk) when ``cfg.ssm_pallas``,
    else the jnp reference at ``LA_CHUNK``.  Returns (o[:, :T], final_state);
    zero pad rows leave both untouched (see ``_pad_chunks``)."""
    T = r.shape[1]
    if getattr(cfg, "ssm_pallas", False):
        from repro.kernels.flex_scan import flex_scan

        sweep, chunk = _scan_schedule()
        pad = (-T) % chunk
        if pad:
            r, k, v, log_w = (_pad_chunks(a, pad) for a in (r, k, v, log_w))
        o, S = flex_scan(r, k, v, log_w, diag_scale, chunk=chunk,
                         sweep=sweep, post_update=post_update)
    else:
        pad = (-T) % LA_CHUNK
        if pad:
            r, k, v, log_w = (_pad_chunks(a, pad) for a in (r, k, v, log_w))
        o, S = chunked_diag_linear_attn(r, k, v, log_w, diag_scale,
                                        post_update=post_update)
    return o[:, :T], S


def _recurrent(cfg, r, k, v, log_w, S, diag_scale=None, post_update=False):
    """One decode step: the fused Pallas kernel when ``cfg.ssm_pallas`` and
    the bucketed sub-plan picks it, else the jnp recurrence."""
    if getattr(cfg, "ssm_pallas", False) and _scan_decode_kind(r.shape[0]) == "fused":
        from repro.kernels.flex_scan import flex_recurrent_step

        return flex_recurrent_step(r, k, v, log_w, S, diag_scale,
                                   post_update=post_update)
    return recurrent_step(r, k, v, log_w, S, diag_scale,
                          post_update=post_update)


def recurrent_step(
    r: jax.Array,      # (B, H, N)
    k: jax.Array,
    v: jax.Array,      # (B, H, M)
    log_w: jax.Array,  # (B, H, N)
    S: jax.Array,      # (B, H, N, M)
    diag_scale: jax.Array | None = None,
    post_update: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """One decode step of the same recurrence. post_update=True -> Mamba2."""
    r32, k32, v32 = (a.astype(jnp.float32) for a in (r, k, v))
    kv = k32[..., :, None] * v32[..., None, :]  # (B,H,N,M)
    S_new = S * jnp.exp(log_w.astype(jnp.float32))[..., None] + kv
    if post_update:  # Mamba2: output reads the post-update state
        o = jnp.einsum("bhn,bhnv->bhv", r32, S_new)
    else:  # RWKV: output reads pre-update state + u-bonus diagonal
        ds = jnp.ones_like(k32) if diag_scale is None else diag_scale[None].astype(jnp.float32)
        o = jnp.einsum("bhn,bhnv->bhv", r32, S)
        o = o + (r32 * ds * k32).sum(-1)[..., None] * v32
    return o.astype(v.dtype), S_new


# ---------------------------------------------------------------------------
# Mamba2 (zamba2 backbone layer)
# ---------------------------------------------------------------------------


def init_mamba2(cfg: ModelConfig, key) -> Params:
    ks = split_keys(key, 6)
    D, Di, N, Hn = cfg.d_model, cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = Di + 2 * N
    return {
        "in_proj": _init(ks[0], (D, 2 * Di + 2 * N + Hn)),   # z, x, B, C, dt
        "conv_w": _init(ks[1], (cfg.ssm_conv, conv_ch), scale=0.5),
        "conv_b": jnp.zeros((conv_ch,)),
        "A_log": jnp.zeros((Hn,)),
        "D_skip": jnp.ones((Hn,)),
        "dt_bias": jnp.zeros((Hn,)),
        "norm_scale": jnp.zeros((Di,)),
        "out_proj": _init(ks[2], (Di, D)),
    }


def _causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv. x (B,T,C), w (K,C). Returns y, new_state (B,K-1,C)."""
    Kw = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (Kw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(Kw)) + b
    return jax.nn.silu(y), xp[:, -(Kw - 1) :]


def mamba2(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    state: dict[str, jax.Array] | None = None,
    return_state: bool = False,
) -> tuple[jax.Array, dict[str, jax.Array] | None]:
    """Mamba2 (SSD) block. x: (B, T, D). state for decode: {conv, ssm}.

    ``return_state=True`` makes a stateless (prefill) call also return the
    final {conv, ssm} state — the chunked scan computes it anyway, so prefill
    state capture costs nothing extra (it used to re-run the whole layer).
    """
    B, T, D = x.shape
    Di, N, Hn, P = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("btd,de->bte", x, p["in_proj"].astype(x.dtype))
    # SSM token mixing is sequence-serial (conv + chunk recurrence), so the
    # model axis lives on CHANNELS/heads here (Di and all split boundaries are
    # 16-divisible); the residual stream re-shards to seq at the block edge.
    zxbcdt = constrain(zxbcdt, "act_batch", None, "act_heads")
    z, xin, Bm, Cm, dt = jnp.split(zxbcdt, [Di, 2 * Di, 2 * Di + N, 2 * Di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_out, conv_state = _causal_conv1d(
        conv_in, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype),
        None if state is None else state["conv"],
    )
    xin, Bm, Cm = jnp.split(conv_out, [Di, Di + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,Hn)
    log_w = jnp.clip(-dt * jnp.exp(p["A_log"]), LOG_DECAY_MIN, -1e-6)  # (B,T,Hn)

    v = (xin * dt.repeat(P, axis=-1).astype(xin.dtype)).reshape(B, T, Hn, P)
    r = jnp.broadcast_to(Cm[:, :, None, :], (B, T, Hn, N))
    k = jnp.broadcast_to(Bm[:, :, None, :], (B, T, Hn, N))
    lw = jnp.broadcast_to(log_w[..., None], (B, T, Hn, N))
    v = constrain(v, "act_batch", None, "act_heads", None)

    if state is None:  # train / prefill: chunked parallel form
        o, ssm_state = _chunked_scan(cfg, r, k, v, lw, post_update=True)
    else:  # decode: exact recurrence
        o, ssm_state = _recurrent(
            cfg, r[:, 0], k[:, 0], v[:, 0], lw[:, 0], state["ssm"], post_update=True
        )
        o = o[:, None]

    o = o.reshape(B, T, Di) + xin * p["D_skip"].repeat(P)[None, None].astype(xin.dtype)
    o = rmsnorm(o * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    o = constrain(o, "act_batch", None, "act_heads")
    out = jnp.einsum("bte,ed->btd", o, p["out_proj"].astype(x.dtype))
    if state is None and not return_state:
        new_state = None
    elif state is None:  # prefill capture: f32 carry for the decode scan
        new_state = {"conv": conv_state.astype(jnp.float32), "ssm": ssm_state}
    else:
        new_state = {"conv": conv_state, "ssm": ssm_state}
    return out, new_state


def init_mamba_state(cfg: ModelConfig, batch: int) -> dict[str, jax.Array]:
    conv_ch = cfg.ssm_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), jnp.float32),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
    }


# ---------------------------------------------------------------------------
# RWKV-6 "Finch" (time mix with data-dependent decay + channel mix)
# ---------------------------------------------------------------------------


def init_rwkv6(cfg: ModelConfig, key) -> Params:
    ks = split_keys(key, 10)
    D, Hs = cfg.d_model, cfg.rwkv_head_size
    Hn = cfg.rwkv_heads
    Fc = cfg.d_ff // 2  # channel-mix hidden (rwkv convention ~3.5x)
    return {
        "mix": 0.5 * jnp.ones((4, D)),       # token-shift lerp for r,k,v,g
        "mix_w": 0.5 * jnp.ones((D,)),       # token-shift lerp for decay input
        "r_proj": _init(ks[0], (D, D)),
        "k_proj": _init(ks[1], (D, D)),
        "v_proj": _init(ks[2], (D, D)),
        "g_proj": _init(ks[3], (D, D)),
        "dw1": _init(ks[4], (D, cfg.rwkv_decay_lora), scale=0.02),  # Finch decay lora
        "dw2": _init(ks[5], (cfg.rwkv_decay_lora, D), scale=0.02),
        "w0": -6.0 * jnp.ones((D,)),
        "u": _init(ks[6], (Hn, Hs), scale=0.5),
        "ln_x_scale": jnp.ones((D,)),
        "out_proj": _init(ks[7], (D, D)),
        # channel mix
        "mix_c": 0.5 * jnp.ones((2, D)),
        "ck": _init(ks[8], (D, Fc)),
        "cv": _init(ks[9], (Fc, D)),
        "cr": _init(split_keys(ks[0], 2)[1], (D, D)),
    }


def _token_shift(x: jax.Array, last: jax.Array | None):
    """x_{t-1} stream. x (B,T,D); last (B,D) decode carry."""
    if last is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return last[:, None].astype(x.dtype)


def rwkv6_time_mix(
    cfg: ModelConfig, p: Params, x: jax.Array,
    state: dict[str, jax.Array] | None = None,
    return_state: bool = False,
):
    """RWKV-6 time mix.  ``return_state=True`` makes a stateless (prefill)
    call also return the final {shift_t, wkv} state the chunked scan already
    computes — prefill no longer needs its own copy of this function."""
    B, T, D = x.shape
    Hn, Hs = cfg.rwkv_heads, cfg.rwkv_head_size
    prev = _token_shift(x, None if state is None else state["shift_t"])
    mix = p["mix"].astype(x.dtype)

    def lerp(i):
        return x + (prev - x) * mix[i]

    # wkv recurrence is head-local: the model axis rides heads (64 % 16 == 0)
    def hshard(a):
        return constrain(a, "act_batch", None, "act_heads", None)

    r = hshard(jnp.einsum("btd,de->bte", lerp(0), p["r_proj"].astype(x.dtype)).reshape(B, T, Hn, Hs))
    k = hshard(jnp.einsum("btd,de->bte", lerp(1), p["k_proj"].astype(x.dtype)).reshape(B, T, Hn, Hs))
    v = hshard(jnp.einsum("btd,de->bte", lerp(2), p["v_proj"].astype(x.dtype)).reshape(B, T, Hn, Hs))
    g = jnp.einsum("btd,de->bte", lerp(3), p["g_proj"].astype(x.dtype))

    # Finch: data-dependent per-channel decay via low-rank projection
    xw = x + (prev - x) * p["mix_w"].astype(x.dtype)
    dd = jnp.einsum(
        "btr,rd->btd",
        jnp.tanh(jnp.einsum("btd,dr->btr", xw, p["dw1"].astype(x.dtype))),
        p["dw2"].astype(x.dtype),
    )
    log_w = -jnp.exp(jnp.clip(p["w0"].astype(jnp.float32) + dd.astype(jnp.float32), -10.0, 1.0))
    log_w = jnp.clip(log_w, LOG_DECAY_MIN, -1e-6).reshape(B, T, Hn, Hs)

    if state is None:
        o, wkv_state = _chunked_scan(cfg, r, k, v, log_w, p["u"])
    else:
        o, wkv_state = _recurrent(
            cfg, r[:, 0], k[:, 0], v[:, 0], log_w[:, 0], state["wkv"], diag_scale=p["u"]
        )
        o = o[:, None]

    o = o.reshape(B, T, D)
    # group-norm per head (layernorm over head dim), then gate
    o = o.reshape(B, T, Hn, Hs)
    mu = o.mean(-1, keepdims=True)
    var = ((o - mu) ** 2).mean(-1, keepdims=True)
    eps = rwkv_groupnorm_eps(cfg)
    o = ((o - mu) * jax.lax.rsqrt(var + eps)).reshape(B, T, D) * p["ln_x_scale"].astype(x.dtype)
    o = o * jax.nn.silu(g)
    out = jnp.einsum("btd,de->bte", o, p["out_proj"].astype(x.dtype))
    if state is None and not return_state:
        new_state = None
    else:
        new_state = {"shift_t": x[:, -1].astype(jnp.float32), "wkv": wkv_state}
    return out, new_state


def rwkv6_channel_mix(
    cfg: ModelConfig, p: Params, x: jax.Array,
    state: dict[str, jax.Array] | None = None,
    return_state: bool = False,
):
    prev = _token_shift(x, None if state is None else state["shift_c"])
    mix = p["mix_c"].astype(x.dtype)
    xk = x + (prev - x) * mix[0]
    xr = x + (prev - x) * mix[1]
    kk = jnp.einsum("btd,df->btf", xk, p["ck"].astype(x.dtype))
    kk = jnp.square(jax.nn.relu(kk))
    vv = jnp.einsum("btf,fd->btd", kk, p["cv"].astype(x.dtype))
    rr = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["cr"].astype(x.dtype)))
    out = rr * vv
    if state is None and not return_state:
        new_state = None
    else:
        new_state = {"shift_c": x[:, -1].astype(jnp.float32)}
    return out, new_state


def init_rwkv_state(cfg: ModelConfig, batch: int) -> dict[str, jax.Array]:
    return {
        "shift_t": jnp.zeros((batch, cfg.d_model), jnp.float32),
        "shift_c": jnp.zeros((batch, cfg.d_model), jnp.float32),
        "wkv": jnp.zeros(
            (batch, cfg.rwkv_heads, cfg.rwkv_head_size, cfg.rwkv_head_size), jnp.float32
        ),
    }
