"""Model configuration covering all ten assigned architecture families."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: int = 0          # 0 -> d_model // num_heads
    max_seq_len: int = 4096

    # attention variants
    qkv_bias: bool = False          # qwen1.5
    qk_norm: bool = False           # qwen3, gemma3
    window_pattern: tuple[int, ...] = (0,)  # per-layer sliding windows, cycled;
                                            # 0 = full/global. gemma3: (1024,)*5+(0,)
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    norm_eps: float = 1e-6
    activation: str = "silu"        # silu (swiglu) | gelu (geglu) | gelu_mlp
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # residual-stream scaling (minicpm / gemma)
    emb_scale: float = 1.0          # multiply token embeddings
    residual_scale: float = 1.0     # multiply block outputs (minicpm depth-scale)

    # MoE
    num_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0            # per-expert hidden dim
    moe_dense_ff: int = 0           # arctic: parallel dense-FFN residual branch
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0              # mamba2 head state size
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 0             # zamba2: shared attn block after every k-th layer
    rwkv_head_size: int = 64
    rwkv_decay_lora: int = 64       # Finch data-dependent decay low-rank size

    # encoder-decoder (whisper)
    num_enc_layers: int = 0
    enc_seq_len: int = 1500         # audio frames from the (stubbed) conv frontend

    # VLM (paligemma)
    vision_tokens: int = 0          # prefix patch embeddings from stubbed SigLIP
    vision_embed_dim: int = 0       # SigLIP output dim (0 -> d_model)

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # kernels / dispatch
    use_pallas: bool = False        # pallas kernels (interpret on CPU); XLA path off
    attn_chunk: int = 128           # query-chunked attention block (per seq shard)
    attn_unroll: bool = False       # unroll the chunk scan (exact HLO cost probes)
    attn_pallas: bool = False       # flash/paged attention via the planned
                                    # flex kernel family (forward/serve only)
    ssm_pallas: bool = False        # chunked-scan / decode-step via the planned
                                    # flex scan kernel family (ssm + hybrid)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so embed/lm_head shard evenly
        on the 16-way tensor axis (MaxText-style; labels always < vocab_size)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    def window_for_layer(self, i: int) -> int:
        return self.window_pattern[i % len(self.window_pattern)]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for MODEL_FLOPS / roofline) -------------------
    def param_count(self) -> tuple[int, int]:
        """(total_params, active_params_per_token)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        emb = V * D * (1 if self.tie_embeddings else 2)

        def attn_p() -> int:
            p = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
            if self.qkv_bias:
                p += self.q_dim + 2 * self.kv_dim
            return p

        def mlp_p(ff: int) -> int:
            mats = 3 if self.activation in ("silu", "gelu") else 2
            return mats * D * ff

        total = active = 0
        if self.family in ("dense", "vlm"):
            per = attn_p() + mlp_p(F) + 2 * D
            total = active = L * per
        elif self.family == "moe":
            e_ff = self.expert_d_ff or F
            per_shared = attn_p() + 2 * D + D * self.num_experts
            per_shared += mlp_p(self.moe_dense_ff) if self.moe_dense_ff else 0
            total = L * (per_shared + self.num_experts * mlp_p(e_ff))
            active = L * (per_shared + self.top_k * mlp_p(e_ff))
        elif self.family == "ssm":  # rwkv6
            H = self.rwkv_head_size
            per = 4 * D * D + D * D  # r,k,v,g,out
            per += 2 * self.rwkv_decay_lora * D + D * H  # decay lora + u
            per += 2 * D * F // 2 + D * D  # channel mix (k: D->F', v: F'->D, r: D->D)
            total = active = L * per
        elif self.family == "hybrid":  # zamba2: mamba layers + one shared attn block
            di = self.ssm_inner
            per_mamba = D * (2 * di + 2 * self.ssm_state + self.ssm_heads) + di * D
            per_mamba += self.ssm_conv * (di + 2 * self.ssm_state) + 2 * self.ssm_heads
            shared = attn_p() + mlp_p(F) + 2 * D
            total = active = L * per_mamba + shared
        elif self.family == "encdec":
            enc = self.num_enc_layers * (attn_p() + mlp_p(F) + 2 * D)
            dec = L * (2 * attn_p() + mlp_p(F) + 3 * D)
            total = active = enc + dec
        total += emb
        active += emb
        return total, active
