"""Model assembly for all ten architectures.

One functional ``Model`` facade with family-specific forward / prefill /
decode paths.  Layer stacks run under ``jax.lax.scan`` over *groups* of
``len(cfg.window_pattern)`` layers so per-layer static sliding windows
(gemma3's 5 local : 1 global) coexist with scan's compact HLO.  Params are
nested dicts; stacked layer params carry a leading (num_groups, group_size)
pair of axes.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as Lyr
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.models.sharding import constrain

Params = dict[str, Any]


def _stack(trees: list[Params]) -> Params:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _group(stacked: Params, groups: int, per: int) -> Params:
    return jax.tree.map(lambda a: a.reshape(groups, per, *a.shape[1:]), stacked)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    pol = None
    if policy == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint(fn, policy=pol)


# ---------------------------------------------------------------------------
# dense / moe decoder blocks
# ---------------------------------------------------------------------------


def init_block(cfg: ModelConfig, key) -> Params:
    ks = Lyr.split_keys(key, 4)
    p: Params = {
        "ln1": Lyr.init_norm(cfg, ks[0]),
        "attn": Lyr.init_attention(cfg, ks[1]),
        "ln2": Lyr.init_norm(cfg, ks[2]),
    }
    if cfg.family == "moe":
        p["moe"] = Lyr.init_moe(cfg, ks[3])
        if cfg.moe_dense_ff:
            p["mlp"] = Lyr.init_mlp(cfg, Lyr.split_keys(ks[3], 2)[1], cfg.moe_dense_ff)
    else:
        p["mlp"] = Lyr.init_mlp(cfg, ks[3])
    return p


def block_apply(
    cfg: ModelConfig, p: Params, x: jax.Array, window: int, prefix_len: int = 0
) -> tuple[jax.Array, dict[str, jax.Array]]:
    # With unit residual scale the residual adds fuse into the wo / w2
    # projection kernels (pallas path) instead of separate XLA adds.
    fuse_res = cfg.residual_scale == 1.0
    h = Lyr.norm(cfg, p["ln1"], x)
    h = Lyr.attention_full(cfg, p["attn"], h, window=window, prefix_len=prefix_len,
                           residual=x if fuse_res else None)
    x = h if fuse_res else x + cfg.residual_scale * h
    h = Lyr.norm(cfg, p["ln2"], x)
    aux = {"load_balance": jnp.zeros((), jnp.float32), "router_z": jnp.zeros((), jnp.float32)}
    if "moe" in p:
        mo, aux = Lyr.moe(cfg, p["moe"], h)
        if "mlp" in p:
            mo = mo + Lyr.mlp(cfg, p["mlp"], h)
        x = x + cfg.residual_scale * mo
    else:
        mo = Lyr.mlp(cfg, p["mlp"], h, residual=x if fuse_res else None)
        x = mo if fuse_res else x + cfg.residual_scale * mo
    x = constrain(x, "act_batch", "act_seq", "act_embed")
    return x, aux


def block_decode(
    cfg: ModelConfig, p: Params, x: jax.Array, cache: Params, pos: jax.Array, window: int
) -> tuple[jax.Array, Params]:
    h = Lyr.norm(cfg, p["ln1"], x)
    h, cache = Lyr.attention_decode(cfg, p["attn"], h, cache, pos, window=window)
    x = x + cfg.residual_scale * h
    h = Lyr.norm(cfg, p["ln2"], x)
    if "moe" in p:
        mo, _ = Lyr.moe(cfg, p["moe"], h)
        if "mlp" in p:
            mo = mo + Lyr.mlp(cfg, p["mlp"], h)
    else:
        mo = Lyr.mlp(cfg, p["mlp"], h)
    return x + cfg.residual_scale * mo, cache


def block_decode_paged(
    cfg: ModelConfig, p: Params, x: jax.Array, pk: jax.Array, pv: jax.Array,
    table: jax.Array, positions: jax.Array, window: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``block_decode`` against one layer's paged block pools (per-slot positions)."""
    h = Lyr.norm(cfg, p["ln1"], x)
    h, pk, pv = Lyr.attention_decode_paged(
        cfg, p["attn"], h, pk, pv, table, positions, window=window)
    x = x + cfg.residual_scale * h
    h = Lyr.norm(cfg, p["ln2"], x)
    if "moe" in p:
        mo, _ = Lyr.moe(cfg, p["moe"], h)
        if "mlp" in p:
            mo = mo + Lyr.mlp(cfg, p["mlp"], h)
    else:
        mo = Lyr.mlp(cfg, p["mlp"], h)
    return x + cfg.residual_scale * mo, pk, pv


# ---------------------------------------------------------------------------
# the Model facade
# ---------------------------------------------------------------------------


class Model:
    def __init__(self, cfg: ModelConfig, remat: str = "none", unroll: bool = False):
        self.cfg = cfg
        self.remat = remat
        self.unroll = unroll  # python-loop layer stacks (exact HLO cost probes)
        self.dtype = jnp.dtype(cfg.dtype)
        pat = len(cfg.window_pattern)
        if cfg.family in ("dense", "moe", "vlm") and cfg.num_layers % pat:
            raise ValueError(f"{cfg.num_layers} layers not divisible by pattern {pat}")

    def _scan(self, body, carry, xs):
        """lax.scan, or an unrolled python loop when cost probing."""
        if not self.unroll:
            return jax.lax.scan(body, carry, xs)
        L = jax.tree.leaves(xs)[0].shape[0]
        ys = []
        for i in range(L):
            xi = jax.tree.map(lambda a, i=i: a[i], xs)
            carry, y = body(carry, xi)
            ys.append(y)
        if all(y is None for y in ys):
            return carry, None
        return carry, jax.tree.map(lambda *a: jnp.stack(a), *ys)

    # -- init ---------------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        ks = Lyr.split_keys(key, 8)
        params: Params = {
            "embed": Lyr._init(ks[0], (cfg.padded_vocab, cfg.d_model), scale=0.02),
            "final_norm": Lyr.init_norm(cfg, ks[1]),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = Lyr._init(ks[2], (cfg.d_model, cfg.padded_vocab), scale=0.02)

        if cfg.family in ("dense", "moe", "vlm"):
            blocks = [init_block(cfg, k) for k in Lyr.split_keys(ks[3], cfg.num_layers)]
            params["layers"] = _stack(blocks)
            if cfg.family == "vlm":
                vin = cfg.vision_embed_dim or cfg.d_model
                params["vision_proj"] = Lyr._init(ks[4], (vin, cfg.d_model))
        elif cfg.family == "ssm":
            blocks = []
            for k in Lyr.split_keys(ks[3], cfg.num_layers):
                k1, k2, k3, k4 = Lyr.split_keys(k, 4)
                blocks.append(
                    {
                        "ln1": Lyr.init_norm(cfg, k1),
                        "tmix": S.init_rwkv6(cfg, k2),
                        "ln2": Lyr.init_norm(cfg, k3),
                    }
                )
            params["layers"] = _stack(blocks)
        elif cfg.family == "hybrid":
            blocks = []
            for k in Lyr.split_keys(ks[3], cfg.num_layers):
                k1, k2 = Lyr.split_keys(k, 2)
                blocks.append({"ln1": Lyr.init_norm(cfg, k1), "mamba": S.init_mamba2(cfg, k2)})
            params["layers"] = _stack(blocks)
            params["shared_attn"] = init_block(cfg.replace(family="dense"), ks[4])
        elif cfg.family == "encdec":
            enc_cfg = cfg
            params["enc_layers"] = _stack(
                [init_block(cfg.replace(family="dense"), k)
                 for k in Lyr.split_keys(ks[3], cfg.num_enc_layers)]
            )
            params["enc_norm"] = Lyr.init_norm(cfg, ks[4])
            dec = []
            for k in Lyr.split_keys(ks[5], cfg.num_layers):
                k1, k2, k3, k4, k5, k6 = Lyr.split_keys(k, 6)
                dec.append(
                    {
                        "ln1": Lyr.init_norm(cfg, k1),
                        "attn": Lyr.init_attention(cfg, k2),
                        "ln_x": Lyr.init_norm(cfg, k3),
                        "xattn": Lyr.init_attention(cfg, k4),
                        "ln2": Lyr.init_norm(cfg, k5),
                        "mlp": Lyr.init_mlp(cfg, k6),
                    }
                )
            params["layers"] = _stack(dec)
            params["dec_pos"] = Lyr._init(ks[6], (cfg.max_seq_len, cfg.d_model), scale=0.02)
        pdt = jnp.dtype(cfg.param_dtype)
        if pdt != jnp.float32:
            params = jax.tree.map(lambda a: a.astype(pdt), params)
        return params

    # -- shared helpers -------------------------------------------------------
    def _embed(self, params, tokens):
        x = params["embed"].astype(self.dtype)[tokens] * self.cfg.emb_scale
        return constrain(x, "act_batch", "act_seq", "act_embed")

    def _logits(self, params, h):
        cfg = self.cfg
        h = Lyr.norm(cfg, params["final_norm"], h)
        wout = params.get("lm_head")
        if wout is None:
            wout = params["embed"].T / max(cfg.emb_scale, 1.0)
        logits = Lyr.linear(cfg, h, wout, name="lm_head").astype(jnp.float32)
        if cfg.logit_softcap:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        # vocab (not seq) carries the 'model' axis here — the two must not collide
        return constrain(logits, "act_batch", "act_seq_np", "act_vocab")

    # -- dense/moe/vlm stack --------------------------------------------------
    def _stack_forward(self, params, x, prefix_len=0):
        cfg = self.cfg
        pat = len(cfg.window_pattern)
        groups = cfg.num_layers // pat
        gp = _group(params["layers"], groups, pat)

        def body(carry, lp):
            x, lb, rz = carry
            for j in range(pat):
                pj = jax.tree.map(lambda a, j=j: a[j], lp)
                x, aux = block_apply(cfg, pj, x, cfg.window_pattern[j], prefix_len)
                lb, rz = lb + aux["load_balance"], rz + aux["router_z"]
            return (x, lb, rz), None

        body = _remat(body, self.remat)
        (x, lb, rz), _ = self._scan(body, (x, jnp.zeros(()), jnp.zeros(())), gp)
        return x, {"load_balance": lb / cfg.num_layers, "router_z": rz / cfg.num_layers}

    # -- rwkv stack -------------------------------------------------------------
    def _rwkv_forward(self, params, x):
        cfg = self.cfg

        def body(carry, lp):
            x, = carry
            h, _ = S.rwkv6_time_mix(cfg, lp["tmix"], Lyr.norm(cfg, lp["ln1"], x))
            x = x + h
            h, _ = S.rwkv6_channel_mix(cfg, lp["tmix"], Lyr.norm(cfg, lp["ln2"], x))
            x = x + h
            x = constrain(x, "act_batch", "act_seq", "act_embed")
            return (x,), None

        body = _remat(body, self.remat)
        (x,), _ = self._scan(body, (x,), params["layers"])
        return x, {}

    # -- hybrid (zamba2) stack ---------------------------------------------------
    def _hybrid_forward(self, params, x):
        cfg = self.cfg
        flags = jnp.array(
            [(i % cfg.attn_every == cfg.attn_every - 1) for i in range(cfg.num_layers)]
        )
        shared = params["shared_attn"]

        def body(carry, inp):
            x, = carry
            lp, flag = inp
            h, _ = S.mamba2(cfg, lp["mamba"], Lyr.norm(cfg, lp["ln1"], x))
            x = x + h

            def with_attn(x):
                y, _ = block_apply(cfg, shared, x, window=0)
                return y

            x = jax.lax.cond(flag, with_attn, lambda x: x, x)
            x = constrain(x, "act_batch", "act_seq", "act_embed")
            return (x,), None

        body = _remat(body, self.remat)
        (x,), _ = self._scan(body, (x,), (params["layers"], flags))
        return x, {}

    # -- encdec (whisper) ---------------------------------------------------------
    def _encode(self, params, audio_embeds):
        cfg = self.cfg
        x = audio_embeds.astype(self.dtype)
        x = x + Lyr.sinusoidal_pos(x.shape[1], cfg.d_model).astype(x.dtype)[None]
        x = constrain(x, "act_batch", "act_seq", "act_embed")

        def body(carry, lp):
            x, = carry
            h = Lyr.norm(cfg, lp["ln1"], x)
            h = Lyr.attention_full(cfg, lp["attn"], h, causal=False, use_rope=False)
            x = x + h
            h = Lyr.norm(cfg, lp["ln2"], x)
            x = x + Lyr.mlp(cfg, lp["mlp"], h)
            return (constrain(x, "act_batch", "act_seq", "act_embed"),), None

        body = _remat(body, self.remat)
        (x,), _ = self._scan(body, (x,), params["enc_layers"])
        return Lyr.norm(cfg, params["enc_norm"], x)

    def _decode_stack(self, params, tokens, enc_out):
        cfg = self.cfg
        B, Sq = tokens.shape
        x = params["embed"].astype(self.dtype)[tokens]
        x = x + params["dec_pos"][:Sq].astype(x.dtype)[None]
        x = constrain(x, "act_batch", "act_seq", "act_embed")

        def body(carry, lp):
            x, = carry
            h = Lyr.norm(cfg, lp["ln1"], x)
            h = Lyr.attention_full(cfg, lp["attn"], h, use_rope=False)
            x = x + h
            h = Lyr.norm(cfg, lp["ln_x"], x)
            h = Lyr.attention_full(cfg, lp["xattn"], h, causal=False, xkv=enc_out, use_rope=False)
            x = x + h
            h = Lyr.norm(cfg, lp["ln2"], x)
            x = x + Lyr.mlp(cfg, lp["mlp"], h)
            return (constrain(x, "act_batch", "act_seq", "act_embed"),), None

        body = _remat(body, self.remat)
        (x,), _ = self._scan(body, (x,), params["layers"])
        return x

    # -- public: training forward --------------------------------------------------
    def forward(self, params: Params, batch: dict[str, jax.Array]):
        """Returns (logits, aux). batch keys depend on family (see input_specs)."""
        cfg = self.cfg
        if cfg.family in ("dense", "moe"):
            x = self._embed(params, batch["tokens"])
            h, aux = self._stack_forward(params, x)
        elif cfg.family == "vlm":
            vis = jnp.einsum(
                "bsd,de->bse", batch["vision_embeds"].astype(self.dtype),
                params["vision_proj"].astype(self.dtype),
            )
            txt = self._embed(params, batch["tokens"])
            x = jnp.concatenate([vis, txt], axis=1)
            x = constrain(x, "act_batch", "act_seq", "act_embed")
            h, aux = self._stack_forward(params, x, prefix_len=cfg.vision_tokens)
            h = h[:, cfg.vision_tokens :]
        elif cfg.family == "ssm":
            x = self._embed(params, batch["tokens"])
            h, aux = self._rwkv_forward(params, x)
        elif cfg.family == "hybrid":
            x = self._embed(params, batch["tokens"])
            h, aux = self._hybrid_forward(params, x)
        elif cfg.family == "encdec":
            enc = self._encode(params, batch["audio_embeds"])
            h = self._decode_stack(params, batch["tokens"], enc)
            aux = {}
        else:
            raise ValueError(cfg.family)
        return self._logits(params, h), aux

    def loss(self, params: Params, batch: dict[str, jax.Array]):
        if self.cfg.attn_pallas:
            raise ValueError(
                "attn_pallas is forward/serve only: the flex flash-attention "
                "kernels define no VJP. Train with attn_pallas=False.")
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        lab = jnp.maximum(labels, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # one-hot contraction instead of take_along_axis: reduces over the
        # vocab-sharded axis without gathering full-vocab logit rows
        onehot = jax.nn.one_hot(lab, logits.shape[-1], dtype=logits.dtype)
        gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
        nll = (lse - gold) * mask
        loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
        if aux:
            loss = loss + 1e-2 * aux.get("load_balance", 0.0) + 1e-3 * aux.get("router_z", 0.0)
        return loss, {"nll": loss, **{k: v for k, v in aux.items()}}

    # -- public: serving -------------------------------------------------------------
    def prefill(self, params: Params, batch: dict[str, jax.Array], cache_len: int):
        """Run the prompt, build decode caches. Returns (cache, last_logits)."""
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            return self._prefill_dense(params, batch, cache_len)
        if cfg.family == "ssm":
            return self._prefill_rwkv(params, batch)
        if cfg.family == "hybrid":
            return self._prefill_hybrid(params, batch, cache_len)
        if cfg.family == "encdec":
            return self._prefill_encdec(params, batch, cache_len)
        raise ValueError(cfg.family)

    def prefill_kv(self, params, batch):
        """Forward the prompt and return ``(logits, k_all, v_all)`` with
        k/v stacked ``(L, B, Sp, Hkv, hd)`` bf16 — no cache layout imposed.

        This is the layout-agnostic half of prefill: ``_prefill_dense``
        copies the K/V into a dense ``(L, B, cache_len, ...)`` cache, while
        the paged serving path (``launch.scheduler``) scatters it into KV
        block pools through a block table instead.  Dense/moe/vlm only.
        """
        cfg = self.cfg
        if cfg.family not in ("dense", "moe", "vlm"):
            raise NotImplementedError(f"prefill_kv covers dense/moe/vlm, not {cfg.family}")
        prefix = cfg.vision_tokens if cfg.family == "vlm" else 0
        if cfg.family == "vlm":
            vis = jnp.einsum(
                "bsd,de->bse", batch["vision_embeds"].astype(self.dtype),
                params["vision_proj"].astype(self.dtype),
            )
            x = jnp.concatenate([vis, self._embed(params, batch["tokens"])], axis=1)
        else:
            x = self._embed(params, batch["tokens"])
        B, Sp, _ = x.shape
        pat = len(cfg.window_pattern)
        gp = _group(params["layers"], cfg.num_layers // pat, pat)

        def body(carry, inp):
            x, = carry
            lp, gi = inp
            ks, vs = [], []
            for j in range(pat):
                pj = jax.tree.map(lambda a, j=j: a[j], lp)
                h = Lyr.norm(cfg, pj["ln1"], x)
                q, k, v = Lyr._project_qkv(cfg, pj["attn"], h)
                k = Lyr.rope(k, jnp.arange(Sp), cfg.rope_theta)
                ks.append(k.astype(jnp.bfloat16))
                vs.append(v.astype(jnp.bfloat16))
                x, _ = block_apply(cfg, pj, x, cfg.window_pattern[j], prefix)
            return (x,), (jnp.stack(ks), jnp.stack(vs))

        (x,), (k_all, v_all) = self._scan(body, (x,), (gp, jnp.arange(cfg.num_layers // pat)))
        k_all = k_all.reshape(cfg.num_layers, B, Sp, cfg.num_kv_heads, cfg.head_dim)
        v_all = v_all.reshape(cfg.num_layers, B, Sp, cfg.num_kv_heads, cfg.head_dim)
        # logits come off the same pass: the scan's x walks through the exact
        # ``block_apply`` sequence ``forward`` uses, so final-norm + lm_head
        # here is bitwise-identical to a separate forward — at half the cost.
        logits = self._logits(params, x[:, prefix:] if prefix else x)
        return logits, k_all, v_all

    def _prefill_dense(self, params, batch, cache_len):
        cfg = self.cfg
        logits, k_all, v_all = self.prefill_kv(params, batch)
        B, Sp = k_all.shape[1], k_all.shape[2]
        cache = Lyr.init_kv_cache(cfg, B, cache_len)
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_all, 0, axis=2)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_all, 0, axis=2)
        return {"kv": cache, "pos": jnp.array(Sp, jnp.int32)}, logits[:, -1]

    def _prefill_rwkv(self, params, batch):
        cfg = self.cfg

        def body(carry, lp):
            x, = carry
            # the exact block-forward op sequence — ``return_state=True``
            # captures the final {shift, wkv} states the chunked scan already
            # computes, so prefill logits stay bitwise equal to ``forward``
            # (this used to be a 40-line drift-prone copy of the time mix)
            h = Lyr.norm(cfg, lp["ln1"], x)
            to, st1 = S.rwkv6_time_mix(cfg, lp["tmix"], h, return_state=True)
            x = x + to
            h2 = Lyr.norm(cfg, lp["ln2"], x)
            co, st2 = S.rwkv6_channel_mix(cfg, lp["tmix"], h2, return_state=True)
            x = x + co
            x = constrain(x, "act_batch", "act_seq", "act_embed")
            return (x,), {**st1, **st2}

        (h,), states = self._scan(body, (self._embed(params, batch["tokens"]),), params["layers"])
        logits = self._logits(params, h)
        return {"states": states, "pos": jnp.array(batch["tokens"].shape[1], jnp.int32)}, logits[:, -1]

    def _prefill_hybrid(self, params, batch, cache_len):
        cfg = self.cfg
        x = self._embed(params, batch["tokens"])
        B, T, _ = x.shape
        n_attn = sum(1 for i in range(cfg.num_layers) if i % cfg.attn_every == cfg.attn_every - 1)
        kv = Lyr.init_kv_cache(cfg, B, cache_len, layers=n_attn)
        flags = jnp.array([(i % cfg.attn_every == cfg.attn_every - 1) for i in range(cfg.num_layers)])
        slots = jnp.cumsum(flags) - 1
        shared = params["shared_attn"]

        def body(carry, inp):
            x, kv_k, kv_v = carry
            lp, flag, slot = inp
            h = Lyr.norm(cfg, lp["ln1"], x)
            B, T, D = h.shape
            # one mamba pass per layer: the chunked scan's final state comes
            # back through ``return_state`` (this used to re-run the whole
            # layer a second time just to recompute it)
            ho, st = S.mamba2(cfg, lp["mamba"], h, return_state=True)
            x = x + ho

            def with_attn(args):
                x, kv_k, kv_v = args
                hh = Lyr.norm(cfg, shared["ln1"], x)
                q, k, v = Lyr._project_qkv(cfg, shared["attn"], hh)
                k = Lyr.rope(k, jnp.arange(T), cfg.rope_theta)
                y, _ = block_apply(cfg, shared, x, window=0)
                zeros = jnp.zeros((1,) + kv_k.shape[1:], kv_k.dtype)
                k_pad = jax.lax.dynamic_update_slice(zeros, k[None].astype(kv_k.dtype), (0, 0, 0, 0, 0))
                v_pad = jax.lax.dynamic_update_slice(zeros, v[None].astype(kv_v.dtype), (0, 0, 0, 0, 0))
                kv_k = jax.lax.dynamic_update_slice(kv_k, k_pad, (slot, 0, 0, 0, 0))
                kv_v = jax.lax.dynamic_update_slice(kv_v, v_pad, (slot, 0, 0, 0, 0))
                return y, kv_k, kv_v

            x, kv_k, kv_v = jax.lax.cond(flag, with_attn, lambda a: a, (x, kv_k, kv_v))
            x = constrain(x, "act_batch", "act_seq", "act_embed")
            return (x, kv_k, kv_v), st

        (h, kv_k, kv_v), mstates = self._scan(
            body, (x, kv["k"], kv["v"]), (params["layers"], flags, slots)
        )
        logits = self._logits(params, h)
        return (
            {"mamba": mstates, "kv": {"k": kv_k, "v": kv_v}, "pos": jnp.array(T, jnp.int32)},
            logits[:, -1],
        )

    def _prefill_encdec(self, params, batch, cache_len):
        cfg = self.cfg
        enc = self._encode(params, batch["audio_embeds"])
        tokens = batch["tokens"]
        h = self._decode_stack(params, tokens, enc)
        logits = self._logits(params, h)
        B, Sp = tokens.shape
        cache = Lyr.init_kv_cache(cfg, B, cache_len)
        # self-attn K/V for the prompt + cross K/V from encoder output
        x = params["embed"].astype(self.dtype)[tokens] + params["dec_pos"][:Sp].astype(self.dtype)[None]

        def body(carry, lp):
            x, = carry
            h = Lyr.norm(cfg, lp["ln1"], x)
            _, k, v = Lyr._project_qkv(cfg, lp["attn"], h)
            hx = Lyr.norm(cfg, lp["ln_x"], x)
            _, xk, xv = Lyr._project_qkv(cfg, lp["xattn"], hx, enc)
            h2 = Lyr.attention_full(cfg, lp["attn"], h, use_rope=False)
            x = x + h2
            hx2 = Lyr.norm(cfg, lp["ln_x"], x)
            x = x + Lyr.attention_full(cfg, lp["xattn"], hx2, causal=False, xkv=enc, use_rope=False)
            h3 = Lyr.norm(cfg, lp["ln2"], x)
            x = x + Lyr.mlp(cfg, lp["mlp"], h3)
            return (x,), (k.astype(jnp.bfloat16), v.astype(jnp.bfloat16), xk.astype(jnp.bfloat16), xv.astype(jnp.bfloat16))

        (_,), (ks, vs, xks, xvs) = self._scan(body, (x,), params["layers"])
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], ks, 0, axis=2)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], vs, 0, axis=2)
        return (
            {"kv": cache, "cross_k": xks, "cross_v": xvs, "pos": jnp.array(Sp, jnp.int32)},
            logits[:, -1],
        )

    # -- public: one-token decode ------------------------------------------------------
    def decode_step(self, params: Params, cache, token: jax.Array):
        """token: (B,) int32. Returns (logits (B,V), new_cache)."""
        cfg = self.cfg
        pos = cache["pos"]
        x = params["embed"].astype(self.dtype)[token][:, None] * cfg.emb_scale
        if cfg.family in ("dense", "moe", "vlm"):
            # The full cache rides the scan CARRY and is updated in place with
            # per-(layer, pos) dynamic_update_slice — scan-stacked ys would
            # defeat buffer donation and double the multi-GB cache in HBM
            # (observed: +6-18GB temp per decode step before this change).
            pat = len(cfg.window_pattern)
            groups = cfg.num_layers // pat
            gp = _group(params["layers"], groups, pat)

            def gbody(carry, inp):
                x, kv_k, kv_v = carry
                lp, g = inp
                for j in range(pat):
                    pj = jax.tree.map(lambda a, j=j: a[j], lp)
                    li = g * pat + j
                    kc = jax.lax.dynamic_index_in_dim(kv_k, li, 0, keepdims=False)
                    vc = jax.lax.dynamic_index_in_dim(kv_v, li, 0, keepdims=False)
                    x, c = block_decode(
                        cfg, pj, x, {"k": kc, "v": vc}, pos,
                        window=cfg.window_pattern[j],
                    )
                    kv_k = jax.lax.dynamic_update_index_in_dim(kv_k, c["k"], li, 0)
                    kv_v = jax.lax.dynamic_update_index_in_dim(kv_v, c["v"], li, 0)
                return (x, kv_k, kv_v), None

            (x, nk, nv), _ = self._scan(
                gbody, (x, cache["kv"]["k"], cache["kv"]["v"]),
                (gp, jnp.arange(groups)),
            )
            logits = self._logits(params, x)[:, 0]
            return logits, {"kv": {"k": nk, "v": nv}, "pos": pos + 1}

        if cfg.family == "ssm":
            def body(carry, inp):
                x, = carry
                lp, st = inp
                h = Lyr.norm(cfg, lp["ln1"], x)
                ho, st1 = S.rwkv6_time_mix(cfg, lp["tmix"], h, state={"shift_t": st["shift_t"], "wkv": st["wkv"]})
                x = x + ho
                h2 = Lyr.norm(cfg, lp["ln2"], x)
                co, st2 = S.rwkv6_channel_mix(cfg, lp["tmix"], h2, state={"shift_c": st["shift_c"]})
                x = x + co
                return (x,), {**st1, **st2}

            (x,), states = self._scan(body, (x,), (params["layers"], cache["states"]))
            logits = self._logits(params, x)[:, 0]
            return logits, {"states": states, "pos": pos + 1}

        if cfg.family == "hybrid":
            # scan over layers; shared-attn block applied via lax.cond on the
            # scanned flag, its KV cache carried whole with a scanned slot idx
            flags = jnp.array(
                [(i % cfg.attn_every == cfg.attn_every - 1) for i in range(cfg.num_layers)]
            )
            slots = jnp.cumsum(flags) - 1
            sh = params["shared_attn"]

            def body(carry, inp):
                x, kv_k, kv_v = carry
                lp, st, flag, slot = inp
                h = Lyr.norm(cfg, lp["ln1"], x)
                ho, st2 = S.mamba2(cfg, lp["mamba"], h, state=st)
                x = x + ho

                def with_attn(args):
                    x, kv_k, kv_v = args
                    hh = Lyr.norm(cfg, sh["ln1"], x)
                    kc = jax.lax.dynamic_index_in_dim(kv_k, slot, 0, keepdims=False)
                    vc = jax.lax.dynamic_index_in_dim(kv_v, slot, 0, keepdims=False)
                    ha, c = Lyr.attention_decode(cfg, sh["attn"], hh, {"k": kc, "v": vc}, pos)
                    y = x + ha
                    h2 = Lyr.norm(cfg, sh["ln2"], y)
                    y = y + Lyr.mlp(cfg, sh["mlp"], h2)
                    kv_k = jax.lax.dynamic_update_index_in_dim(kv_k, c["k"], slot, 0)
                    kv_v = jax.lax.dynamic_update_index_in_dim(kv_v, c["v"], slot, 0)
                    return y, kv_k, kv_v

                x, kv_k, kv_v = jax.lax.cond(flag, with_attn, lambda a: a, (x, kv_k, kv_v))
                return (x, kv_k, kv_v), st2

            (x, nk, nv), mstack = self._scan(
                body,
                (x, cache["kv"]["k"], cache["kv"]["v"]),
                (params["layers"], cache["mamba"], flags, slots),
            )
            logits = self._logits(params, x)[:, 0]
            return logits, {"mamba": mstack, "kv": {"k": nk, "v": nv}, "pos": pos + 1}

        if cfg.family == "encdec":
            x = x + params["dec_pos"][pos][None, None].astype(x.dtype)

            def body(carry, inp):
                x, = carry
                lp, kc, vc, xk, xv = inp
                h = Lyr.norm(cfg, lp["ln1"], x)
                ha, c = Lyr.attention_decode(cfg, lp["attn"], h, {"k": kc, "v": vc}, pos, use_rope=False)
                x = x + ha
                hx = Lyr.norm(cfg, lp["ln_x"], x)
                q, _, _ = Lyr._project_qkv(cfg, lp["xattn"], hx)
                import math as _m
                o = Lyr._sdpa(q, xk, xv, jnp.ones((1, 1, 1, xk.shape[1]), bool), 1.0 / _m.sqrt(cfg.head_dim))
                D = cfg.d_model
                x = x + jnp.einsum(
                    "bshd,hdD->bsD", o,
                    lp["xattn"]["wo"].astype(x.dtype).reshape(cfg.num_heads, cfg.head_dim, D),
                )
                h2 = Lyr.norm(cfg, lp["ln2"], x)
                x = x + Lyr.mlp(cfg, lp["mlp"], h2)
                return (x,), (c["k"], c["v"])

            (x,), (nk, nv) = self._scan(
                body, (x,),
                (params["layers"], cache["kv"]["k"], cache["kv"]["v"], cache["cross_k"], cache["cross_v"]),
            )
            logits = self._logits(params, x)[:, 0]
            return logits, {**cache, "kv": {"k": nk, "v": nv}, "pos": pos + 1}

        raise ValueError(cfg.family)

    def decode_step_paged(self, params: Params, pools, table, positions, token: jax.Array):
        """One continuous-batching decode step over the paged KV block pools.

        pools {"k","v"}: (L, num_blocks, bs, Hkv, hd); table (B, nb) int32
        per-slot block tables; positions (B,) int32 per-slot write positions;
        token (B,) int32.  Returns (logits (B, V), new pools).  Slot →
        request mapping, admission, eviction and the block free list are the
        scheduler's problem — this step is pure fixed-shape array math, one
        jit signature per batch-size bucket.  Like ``decode_step``, the
        pools ride the scan carry with per-layer dynamic slices so buffer
        donation keeps one pool-sized buffer live.  Dense/moe/vlm only.
        """
        cfg = self.cfg
        if cfg.family not in ("dense", "moe", "vlm"):
            raise NotImplementedError(
                f"decode_step_paged covers dense/moe/vlm, not {cfg.family}")
        x = params["embed"].astype(self.dtype)[token][:, None] * cfg.emb_scale
        pat = len(cfg.window_pattern)
        groups = cfg.num_layers // pat
        gp = _group(params["layers"], groups, pat)

        def gbody(carry, inp):
            x, pool_k, pool_v = carry
            lp, g = inp
            for j in range(pat):
                pj = jax.tree.map(lambda a, j=j: a[j], lp)
                li = g * pat + j
                kl = jax.lax.dynamic_index_in_dim(pool_k, li, 0, keepdims=False)
                vl = jax.lax.dynamic_index_in_dim(pool_v, li, 0, keepdims=False)
                x, kl, vl = block_decode_paged(
                    cfg, pj, x, kl, vl, table, positions,
                    window=cfg.window_pattern[j],
                )
                pool_k = jax.lax.dynamic_update_index_in_dim(pool_k, kl, li, 0)
                pool_v = jax.lax.dynamic_update_index_in_dim(pool_v, vl, li, 0)
            return (x, pool_k, pool_v), None

        (x, nk, nv), _ = self._scan(
            gbody, (x, pools["k"], pools["v"]), (gp, jnp.arange(groups)))
        logits = self._logits(params, x)[:, 0]
        return logits, {"k": nk, "v": nv}
