"""AdamW with optional block-wise int8-quantised moments (8-bit Adam).

The int8 states are a distributed-optimisation feature: for the 480B-class
MoE configs they cut optimiser memory 4x (fp32 m,v -> int8 + per-block f32
scales), which is what lets arctic-480b train on a single 256-chip pod
(EXPERIMENTS.md §Dry-run memory table).  Quantisation is block-wise absmax
(block = trailing 256 elements) with dequant-before-update, requant-after,
an error-feedback-free scheme adequate at these block sizes.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any
BLOCK = 256


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    m: Params
    v: Params
    scales: Params | None = None  # (m_scale, v_scale) trees when quantised


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-channel (last-axis) absmax int8 quantisation.

    The int8 tensor keeps exactly the parameter's shape — and therefore its
    sharding — with one f32 scale per channel.  No reshapes: any re-blocking
    across sharded dims forces GSPMD to all-gather the full f32 state on
    dequantise (hundreds of GB for the 480B configs; observed before this fix).
    """
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jax.Array, scale: jax.Array, shape, size) -> jax.Array:
    return (q.astype(jnp.float32) * scale).reshape(shape)


def adamw_init(params: Params, quantize: bool = False) -> AdamWState:
    """quantize=True: int8 per-channel first moment + bf16 second moment
    (~3.1 bytes/param vs 8) — the second moment's sqrt sensitivity makes
    int8 v drift linearly, bf16 keeps it bounded (tests/test_substrates.py)."""
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    if not quantize:
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )
    qm = jax.tree.map(lambda p: _quantize(jnp.zeros_like(p, jnp.float32)), params)
    m = jax.tree.map(lambda t: t[0], qm, is_leaf=lambda t: isinstance(t, tuple))
    ms = jax.tree.map(lambda t: t[1], qm, is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.bfloat16), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=m, v=v, scales=(ms, None))


def adamw_update(
    params: Params,
    grads: Params,
    state: AdamWState,
    lr: jax.Array | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> tuple[Params, AdamWState]:
    step = state.step + 1
    # global-norm clip
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    clip = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))

    quant = state.scales is not None
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, ms=None):
        g = g.astype(jnp.float32) * clip
        if quant:
            m = _dequantize(m, ms, p.shape, p.size)
            v = v.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        update = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        p32 = p.astype(jnp.float32)
        new_p = (p32 - lr * (update + weight_decay * p32)).astype(p.dtype)
        if quant:
            mq, mss = _quantize(m)
            return new_p, mq, v.astype(jnp.bfloat16), mss, None
        return new_p, m, v, None, None

    if quant:
        out = jax.tree.map(upd, params, grads, state.m, state.v, state.scales[0])
    else:
        out = jax.tree.map(upd, params, grads, state.m, state.v)
    get = lambda i: jax.tree.map(
        lambda t: t[i], out, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 5
    )
    new_params, m, v = get(0), get(1), get(2)
    scales = (get(3), None) if quant else None
    return new_params, AdamWState(step=step, m=m, v=v, scales=scales)


def quantize_state(state: AdamWState) -> AdamWState:
    """Convert an fp32 state to int8-m / bf16-v (e.g. before checkpointing)."""
    if state.scales is not None:
        return state
    qm = jax.tree.map(_quantize, state.m)
    tup = lambda t, i: jax.tree.map(
        lambda x: x[i], t, is_leaf=lambda x: isinstance(x, tuple)
    )
    return AdamWState(
        step=state.step,
        m=tup(qm, 0),
        v=jax.tree.map(lambda x: x.astype(jnp.bfloat16), state.v),
        scales=(tup(qm, 1), None),
    )
