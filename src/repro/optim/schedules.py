"""LR schedules, including minicpm's WSD (warmup-stable-decay)."""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup: int, peak: float):
    return peak * jnp.minimum(1.0, (step + 1) / max(warmup, 1))


def constant(step, peak: float, warmup: int = 0):
    return linear_warmup(step, warmup, peak) if warmup else jnp.full_like(
        jnp.asarray(step, jnp.float32), peak
    )


def cosine(step, total: int, peak: float, warmup: int = 0, floor: float = 0.0):
    w = linear_warmup(step, warmup, peak)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    c = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, w, c)


def wsd(step, total: int, peak: float, warmup: int = 0, decay_frac: float = 0.1,
        floor: float = 0.0):
    """Warmup-Stable-Decay (MiniCPM): flat plateau, then sharp decay tail."""
    w = linear_warmup(step, warmup, peak)
    decay_start = int(total * (1 - decay_frac))
    t = jnp.clip((step - decay_start) / max(total - decay_start, 1), 0.0, 1.0)
    d = peak * (floor / peak) ** t if floor > 0 else peak * (1 - t)
    stable = jnp.full_like(jnp.asarray(step, jnp.float32), peak)
    out = jnp.where(step < warmup, w, jnp.where(step < decay_start, stable, d))
    return out
