"""Optimizers and schedules (no optax dependency)."""

from .adamw import AdamWState, adamw_init, adamw_update, quantize_state
from .schedules import constant, cosine, linear_warmup, wsd

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "constant",
    "cosine",
    "linear_warmup",
    "quantize_state",
    "wsd",
]
